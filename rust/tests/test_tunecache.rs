//! On-disk tune-cache invariants: a cold tune writes winners to the
//! cache file, a warm second load answers every layer signature from it
//! without a microbench, a corrupt or stale-version file silently
//! degrades to live tuning, racing writers never leave a torn file, and
//! explicit config knobs always beat cached winners. These tests live
//! in their own binary (not `test_autotune.rs`) on purpose: they call
//! [`rmsmp::gemm::autotune::clear_process_cache`], which would race the
//! process-cache determinism assertions in the autotune suite if both
//! shared a test harness.
//!
//! Robust to `RMSMP_NO_TUNE=1`: direct `tune_layer` calls ignore the
//! escape hatch (it is a plan-builder policy), and the plan-level
//! assertions below only require `cache_misses == 0` on the warm build,
//! which the no-tune degenerate (zero tuning activity) satisfies.

use std::path::PathBuf;
use std::sync::Arc;

use rmsmp::gemm::autotune::{self, tune_layer};
use rmsmp::gemm::{
    LayerSig, PackedWeights, ParallelConfig, SortedWeights, TuneSource, TuneStats,
    MICRO_ROWS_CANDIDATES,
};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

/// The versioned first line of the cache format — the on-disk contract
/// these tests pin (bump it in `gemm/autotune.rs` and every existing
/// cache file is deliberately stale).
const HEADER: &str = "rmsmp-tune-cache v2";

/// A per-test cache file under the system temp dir, deleted on drop so
/// reruns always start cold.
struct TmpCache(PathBuf);

impl TmpCache {
    fn new(name: &str) -> TmpCache {
        let p = std::env::temp_dir()
            .join(format!("rmsmp-tunecache-test-{}-{name}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        TmpCache(p)
    }
}

impl Drop for TmpCache {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

fn knobs(p: &rmsmp::gemm::TunedParams) -> (usize, usize, usize, usize) {
    (p.micro_rows, p.tile_cols, p.min_rows_per_task, p.panel_bytes)
}

#[test]
fn cold_tune_writes_the_cache_and_a_warm_tune_reads_it_back() {
    let tmp = TmpCache::new("roundtrip");
    let sig = LayerSig::canonical(24, 48, 8);
    let cfg = ParallelConfig::sequential();

    let mut cold_stats = TuneStats::default();
    let cold = tune_layer(sig, &cfg, false, None, Some(&tmp.0), &mut cold_stats);
    assert_eq!(cold_stats.cache_misses, 1, "cold tune must microbench");
    assert_eq!(cold.source, TuneSource::Tuned);
    let text = std::fs::read_to_string(&tmp.0).expect("cold tune wrote no cache file");
    assert!(text.starts_with(HEADER), "bad cache header:\n{text}");
    assert!(text.contains(" => "), "no cache entry written:\n{text}");

    // drop the process cache so the warm answer can only come from disk
    autotune::clear_process_cache();
    let mut warm_stats = TuneStats::default();
    let warm = tune_layer(sig, &cfg, false, None, Some(&tmp.0), &mut warm_stats);
    assert_eq!(
        (warm_stats.cache_hits, warm_stats.cache_misses),
        (1, 0),
        "warm tune must answer from the disk cache without a microbench"
    );
    assert_eq!(warm.source, TuneSource::DiskCache);
    assert_eq!(knobs(&warm), knobs(&cold), "disk round-trip changed the winners");
}

#[test]
fn corrupt_and_stale_cache_files_fall_back_to_live_tuning() {
    let cfg = ParallelConfig::sequential();

    // non-UTF-8 garbage: unreadable as text, must not error
    let tmp = TmpCache::new("corrupt");
    std::fs::write(&tmp.0, b"\xff\xfe\x00 definitely not a cache").unwrap();
    let sig = LayerSig::canonical(16, 72, 8);
    let mut stats = TuneStats::default();
    let p = tune_layer(sig, &cfg, false, None, Some(&tmp.0), &mut stats);
    assert_eq!(stats.cache_misses, 1, "corrupt cache must fall back to a microbench");
    assert_eq!(p.source, TuneSource::Tuned);
    // ...and the fallback's write repairs the file in place
    let text = std::fs::read_to_string(&tmp.0).unwrap();
    assert!(text.starts_with(HEADER), "fallback did not rewrite a valid cache");

    // stale schema version: parseable, but the header gate rejects it
    let tmp2 = TmpCache::new("stale");
    std::fs::write(&tmp2.0, "rmsmp-tune-cache v1\nold-key => 4 256 8 32768\n").unwrap();
    let sig2 = LayerSig::canonical(32, 96, 8);
    let mut stats2 = TuneStats::default();
    tune_layer(sig2, &cfg, false, None, Some(&tmp2.0), &mut stats2);
    assert_eq!(stats2.cache_misses, 1, "stale-version cache must not be trusted");
    let text2 = std::fs::read_to_string(&tmp2.0).unwrap();
    assert!(text2.starts_with(HEADER), "rewrite kept the stale version header");
    assert!(!text2.contains("old-key"), "stale entries survived the version bump");

    // torn / half-garbage entries under a valid header: skipped, not fatal
    let tmp3 = TmpCache::new("torn");
    std::fs::write(
        &tmp3.0,
        format!("{HEADER}\ngood-looking-key => 4 256\nnoise\nk => a b c d\n"),
    )
    .unwrap();
    // fresh signature: sig2 is already in the process cache by now
    let sig3 = LayerSig::canonical(48, 96, 8);
    let mut stats3 = TuneStats::default();
    tune_layer(sig3, &cfg, false, None, Some(&tmp3.0), &mut stats3);
    assert_eq!(stats3.cache_misses, 1, "torn entries must read as absent");
}

#[test]
fn racing_writers_leave_a_complete_parseable_file() {
    let tmp = TmpCache::new("race");
    let cfg = ParallelConfig::sequential();
    std::thread::scope(|s| {
        for i in 0..4usize {
            let path = &tmp.0;
            let cfg = &cfg;
            s.spawn(move || {
                let sig = LayerSig::canonical(16 + 8 * i, 64, 8);
                let mut stats = TuneStats::default();
                tune_layer(sig, cfg, false, None, Some(path), &mut stats);
            });
        }
    });
    // write is temp-file + atomic rename: whatever interleaving the
    // racing read-merge-rename writers took, the surviving file is a
    // complete snapshot — header first, every entry line well-formed
    let text = std::fs::read_to_string(&tmp.0).expect("racing writers lost the file");
    let mut lines = text.lines();
    assert_eq!(lines.next().map(str::trim), Some(HEADER));
    let mut entries = 0;
    for line in lines {
        let (_, val) = line.split_once(" => ").expect("torn cache line");
        let nums: Vec<usize> =
            val.split_whitespace().map(|t| t.parse().unwrap()).collect();
        assert_eq!(nums.len(), 4, "entry must carry mr/tile/chunk/panel: {line:?}");
        entries += 1;
    }
    assert!(entries >= 1, "last writer must persist at least its own entry");
}

#[test]
fn explicit_config_knobs_override_cached_winners() {
    let tmp = TmpCache::new("override");
    // seed the cache from the default baseline, where every knob sweeps
    let sig = LayerSig::canonical(40, 80, 8);
    let base = ParallelConfig::sequential();
    let mut stats = TuneStats::default();
    tune_layer(sig, &base, false, None, Some(&tmp.0), &mut stats);

    // an explicit non-default height is a caller decision: the sweep is
    // skipped and the cached winner cannot displace it (the cache key
    // includes the baseline knobs, so this cannot even alias the seeded
    // entry)
    let explicit = ParallelConfig { micro_rows: 8, tile_cols: 64, ..base };
    let mut stats2 = TuneStats::default();
    let p = tune_layer(sig, &explicit, false, None, Some(&tmp.0), &mut stats2);
    assert_eq!(p.micro_rows, 8, "explicit micro_rows lost to the tuner");
    assert_eq!(p.apply_to(explicit).micro_rows, 8);
    assert_eq!(p.apply_to(explicit).tile_cols, 64, "explicit tile_cols lost");
}

// ---------------------------------------------------------------------------
// Plan-level: the acceptance gate — a second plan compile against a warm
// cache performs zero microbench dispatches and reproduces the first
// plan's logits bit for bit.
// ---------------------------------------------------------------------------

fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    schemes: Vec<Scheme>,
    bias: Vec<f32>,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups: 1,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias,
        w: Some(w),
        packed,
        sorted,
    }
}

/// conv(3x3 s1 p1, relu) -> gap -> fc, integer-accumulating schemes
/// only. Callers pick distinct `(c1, classes)` per test: layer
/// signatures are part of the tune-cache key, and two tests sharing
/// one would let the process cache satisfy a build the test needs to
/// see miss.
fn model(c1: usize, classes: usize) -> (Manifest, ModelWeights, Tensor4) {
    let (n, c_in, hw) = (2usize, 3usize, 5usize);
    let cc = c_in * 9;
    let mut rng = Rng::new(21);
    let pool: [Scheme; 3] = [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];
    let w1 = Mat::from_vec(c1, cc, rng.normal_vec(c1 * cc, 0.5));
    let b1: Vec<f32> = (0..c1).map(|_| rng.normal() * 0.1).collect();
    let layers = vec![
        layer(
            "c1",
            "conv",
            w1,
            (c1, c_in, 3, 3),
            1,
            1,
            (0..c1).map(|r| pool[r % 3]).collect(),
            b1,
        ),
        layer(
            "fc",
            "linear",
            Mat::from_vec(classes, c1, rng.normal_vec(classes * c1, 0.5)),
            (classes, c1, 1, 1),
            0,
            0,
            (0..classes).map(|r| pool[r % 3]).collect(),
            (0..classes).map(|_| rng.normal() * 0.1).collect(),
        ),
    ];
    let json = format!(
        r#"{{"model":"tunecache","arch":"resnet","num_classes":{classes},
            "input_shape":[{n},{c_in},{hw},{hw}],"ratio":[65,30,5],"act_bits":4,
            "layers":[
              {{"name":"c1","kind":"conv","rows":{c1},"cols":{cc},"stride":1,"pad":1,
               "groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}},
              {{"name":"fc","kind":"linear","rows":{classes},"cols":{c1},"stride":0,"pad":0,
               "groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}],
            "program":[
              {{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true}},
              {{"op":"gap","in":"b0","out":"g0"}},
              {{"op":"linear","layer":"fc","in":"g0","out":"logits"}}]}}"#
    );
    let manifest = Manifest::from_json(&Json::parse(&json).unwrap()).unwrap();
    let mut x = Tensor4::zeros(n, c_in, hw, hw);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.2);
    }
    (manifest, ModelWeights { layers }, x)
}

fn logits(manifest: &Manifest, weights: &ModelWeights, plan: Plan, x: &Tensor4) -> Vec<f32> {
    let mut exec = Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        Arc::new(plan),
        ParallelConfig::sequential(),
        None,
    )
    .unwrap();
    exec.infer(x).unwrap().data.clone()
}

#[test]
fn warm_cache_plan_compile_skips_every_microbench() {
    let tmp = TmpCache::new("plan-warm");
    let (manifest, weights, x) = model(10, 3);

    let cold =
        Plan::builder(&manifest, &weights).capacity(2).tune_cache(&tmp.0).build().unwrap();
    // drop the process cache: the warm build below may only use the disk
    autotune::clear_process_cache();
    let warm =
        Plan::builder(&manifest, &weights).capacity(2).tune_cache(&tmp.0).build().unwrap();

    assert_eq!(
        warm.tune_stats.cache_misses, 0,
        "warm tune cache still ran a microbench: {:?}",
        warm.tune_stats
    );
    for (c, w) in cold.layer_tuned.iter().zip(&warm.layer_tuned) {
        assert_eq!(knobs(c), knobs(w), "warm cache changed a layer's winners");
        assert!(
            MICRO_ROWS_CANDIDATES.contains(&w.micro_rows),
            "layer micro_rows {} not a tuner candidate",
            w.micro_rows
        );
    }
    let a = logits(&manifest, &weights, cold, &x);
    let b = logits(&manifest, &weights, warm, &x);
    assert_eq!(a, b, "warm-cache plan changed the logits");
}

#[test]
fn explicit_builder_config_beats_the_warm_cache_at_plan_level() {
    let tmp = TmpCache::new("plan-override");
    let (manifest, weights, x) = model(12, 4);
    // warm the cache with the default baseline first
    let baseline =
        Plan::builder(&manifest, &weights).capacity(2).tune_cache(&tmp.0).build().unwrap();

    let cfg = ParallelConfig { micro_rows: 6, ..ParallelConfig::sequential() };
    let plan = Plan::builder(&manifest, &weights)
        .capacity(2)
        .config(&cfg)
        .tune_cache(&tmp.0)
        .build()
        .unwrap();
    assert_eq!(plan.cfg.micro_rows, 6, "explicit micro_rows lost to a cached winner");
    for t in &plan.layer_tuned {
        assert_eq!(t.micro_rows, 6, "a layer overrode the explicit micro_rows");
    }
    // still purely an optimization: logits match the baseline build
    let a = logits(&manifest, &weights, baseline, &x);
    let b = logits(&manifest, &weights, plan, &x);
    assert_eq!(a, b, "explicit blocking override changed the logits");
}
