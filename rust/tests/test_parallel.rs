//! Parallel-execution invariants: the multi-threaded mixed GEMM must be
//! bit-exact vs the sequential path across random row/scheme/batch shapes
//! and thread counts, and the coordinator must stay consistent under
//! concurrent requests through the parallel executor.

use std::sync::Arc;

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{Server, ServerConfig};
use rmsmp::gemm::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, PackedActs,
    PackedWeights, ParallelConfig, SortedWeights,
};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::prop_assert;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::prop::{check, Gen};
use rmsmp::util::rng::Rng;

const SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

fn gen_problem(g: &mut Gen) -> (PackedActs, PackedWeights) {
    let batch = g.usize_in(0, 7);
    let rows = g.usize_in(1, 96);
    let cols = g.usize_in(1, 80);
    let x = Mat::from_vec(batch, cols, g.vec_f32(batch * cols, batch * cols, 0.0, 1.5));
    let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
    let schemes: Vec<Scheme> = (0..rows).map(|_| *g.choice(&SCHEMES)).collect();
    let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let acts = PackedActs::quantize(&x, g.f32_in(0.3, 2.0), 4);
    let pw = PackedWeights::quantize(&w, &schemes, &alpha);
    (acts, pw)
}

/// One standalone mixed GEMM through the public dispatch descriptor.
fn run_mixed(engine: &MixedGemm, acts: &PackedActs, pw: &PackedWeights, parallel: bool) -> Mat {
    let sw = SortedWeights::from_packed(pw);
    let chunks = chunk_tasks(sw.partition(), engine.config().min_rows_per_task);
    let mut scratch = GemmScratch::new(engine.lanes());
    let mut out = Mat::zeros(acts.rows, pw.rows);
    engine.dispatch(
        GemmCall {
            acts: GemmActs::Packed(acts),
            weights: &sw,
            chunks: &chunks,
            parallel,
            fill: true,
            out: GemmOut::F32(&mut out),
        },
        &mut scratch,
    );
    out
}

#[test]
fn prop_parallel_bit_exact_across_threads() {
    // shared pools: one engine per thread count, reused across cases
    let engines: Vec<MixedGemm> = [1usize, 2, 8]
        .iter()
        .map(|&threads| {
            MixedGemm::with_config(ParallelConfig {
                threads,
                tile_cols: 32,
                min_rows_per_task: 4,
                ..ParallelConfig::default()
            })
        })
        .collect();
    check("parallel-bit-exact", 40, |g| {
        let (acts, pw) = gen_problem(g);
        let want = run_mixed(&engines[0], &acts, &pw, false);
        for e in &engines {
            let got = run_mixed(e, &acts, &pw, true);
            prop_assert!(
                got.data == want.data,
                "diverged at {} threads (batch={} rows={})",
                e.config().resolved_threads(),
                acts.rows,
                pw.rows
            );
        }
        Ok(())
    });
}

#[test]
fn prop_task_granularity_does_not_change_results() {
    let pool_cfg = ParallelConfig { threads: 4, tile_cols: 16, min_rows_per_task: 1, ..ParallelConfig::default() };
    let coarse_cfg = ParallelConfig { threads: 4, tile_cols: 16, min_rows_per_task: 64, ..ParallelConfig::default() };
    let fine = MixedGemm::with_config(pool_cfg);
    let coarse = MixedGemm::with_config(coarse_cfg);
    check("task-granularity", 25, |g| {
        let (acts, pw) = gen_problem(g);
        let a = run_mixed(&fine, &acts, &pw, true);
        let b = run_mixed(&coarse, &acts, &pw, true);
        prop_assert!(a.data == b.data, "task size changed results");
        Ok(())
    });
}

#[test]
fn prop_tile_size_exact_for_rmsmp_classes() {
    // integer accumulation: any tile size is bit-exact for the three
    // hardware classes (APoT is float and pinned per tile size instead).
    let rmsmp_only = [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];
    check("tile-exact", 25, |g| {
        let rows = g.usize_in(1, 48);
        let cols = g.usize_in(1, 120);
        let batch = g.usize_in(1, 5);
        let x = Mat::from_vec(batch, cols, g.vec_f32(batch * cols, batch * cols, 0.0, 1.0));
        let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
        let schemes: Vec<Scheme> = (0..rows).map(|_| *g.choice(&rmsmp_only)).collect();
        let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);

        let untiled = MixedGemm::with_config(ParallelConfig {
            threads: 1,
            tile_cols: 0,
            min_rows_per_task: 8,
            ..ParallelConfig::default()
        });
        let want = run_mixed(&untiled, &acts, &pw, true);
        for tile in [1usize, 13, 64] {
            let tiled = MixedGemm::with_config(ParallelConfig {
                threads: 1,
                tile_cols: tile,
                min_rows_per_task: 8,
                ..ParallelConfig::default()
            });
            let got = run_mixed(&tiled, &acts, &pw, true);
            prop_assert!(got.data == want.data, "tile {tile} changed integer results");
        }
        Ok(())
    });
}

/// Tiny linear model (gap -> fc) that needs no artifacts.
fn tiny_model(seed: u64) -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "tiny", "arch": "resnet", "num_classes": 3,
        "input_shape": [1, 2, 4, 4], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "fc", "kind": "linear", "rows": 3, "cols": 2,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [1, 1, 1, 0]}
        ],
        "program": [
          {"op": "gap", "in": "in0", "out": "b0"},
          {"op": "linear", "layer": "fc", "in": "b0", "out": "logits"}
        ]
      }"#,
        )
        .unwrap(),
    )
    .unwrap();
    let schemes = vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];
    let mut rng = Rng::new(seed);
    let w = Mat::from_vec(3, 2, rng.normal_vec(6, 0.5));
    let alpha: Vec<f32> = (0..3).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    let weights = ModelWeights {
        layers: vec![LayerWeights {
            name: "fc".into(),
            kind: "linear".into(),
            rows: 3,
            cols: 2,
            out_ch: 3,
            in_ch: 2,
            kh: 1,
            kw: 1,
            stride: 0,
            pad: 0,
            groups: 1,
            a_alpha: 1.0,
            scheme: schemes,
            alpha,
            bias: vec![0.0; 3],
            w: Some(w),
            packed,
            sorted,
        }],
    };
    (manifest, weights)
}

#[test]
fn coordinator_concurrent_requests_through_parallel_executor() {
    let (m, w) = tiny_model(9);
    let server = Arc::new(
        Server::start(
            m,
            w,
            ServerConfig {
                workers: 2,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: std::time::Duration::from_millis(1),
                    queue_cap: 256,
                },
                parallel: ParallelConfig { threads: 2, ..ParallelConfig::default() },
            },
        )
        .unwrap(),
    );

    let img: Vec<f32> = (0..server.input_len()).map(|i| (i % 5) as f32 / 5.0).collect();
    let want = server.infer(img.clone()).unwrap().logits;

    let mut clients = Vec::new();
    for _ in 0..4 {
        let server = Arc::clone(&server);
        let img = img.clone();
        let want = want.clone();
        clients.push(std::thread::spawn(move || {
            let rxs: Vec<_> = (0..8).map(|_| server.submit(img.clone()).unwrap()).collect();
            for rx in rxs {
                let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
                assert_eq!(r.logits, want, "concurrent request diverged");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => panic!("server still shared after client joins"),
    }
}
