//! Cross-language bit-exactness: Rust quantizers vs the JAX oracles, via
//! the shared test vectors in `artifacts/testvec/` (emitted by
//! `python -m compile.testvec` during `make artifacts`).
//!
//! These tests are skipped (with a notice) when the artifacts are absent,
//! so `cargo test` works before `make artifacts`; CI runs them after.

use std::path::PathBuf;

use rmsmp::gemm::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, PackedActs,
    PackedWeights, SortedWeights,
};
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;

fn testvec_dir() -> Option<PathBuf> {
    let dir = rmsmp::runtime::artifacts_dir().join("testvec");
    dir.exists().then_some(dir)
}

macro_rules! require_testvec {
    () => {
        match testvec_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/testvec missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn fixed_quant_bit_exact() {
    let dir = require_testvec!();
    let cases = Json::load(&dir.join("fixed.json")).unwrap();
    for case in cases.as_arr().unwrap() {
        let m = case.get("m").unwrap().as_usize().unwrap() as u32;
        let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
        let w = case.get("w").unwrap().as_f32_vec().unwrap();
        let q = case.get("q").unwrap().as_f32_vec().unwrap();
        let code = case.get("code").unwrap().as_f32_vec().unwrap();
        for i in 0..w.len() {
            let got = quant::fixed_quant(w[i], alpha, m);
            assert!(
                (got - q[i]).abs() < 1e-6,
                "fixed m={m} alpha={alpha} w={} got {got} want {}",
                w[i],
                q[i]
            );
            assert_eq!(
                quant::fixed_code(w[i], alpha, m),
                code[i] as i32,
                "code m={m} w={}",
                w[i]
            );
        }
    }
}

#[test]
fn pot_quant_bit_exact() {
    let dir = require_testvec!();
    let cases = Json::load(&dir.join("pot.json")).unwrap();
    for case in cases.as_arr().unwrap() {
        let m = case.get("m").unwrap().as_usize().unwrap() as u32;
        let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
        let w = case.get("w").unwrap().as_f32_vec().unwrap();
        let q = case.get("q").unwrap().as_f32_vec().unwrap();
        let sign = case.get("sign").unwrap().as_f32_vec().unwrap();
        let exp = case.get("exp").unwrap().as_f32_vec().unwrap();
        for i in 0..w.len() {
            let got = quant::pot_quant(w[i], alpha, m);
            assert!(
                (got - q[i]).abs() < 1e-6,
                "pot m={m} alpha={alpha} w={} got {got} want {}",
                w[i],
                q[i]
            );
            let (s, e) = quant::pot_code(w[i], alpha, m);
            assert_eq!(
                (s, e),
                (sign[i] as i32, exp[i] as i32),
                "pot code m={m} w={}",
                w[i]
            );
        }
    }
}

#[test]
fn apot_quant_bit_exact() {
    let dir = require_testvec!();
    let cases = Json::load(&dir.join("apot.json")).unwrap();
    for case in cases.as_arr().unwrap() {
        let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
        let w = case.get("w").unwrap().as_f32_vec().unwrap();
        let q = case.get("q").unwrap().as_f32_vec().unwrap();
        for i in 0..w.len() {
            let got = quant::apot_quant(w[i], alpha, 4);
            assert!(
                (got - q[i]).abs() < 2e-6,
                "apot alpha={alpha} w={} got {got} want {}",
                w[i],
                q[i]
            );
        }
    }
}

#[test]
fn act_quant_bit_exact() {
    let dir = require_testvec!();
    let cases = Json::load(&dir.join("act.json")).unwrap();
    for case in cases.as_arr().unwrap() {
        let m = case.get("m").unwrap().as_usize().unwrap() as u32;
        let alpha = case.get("alpha").unwrap().as_f64().unwrap() as f32;
        let x = case.get("x").unwrap().as_f32_vec().unwrap();
        let q = case.get("q").unwrap().as_f32_vec().unwrap();
        let code = case.get("code").unwrap().as_f32_vec().unwrap();
        for i in 0..x.len() {
            assert!((quant::act_quant(x[i], alpha, m) - q[i]).abs() < 1e-6);
            assert_eq!(quant::act_code(x[i], alpha, m), code[i] as i32);
        }
    }
}

fn parse_schemes(v: &[f32]) -> Vec<Scheme> {
    v.iter().map(|&c| Scheme::from_code(c as u8).unwrap()).collect()
}

#[test]
fn rowwise_quant_bit_exact() {
    let dir = require_testvec!();
    let tv = Json::load(&dir.join("rowwise.json")).unwrap();
    let rows = tv.get("rows").unwrap().as_usize().unwrap();
    let cols = tv.get("cols").unwrap().as_usize().unwrap();
    let w = Mat::from_vec(rows, cols, tv.get("w").unwrap().as_f32_vec().unwrap());
    let alpha = tv.get("alpha").unwrap().as_f32_vec().unwrap();
    let schemes = parse_schemes(&tv.get("scheme").unwrap().as_f32_vec().unwrap());
    let want = Mat::from_vec(rows, cols, tv.get("q").unwrap().as_f32_vec().unwrap());
    let got = quant::rowwise_quant(&w, &alpha, &schemes);
    let err = got.max_abs_err(&want);
    assert!(err < 2e-6, "rowwise err {err}");
}

#[test]
fn mixed_gemm_matches_jax() {
    let dir = require_testvec!();
    let tv = Json::load(&dir.join("gemm.json")).unwrap();
    let batch = tv.get("batch").unwrap().as_usize().unwrap();
    let rows = tv.get("rows").unwrap().as_usize().unwrap();
    let cols = tv.get("cols").unwrap().as_usize().unwrap();
    let x = Mat::from_vec(batch, cols, tv.get("x").unwrap().as_f32_vec().unwrap());
    let w = Mat::from_vec(rows, cols, tv.get("w").unwrap().as_f32_vec().unwrap());
    let alpha = tv.get("alpha").unwrap().as_f32_vec().unwrap();
    let schemes = parse_schemes(&tv.get("scheme").unwrap().as_f32_vec().unwrap());
    let act_alpha = tv.get("act_alpha").unwrap().as_f64().unwrap() as f32;
    let want = Mat::from_vec(batch, rows, tv.get("y").unwrap().as_f32_vec().unwrap());

    // integer cores, through the public dispatch descriptor
    let g = MixedGemm::new();
    let acts = PackedActs::quantize(&x, act_alpha, 4);
    let pw = PackedWeights::quantize(&w, &schemes, &alpha);
    let sw = SortedWeights::from_packed(&pw);
    let chunks = chunk_tasks(sw.partition(), g.config().min_rows_per_task);
    let mut scratch = GemmScratch::new(g.lanes());
    let mut int_out = Mat::zeros(acts.rows, pw.rows);
    g.dispatch(
        GemmCall {
            acts: GemmActs::Packed(&acts),
            weights: &sw,
            chunks: &chunks,
            parallel: false,
            fill: true,
            out: GemmOut::F32(&mut int_out),
        },
        &mut scratch,
    );
    let err = int_out.max_abs_err(&want);
    assert!(err < 5e-4, "integer gemm vs jax err {err}");

    // float fake-quant path
    let f_out = g.run_float(&x, &w, &schemes, &alpha, act_alpha, 4);
    let err = f_out.max_abs_err(&want);
    assert!(err < 5e-5, "float gemm vs jax err {err}");
}
