//! Integer-resident pipeline invariants: the plan executor with fused
//! requantization epilogues must produce **bit-identical** activation
//! codes and logits to the f32-resident dataflow and to the reference
//! interpreter (`Executor::reference_infer`), across conv stride/pad,
//! grouped conv, residual Add+ReLU, Gap, the linear head, batch
//! {1, 5, 8}, threads {1, 8}, and the scalar (`RMSMP_NO_SIMD`) vs
//! native SIMD kernels.
//!
//! Activation codes are pinned directly: for every op the plan marked
//! integer-resident, the u8 code slot after `infer` must equal the
//! elementwise requantization of the f32-resident executor's slot
//! values — i.e. exactly what the consumer's quantizer would have
//! produced from the f32 buffer.

use std::sync::Arc;

use rmsmp::gemm::{Isa, PackedWeights, ParallelConfig, Requant, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan, PlanOp};
use rmsmp::prop_assert;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::prop::{check, Gen};
use rmsmp::util::rng::Rng;

const SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

#[allow(clippy::too_many_arguments)]
fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
    schemes: Vec<Scheme>,
    bias: Vec<f32>,
    a_alpha: f32,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        a_alpha,
        scheme: schemes,
        alpha,
        bias,
        w: Some(w),
        packed,
        sorted,
    }
}

#[allow(clippy::too_many_arguments)]
fn rand_layer(
    g: &mut Gen,
    name: &str,
    kind: &str,
    rows: usize,
    cols: usize,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
) -> LayerWeights {
    let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
    let schemes: Vec<Scheme> = (0..rows).map(|_| *g.choice(&SCHEMES)).collect();
    let bias = g.vec_normal(rows, rows, 0.1);
    // non-unit, per-layer activation clip scales so the fused epilogue's
    // requantization scale actually differs per edge
    let a_alpha = g.f32_in(0.6, 1.4);
    layer(name, kind, w, conv, stride, pad, groups, schemes, bias, a_alpha)
}

/// Build a random model of one of three topologies, all containing at
/// least one integer-resident edge:
///   0 — conv(k3, random stride/pad, relu) → conv → gap → fc
///   1 — conv → depthwise conv (groups = channels) → conv → gap → fc
///   2 — conv(relu) → conv → add(+relu) → conv → conv → gap → fc
///       (the epilogue_fusion pass folds the add into the second conv,
///        whose fused output then goes integer-resident into c3; b0
///        feeds both the conv and the fused addend, so it stays f32;
///        the conv→conv pair after the residual is the second
///        integer-resident edge)
fn build_model(g: &mut Gen, topo: usize, n: usize) -> (Manifest, ModelWeights, Tensor4) {
    let c_in = *g.choice(&[2usize, 3]);
    let hw = *g.choice(&[6usize, 7]);
    let c1 = 4usize;
    let classes = 3usize;
    let (stride, pad) = if topo == 0 {
        (*g.choice(&[1usize, 2]), *g.choice(&[0usize, 1]))
    } else {
        (1, 1)
    };

    let mut layers = vec![rand_layer(
        g,
        "c1",
        "conv",
        c1,
        c_in * 9,
        (c1, c_in, 3, 3),
        stride,
        pad,
        1,
    )];
    let mut meta = format!(
        r#"{{"name":"c1","kind":"conv","rows":{c1},"cols":{},"stride":{stride},"pad":{pad},"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#,
        c_in * 9
    );
    let mut prog =
        r#"{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true}"#.to_string();

    let conv_meta = |name: &str, rows: usize, cols: usize, groups: usize| {
        format!(
            r#",{{"name":"{name}","kind":"conv","rows":{rows},"cols":{cols},"stride":1,"pad":1,"groups":{groups},"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
        )
    };

    let gap_in = match topo {
        1 => {
            layers.push(rand_layer(g, "dw", "conv", c1, 9, (c1, c1, 3, 3), 1, 1, c1));
            meta.push_str(&conv_meta("dw", c1, 9, c1));
            prog.push_str(r#",{"op":"conv","layer":"dw","in":"b0","out":"b1","relu":false}"#);
            layers.push(rand_layer(
                g,
                "c2",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c2", c1, c1 * 9, 1));
            prog.push_str(r#",{"op":"conv","layer":"c2","in":"b1","out":"b2","relu":true}"#);
            "b2"
        }
        2 => {
            layers.push(rand_layer(
                g,
                "c2",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c2", c1, c1 * 9, 1));
            prog.push_str(r#",{"op":"conv","layer":"c2","in":"b0","out":"b1","relu":false}"#);
            prog.push_str(r#",{"op":"add","a":"b0","b":"b1","out":"b2","relu":true}"#);
            layers.push(rand_layer(
                g,
                "c3",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c3", c1, c1 * 9, 1));
            prog.push_str(r#",{"op":"conv","layer":"c3","in":"b2","out":"b3","relu":false}"#);
            layers.push(rand_layer(
                g,
                "c4",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c4", c1, c1 * 9, 1));
            prog.push_str(r#",{"op":"conv","layer":"c4","in":"b3","out":"b4","relu":true}"#);
            "b4"
        }
        _ => {
            layers.push(rand_layer(
                g,
                "c2",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c2", c1, c1 * 9, 1));
            prog.push_str(r#",{"op":"conv","layer":"c2","in":"b0","out":"b1","relu":false}"#);
            "b1"
        }
    };

    layers.push(rand_layer(g, "fc", "linear", classes, c1, (classes, c1, 1, 1), 0, 0, 1));
    meta.push_str(&format!(
        r#",{{"name":"fc","kind":"linear","rows":{classes},"cols":{c1},"stride":0,"pad":0,"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
    ));
    prog.push_str(&format!(
        r#",{{"op":"gap","in":"{gap_in}","out":"g0"}},{{"op":"linear","layer":"fc","in":"g0","out":"logits"}}"#
    ));

    let json = format!(
        r#"{{"model":"requant","arch":"resnet","num_classes":{classes},
            "input_shape":[{n},{c_in},{hw},{hw}],"ratio":[65,30,5],"act_bits":4,
            "layers":[{meta}],"program":[{prog}]}}"#
    );
    let manifest = Manifest::from_json(&Json::parse(&json).unwrap()).unwrap();

    let mut x = Tensor4::zeros(n, c_in, hw, hw);
    for v in x.data.iter_mut() {
        *v = g.f32_in(0.0, 1.2);
    }
    (manifest, ModelWeights { layers }, x)
}

/// The f32-resident twin of an integer-resident executor: same
/// manifest/weights/config, plan compiled with domain inference off.
fn f32_resident_executor(
    manifest: &Manifest,
    weights: &ModelWeights,
    cfg: ParallelConfig,
) -> Executor {
    let capacity = manifest.input_shape.first().copied().unwrap_or(1);
    let plan = Arc::new(
        Plan::builder(manifest, weights)
            .capacity(capacity)
            .config(&cfg)
            .disable_pass("integer_resident")
            .build()
            .unwrap(),
    );
    Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        plan,
        cfg,
        None,
    )
    .unwrap()
}

/// Elements the plan op wrote to its output slot for batch `n`.
fn out_len(op: &PlanOp, weights: &ModelWeights, n: usize) -> (usize, usize) {
    match op {
        PlanOp::Conv { layer, out, oh, ow, .. } => {
            (*out, n * weights.layers[*layer].out_ch * oh * ow)
        }
        PlanOp::Linear { out, out_cols, .. } => (*out, n * out_cols),
        PlanOp::Add { out, per_image, .. } => (*out, n * per_image),
        PlanOp::Gap { out, c, .. } => (*out, n * c),
    }
}

/// Pin every integer-resident slot's codes against the f32-resident
/// executor's values run through the consumer quantizer, and return how
/// many integer-resident ops the plan holds.
fn assert_codes_pinned(int_exec: &Executor, f32_exec: &Executor, n: usize) -> usize {
    let weights = int_exec.weights();
    let mut integer_ops = 0;
    for op in &int_exec.plan().ops {
        let rq: Option<Requant> = match op {
            PlanOp::Conv { out_quant, .. } | PlanOp::Linear { out_quant, .. } => *out_quant,
            _ => None,
        };
        let Some(rq) = rq else { continue };
        integer_ops += 1;
        let (slot, len) = out_len(op, weights, n);
        let codes = &int_exec.workspace().slot_codes(slot)[..len];
        let vals = &f32_exec.workspace().slot_f32(slot)[..len];
        for (i, (&c, &v)) in codes.iter().zip(vals).enumerate() {
            assert_eq!(
                c,
                rq.code(v),
                "slot {slot} elem {i}: integer-resident code diverged from requantized f32"
            );
        }
    }
    integer_ops
}

#[test]
fn prop_integer_resident_bit_exact_across_grid() {
    check("requant-pipeline", 18, |g| {
        let topo = g.usize_in(0, 2);
        let n = *g.choice(&[1usize, 5, 8]);
        let (manifest, weights, x) = build_model(g, topo, n);
        let isas = [Isa::Scalar, Isa::detect()];
        for &threads in &[1usize, 8] {
            let cfg = ParallelConfig { threads, tile_cols: 32, min_rows_per_task: 2, ..ParallelConfig::default() };
            let mut int_exec =
                Executor::with_parallel(manifest.clone(), weights.clone(), cfg, None)
                    .map_err(|e| format!("compile failed (topo {topo}): {e}"))?;
            let mut f32_exec = f32_resident_executor(&manifest, &weights, cfg);
            prop_assert!(
                int_exec.plan().integer_resident && !f32_exec.plan().integer_resident,
                "plan domain flags wrong"
            );
            for &isa in &isas {
                int_exec.set_isa(isa);
                f32_exec.set_isa(isa);
                let int_out = int_exec.infer(&x).unwrap().clone();
                let f32_out = f32_exec.infer(&x).unwrap().clone();
                let ref_out = int_exec.reference_infer(&x).unwrap();
                prop_assert!(
                    int_out.data == ref_out.data,
                    "integer path != reference (topo {topo}, {threads} thr, {isa:?})"
                );
                prop_assert!(
                    int_out.data == f32_out.data,
                    "integer path != f32-resident path (topo {topo}, {threads} thr, {isa:?})"
                );
                // warm re-run over reused buffers must not drift
                let again = int_exec.infer(&x).unwrap().clone();
                prop_assert!(again.data == int_out.data, "warm re-run drifted (topo {topo})");
                let pinned = assert_codes_pinned(&int_exec, &f32_exec, n);
                prop_assert!(
                    pinned >= 1,
                    "topology {topo} produced no integer-resident edge"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn domain_inference_marks_expected_edges() {
    let mut g = Gen { rng: Rng::new(31), size: 1.0 };
    // topo 2 after fusion: the add is folded into c2 (whose output b2
    // then goes integer-resident into c3); b0 feeds c2's GEMM input
    // *and* its fused addend → f32; b1 is orphaned by the fold → dead;
    // b3 (c3 → c4) is the second integer edge; b4 feeds gap → f32.
    let (manifest, weights, _) = build_model(&mut g, 2, 2);
    let plan = Plan::builder(&manifest, &weights)
        .capacity(2)
        .config(&ParallelConfig::sequential())
        .build()
        .unwrap();
    assert!(plan.integer_resident);
    let mut by_layer: Vec<(String, bool, bool)> = Vec::new();
    for op in &plan.ops {
        if let PlanOp::Conv { layer, in_codes, out_quant, .. }
        | PlanOp::Linear { layer, in_codes, out_quant, .. } = op
        {
            by_layer.push((
                weights.layers[*layer].name.clone(),
                *in_codes,
                out_quant.is_some(),
            ));
        }
    }
    let find = |name: &str| by_layer.iter().find(|(n, _, _)| n == name).unwrap().clone();
    // c1 -> b0 is read by c2's GEMM input (quant) and fused addend
    // (f32): stays f32
    assert_eq!(find("c1"), ("c1".into(), false, false));
    // c2 carries the fused add, reads f32 b0, writes b2 read only by
    // c3: u8 out through the fused epilogue
    assert_eq!(find("c2"), ("c2".into(), false, true));
    let c2 = plan
        .ops
        .iter()
        .find_map(|op| match op {
            PlanOp::Conv { layer, fused_add, .. }
                if weights.layers[*layer].name == "c2" =>
            {
                Some(*fused_add)
            }
            _ => None,
        })
        .unwrap();
    let fa = c2.expect("add not fused into c2");
    assert!(fa.relu, "fused add lost its relu");
    // the add op itself is gone
    assert!(!plan.ops.iter().any(|op| matches!(op, PlanOp::Add { .. })));
    // c3 consumes c2's codes, writes b3 read only by c4: u8 out
    assert_eq!(find("c3"), ("c3".into(), true, true));
    // c4 consumes codes, writes b4 read only by gap: f32 out
    assert_eq!(find("c4"), ("c4".into(), true, false));
    // fc reads the f32 gap output and writes logits: f32 everywhere
    assert_eq!(find("fc"), ("fc".into(), false, false));
    // b1 was orphaned by the fold: dead, zero bytes either domain
    let b1_id = plan.slots.iter().position(|s| s.name == "b1").unwrap();
    let b1 = &plan.slots[b1_id];
    assert!(!b1.holds_f32 && !b1.holds_codes, "b1 not dead: {b1:?}");
    let fp = plan.footprint(1);
    assert_eq!(fp.slot_bytes(b1_id), 0, "dead slot still budgets bytes");

    // topo 0 is the positive case: c1 -> b0 read only by c2
    let (manifest, weights, _) = build_model(&mut g, 0, 2);
    let plan = Plan::builder(&manifest, &weights)
        .capacity(2)
        .config(&ParallelConfig::sequential())
        .build()
        .unwrap();
    let mut marked = 0;
    for op in &plan.ops {
        if let PlanOp::Conv { layer, in_codes, out_quant, .. } = op {
            let name = &weights.layers[*layer].name;
            if name == "c1" {
                assert!(out_quant.is_some(), "c1 -> c2 edge not integer-resident");
                let want = Requant::new(weights.layer("c2").unwrap().a_alpha, 4);
                assert_eq!(out_quant.unwrap(), want, "epilogue scale != consumer scale");
                marked += 1;
            }
            if name == "c2" {
                assert!(*in_codes, "c2 does not consume codes");
                assert!(out_quant.is_none(), "c2 -> gap must stay f32");
                marked += 1;
            }
        }
    }
    assert_eq!(marked, 2);
    // slot domains: b0 codes-only (no f32 buffer), in0 f32
    let b0 = plan.slots.iter().find(|s| s.name == "b0").unwrap();
    assert!(b0.holds_codes && !b0.holds_f32, "b0 domains: {b0:?}");
    let fp = plan.footprint(1);
    let b0_id = plan.slots.iter().position(|s| s.name == "b0").unwrap();
    assert_eq!(fp.slot_elems[b0_id], 0, "codes-only slot still budgets f32");
    assert!(fp.code_slot_elems[b0_id] > 0);
}

#[test]
fn grouped_conv_integer_edges_bit_exact_batch8() {
    // fixed heavy case: depthwise chain (codes in *and* codes out of a
    // grouped conv) at batch 8 across thread counts
    for seed in [3u64, 17] {
        let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
        let (manifest, weights, x) = build_model(&mut g, 1, 8);
        for threads in [1usize, 8] {
            let cfg = ParallelConfig { threads, tile_cols: 16, min_rows_per_task: 2, ..ParallelConfig::default() };
            let mut int_exec =
                Executor::with_parallel(manifest.clone(), weights.clone(), cfg, None).unwrap();
            let mut f32_exec = f32_resident_executor(&manifest, &weights, cfg);
            let int_out = int_exec.infer(&x).unwrap().clone();
            let f32_out = f32_exec.infer(&x).unwrap().clone();
            let ref_out = int_exec.reference_infer(&x).unwrap();
            assert_eq!(int_out.data, ref_out.data, "seed {seed} threads {threads}");
            assert_eq!(int_out.data, f32_out.data, "seed {seed} threads {threads}");
            // dw consumes and produces codes; c2 consumes codes
            let pinned = assert_codes_pinned(&int_exec, &f32_exec, 8);
            assert!(pinned >= 2, "expected dw + c1 integer edges, got {pinned}");
        }
    }
}

#[test]
fn from_shared_rejects_stale_epilogue_scales() {
    let mut g = Gen { rng: Rng::new(41), size: 1.0 };
    let (manifest, weights, _) = build_model(&mut g, 0, 2);
    let cfg = ParallelConfig::sequential();
    let plan =
        Arc::new(Plan::builder(&manifest, &weights).capacity(2).config(&cfg).build().unwrap());
    // same geometry + scheme mix, different consumer clip scale: the
    // baked epilogue scale is stale for these weights
    let mut w2 = weights.clone();
    for l in w2.layers.iter_mut() {
        if l.name == "c2" {
            l.a_alpha *= 2.0;
        }
    }
    assert!(
        Executor::from_shared(
            Arc::new(manifest.clone()),
            Arc::new(w2),
            Arc::clone(&plan),
            cfg,
            None
        )
        .is_err(),
        "stale epilogue scale accepted"
    );
    // the original weights still pass
    assert!(Executor::from_shared(
        Arc::new(manifest),
        Arc::new(weights),
        plan,
        cfg,
        None
    )
    .is_ok());
}
