//! Steady-state zero-allocation guard for the compiled-plan executor.
//!
//! A counting global allocator wraps `System`; after one warm-up call,
//! repeated `infer` calls over the preallocated workspace must perform
//! **zero** heap allocations (sequential path — the parallel path boxes
//! one pool job per helper per dispatch, and is covered by the
//! buffer-pointer-stability test in `test_plan.rs` instead). Both
//! dataflows are pinned: the mixed-domain model (residual add forces
//! f32 edges) and an integer-resident chain where activations flow as
//! u8 codes through the fused requantization epilogues. The serving
//! worker loop's batch-packing step (`pack_batch` + infer, the HTTP
//! request path minus the sockets) is held to the same zero, and so is
//! the `.rmsa` mapped-artifact load path: weights whose code planes
//! alias an mmap'd file must run the same steady-state window without
//! copying them out.
//!
//! This file contains exactly one test so no concurrent test can
//! allocate while the steady-state window is being counted.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use rmsmp::coordinator::server::pack_batch;
use rmsmp::gemm::{PackedWeights, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::Executor;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
    schemes: Vec<Scheme>,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias: vec![0.01; w.rows],
        w: Some(w),
        packed,
        sorted,
    }
}

/// The mixed-domain model's manifest, kept as a raw string so the
/// mapped-artifact leg can embed it via `artifact::pack`.
const MODEL_JSON: &str = r#"{
        "model": "alloc", "arch": "resnet", "num_classes": 3,
        "input_shape": [2, 2, 6, 6], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "c1", "kind": "conv", "rows": 4, "cols": 18,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]},
          {"name": "dw", "kind": "conv", "rows": 4, "cols": 9,
           "stride": 1, "pad": 1, "groups": 4, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]},
          {"name": "fc", "kind": "linear", "rows": 3, "cols": 4,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]}
        ],
        "program": [
          {"op": "conv", "layer": "c1", "in": "in0", "out": "b0", "relu": true},
          {"op": "conv", "layer": "dw", "in": "b0", "out": "b1", "relu": false},
          {"op": "add", "a": "b0", "b": "b1", "out": "b2", "relu": true},
          {"op": "gap", "in": "b2", "out": "g0"},
          {"op": "linear", "layer": "fc", "in": "g0", "out": "logits"}
        ]
      }"#;

/// Every op kind in one model: conv → depthwise conv → residual add →
/// gap → linear.
fn model() -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(&Json::parse(MODEL_JSON).unwrap()).unwrap();

    let mut rng = Rng::new(7);
    let schemes4 = vec![
        Scheme::PotW4A4,
        Scheme::FixedW4A4,
        Scheme::FixedW8A4,
        Scheme::ApotW4A4,
    ];
    let layers = vec![
        layer(
            "c1",
            "conv",
            Mat::from_vec(4, 18, rng.normal_vec(4 * 18, 0.5)),
            (4, 2, 3, 3),
            1,
            1,
            1,
            schemes4.clone(),
        ),
        layer(
            "dw",
            "conv",
            Mat::from_vec(4, 9, rng.normal_vec(4 * 9, 0.5)),
            (4, 4, 3, 3),
            1,
            1,
            4,
            schemes4,
        ),
        layer(
            "fc",
            "linear",
            Mat::from_vec(3, 4, rng.normal_vec(12, 0.5)),
            (3, 4, 1, 1),
            0,
            0,
            1,
            vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4],
        ),
    ];
    (manifest, ModelWeights { layers })
}

/// Integer-resident chain: every inter-layer edge up to the gap carries
/// u8 codes (c1 → depthwise dw → c2 consume/produce codes via the fused
/// epilogues; c2 → gap falls back to f32).
fn integer_chain_model() -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "alloc-int", "arch": "resnet", "num_classes": 3,
        "input_shape": [2, 2, 6, 6], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "c1", "kind": "conv", "rows": 4, "cols": 18,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]},
          {"name": "dw", "kind": "conv", "rows": 4, "cols": 9,
           "stride": 1, "pad": 1, "groups": 4, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]},
          {"name": "c2", "kind": "conv", "rows": 4, "cols": 36,
           "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]},
          {"name": "fc", "kind": "linear", "rows": 3, "cols": 4,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [0, 0, 0, 0]}
        ],
        "program": [
          {"op": "conv", "layer": "c1", "in": "in0", "out": "b0", "relu": true},
          {"op": "conv", "layer": "dw", "in": "b0", "out": "b1", "relu": false},
          {"op": "conv", "layer": "c2", "in": "b1", "out": "b2", "relu": true},
          {"op": "gap", "in": "b2", "out": "g0"},
          {"op": "linear", "layer": "fc", "in": "g0", "out": "logits"}
        ]
      }"#,
        )
        .unwrap(),
    )
    .unwrap();

    let mut rng = Rng::new(13);
    let schemes4 = vec![
        Scheme::PotW4A4,
        Scheme::FixedW4A4,
        Scheme::FixedW8A4,
        Scheme::ApotW4A4,
    ];
    let layers = vec![
        layer(
            "c1",
            "conv",
            Mat::from_vec(4, 18, rng.normal_vec(4 * 18, 0.5)),
            (4, 2, 3, 3),
            1,
            1,
            1,
            schemes4.clone(),
        ),
        layer(
            "dw",
            "conv",
            Mat::from_vec(4, 9, rng.normal_vec(4 * 9, 0.5)),
            (4, 4, 3, 3),
            1,
            1,
            4,
            schemes4.clone(),
        ),
        layer(
            "c2",
            "conv",
            Mat::from_vec(4, 36, rng.normal_vec(4 * 36, 0.5)),
            (4, 4, 3, 3),
            1,
            1,
            1,
            schemes4,
        ),
        layer(
            "fc",
            "linear",
            Mat::from_vec(3, 4, rng.normal_vec(12, 0.5)),
            (3, 4, 1, 1),
            0,
            0,
            1,
            vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4],
        ),
    ];
    (manifest, ModelWeights { layers })
}

fn assert_zero_alloc_steady_state(label: &str, manifest: Manifest, weights: ModelWeights) {
    let mut exec = Executor::new(manifest, weights).unwrap();
    let mut rng = Rng::new(9);
    let mut x = Tensor4::zeros(2, 2, 6, 6);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.0);
    }

    // warm-up: first call may touch the allocator (it should not, given
    // the plan-sized preallocation, but that is pinned by the assert on
    // the steady-state window below, not here)
    let warm = exec.infer(&x).unwrap().clone();

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..5 {
        let y = exec.infer(&x).unwrap();
        assert_eq!(y.data, warm.data);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "{label}: steady-state infer touched the allocator {} times",
        after - before
    );
}

#[test]
fn steady_state_infer_performs_zero_allocations() {
    // mixed-domain model: the residual add keeps b0/b1 in f32
    let (manifest, weights) = model();
    assert_zero_alloc_steady_state("mixed-domain", manifest, weights);

    // serving worker loop: the HTTP path packs request payloads into one
    // reused tensor before infer (coordinator::server::pack_batch); at
    // steady state pack + infer together must stay off the allocator, so
    // the zero-allocation contract extends to the socket request path
    {
        let (manifest, weights) = model();
        let mut exec = Executor::new(manifest, weights).unwrap();
        let mut rng = Rng::new(11);
        let payloads: Vec<Vec<f32>> =
            (0..2).map(|_| (0..72).map(|_| rng.uniform(0.0, 1.0)).collect()).collect();
        let mut x = Tensor4::zeros(0, 2, 6, 6);
        // warm-up grows the tensor to the batch high-water once
        pack_batch(&mut x, (2, 6, 6), 2, payloads.iter().map(|p| p.as_slice()));
        let warm = exec.infer(&x).unwrap().clone();
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..5 {
            pack_batch(&mut x, (2, 6, 6), 2, payloads.iter().map(|p| p.as_slice()));
            let y = exec.infer(&x).unwrap();
            assert_eq!(y.data, warm.data);
        }
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(
            after - before,
            0,
            "worker-loop pack+infer touched the allocator {} times",
            after - before
        );
    }

    // mapped-artifact path: the same mixed-domain model packed into a
    // `.rmsa` file and loaded back with its code planes aliasing the
    // mapped bytes — the zero-allocation contract must hold with the
    // weights resident in the page cache, not the heap
    {
        let (_, weights) = model();
        let path = std::env::temp_dir().join(format!("rmsmp-alloc-{}.rmsa", std::process::id()));
        rmsmp::model::artifact::pack_to_file(MODEL_JSON, &weights, &path).unwrap();
        let (manifest, mapped) = rmsmp::model::artifact::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(mapped.layers.iter().all(|l| l.w.is_none()));
        assert_zero_alloc_steady_state("mapped-artifact", manifest, mapped);
    }

    // integer-resident chain: u8 codes flow through the fused epilogues
    let (manifest, weights) = integer_chain_model();
    {
        // sanity: the chain really compiles to an integer-resident path
        let exec = Executor::new(manifest.clone(), weights.clone()).unwrap();
        let codes_slots = exec
            .plan()
            .slots
            .iter()
            .filter(|s| s.holds_codes && !s.holds_f32)
            .count();
        assert!(codes_slots >= 2, "expected b0/b1 integer-resident, got {codes_slots}");
        // ...and that its non-grouped convs run the implicit-GEMM panel
        // path, so the zero-allocation window below pins the implicit
        // packer (per-lane panel reuse included), not just the explicit
        // staging buffers
        let implicit_convs = exec
            .plan()
            .ops
            .iter()
            .filter(|op| {
                matches!(op, rmsmp::model::PlanOp::Conv { implicit: true, .. })
            })
            .count();
        assert!(implicit_convs >= 2, "expected implicit convs, got {implicit_convs}");
    }
    assert_zero_alloc_steady_state("integer-resident", manifest, weights);
}
