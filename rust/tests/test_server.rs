//! Coordinator end-to-end: dynamic batching server over the real artifacts
//! (integer executor backend), failure/backpressure behaviour, and the
//! HTTP/1.1 front-end over real loopback sockets (synthetic in-memory
//! model, so the socket tests always run).

use std::path::PathBuf;
use std::time::Duration;

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{
    HttpConfig, HttpServer, OpenLoopGen, Router, Server, ServerConfig, SimpleClient, SubmitError,
};
use rmsmp::gemm::{PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::weights::LayerWeights;
use rmsmp::model::{Executor, Manifest, ModelWeights};
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = rmsmp::runtime::artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

fn load() -> Option<(Manifest, ModelWeights)> {
    let dir = artifacts()?;
    Some((
        Manifest::load(&dir.join("manifest.json")).unwrap(),
        ModelWeights::load(&dir.join("weights.bin")).unwrap(),
    ))
}

macro_rules! require {
    () => {
        match load() {
            Some(x) => x,
            None => {
                eprintln!("skipping: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn serves_requests_and_batches() {
    let (m, w) = require!();
    let num_classes = m.num_classes;
    let server = Server::start(
        m,
        w,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(5),
                queue_cap: 64,
            },
            parallel: ParallelConfig::sequential(),
        },
    )
    .unwrap();

    let mut gen = OpenLoopGen::new(3, 1000.0, server.input_len());
    let n = 12;
    let mut rxs = Vec::new();
    for _ in 0..n {
        rxs.push(server.submit(gen.next_event().image).unwrap());
    }
    let mut seen = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.logits.len(), num_classes);
        assert!(resp.total_ms >= 0.0);
        assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
        seen += 1;
    }
    assert_eq!(seen, n);
    assert_eq!(
        server.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
    // batching actually happened (12 requests at 1000 rps into batch=4)
    assert!(server.metrics.mean_batch_size() > 1.0);
    server.shutdown();
}

#[test]
fn identical_inputs_get_identical_logits() {
    let (m, w) = require!();
    let server = Server::start(m, w, ServerConfig::default()).unwrap();
    let img: Vec<f32> = (0..server.input_len())
        .map(|i| (i % 17) as f32 / 17.0)
        .collect();
    let a = server.infer(img.clone()).unwrap();
    let b = server.infer(img).unwrap();
    assert_eq!(a.logits, b.logits, "determinism across batches");
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_full() {
    let (m, w) = require!();
    let server = Server::start(
        m,
        w,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(50),
                queue_cap: 2,
            },
            parallel: ParallelConfig::sequential(),
        },
    )
    .unwrap();
    let img = vec![0.5f32; server.input_len()];
    // flood faster than the worker drains
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for _ in 0..64 {
        match server.submit(img.clone()) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
    for rx in rxs {
        let _ = rx.recv_timeout(Duration::from_secs(120));
    }
    assert_eq!(
        server.metrics.rejected.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    server.shutdown();
}

#[test]
fn multi_worker_consistency() {
    let (m, w) = require!();
    let server = Server::start(
        m,
        w,
        ServerConfig {
            workers: 2,
            policy: BatchPolicy {
                max_batch: 1, // force per-request batches across workers
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
            },
            parallel: ParallelConfig { threads: 2, ..ParallelConfig::default() },
        },
    )
    .unwrap();
    let img: Vec<f32> = (0..server.input_len())
        .map(|i| ((i * 7) % 23) as f32 / 23.0)
        .collect();
    let first = server.infer(img.clone()).unwrap().logits;
    let rxs: Vec<_> = (0..6)
        .map(|_| server.submit(img.clone()).unwrap())
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(r.logits, first, "workers disagree");
    }
    server.shutdown();
}

// --- HTTP front-end over real sockets (synthetic model, always runs) -------

/// Tiny gap→linear model: input (2, 4, 4) → 3 classes, mixed row schemes.
fn tiny(seed: u64) -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "tiny", "arch": "resnet", "num_classes": 3,
        "input_shape": [1, 2, 4, 4], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "fc", "kind": "linear", "rows": 3, "cols": 2,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [1, 1, 1, 0]}
        ],
        "program": [
          {"op": "gap", "in": "in0", "out": "b0"},
          {"op": "linear", "layer": "fc", "in": "b0", "out": "logits"}
        ]
      }"#,
        )
        .unwrap(),
    )
    .unwrap();
    let schemes = vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];
    let mut rng = Rng::new(seed);
    let w = Mat::from_vec(3, 2, rng.normal_vec(6, 0.5));
    let alpha: Vec<f32> = (0..3).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    let weights = ModelWeights {
        layers: vec![LayerWeights {
            name: "fc".into(),
            kind: "linear".into(),
            rows: 3,
            cols: 2,
            out_ch: 3,
            in_ch: 2,
            kh: 1,
            kw: 1,
            stride: 0,
            pad: 0,
            groups: 1,
            a_alpha: 1.0,
            scheme: schemes,
            alpha,
            bias: vec![0.0; 3],
            w: Some(w),
            packed,
            sorted,
        }],
    };
    (manifest, weights)
}

fn boot_http(policy: BatchPolicy, conn_threads: usize, max_body: usize) -> (HttpServer, String) {
    let (m, w) = tiny(1);
    let server = Server::start(
        m,
        w,
        ServerConfig { workers: 1, policy, parallel: ParallelConfig::sequential() },
    )
    .unwrap();
    let http = HttpServer::start(
        server,
        HttpConfig { conn_threads, max_body_bytes: max_body, ..HttpConfig::default() },
    )
    .unwrap();
    let addr = http.addr().to_string();
    (http, addr)
}

fn quick_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2), queue_cap: 256 }
}

fn body_for(img: &[f32], extra: &str) -> String {
    use std::fmt::Write as _;
    let mut body = String::from("{");
    body.push_str(extra);
    body.push_str("\"input\":[");
    for (i, v) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{v}");
    }
    body.push_str("]}");
    body
}

#[test]
fn http_concurrent_clients_get_bit_identical_logits() {
    let (http, addr) = boot_http(quick_policy(), 8, 1 << 20);

    // reference logits straight from the executor, same weights (seed 1)
    let (m, w) = tiny(1);
    let mut exec = Executor::new(m, w).unwrap();
    let inputs: Vec<Vec<f32>> = (0..4)
        .map(|k| (0..32).map(|i| ((i * 7 + k * 3) % 19) as f32 / 19.0).collect())
        .collect();
    let mut want = Vec::new();
    for img in &inputs {
        let mut x = rmsmp::quant::tensor::Tensor4::zeros(1, 2, 4, 4);
        x.data.copy_from_slice(img);
        want.push(exec.infer(&x).unwrap().row(0).to_vec());
    }

    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(k, img)| {
            let addr = addr.clone();
            let body = body_for(img, "");
            std::thread::spawn(move || {
                let mut c = SimpleClient::connect(&addr).unwrap();
                let mut out = Vec::new();
                for _ in 0..3 {
                    let resp = c.request("POST", "/v1/infer", &body).unwrap();
                    assert_eq!(resp.status, 200, "{}", resp.body);
                    let j = Json::parse(&resp.body).unwrap();
                    out.push(j.get("logits").unwrap().as_f32_vec().unwrap());
                }
                (k, out)
            })
        })
        .collect();
    for h in handles {
        let (k, got) = h.join().unwrap();
        for logits in got {
            // f32 Display roundtrips exactly through the JSON response
            assert_eq!(logits, want[k], "client {k} logits drifted over HTTP");
        }
    }
    http.shutdown();
}

#[test]
fn http_rejects_bad_requests_without_worker_death() {
    let (http, addr) = boot_http(quick_policy(), 4, 4096);

    // malformed JSON → 400 (keep-alive preserved: app-level error)
    let mut c = SimpleClient::connect(&addr).unwrap();
    let resp = c.request("POST", "/v1/infer", "{not json").unwrap();
    assert_eq!(resp.status, 400);

    // wrong input length → 400 from SubmitError::Invalid, same connection
    let resp = c.request("POST", "/v1/infer", "{\"input\":[1,2,3]}").unwrap();
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("input length"), "{}", resp.body);

    // unknown model → 404
    let img = vec![0.5f32; 32];
    let resp = c.request("POST", "/v1/infer", &body_for(&img, "\"model\":\"nope\",")).unwrap();
    assert_eq!(resp.status, 404);

    // unknown route → 404; wrong method on a real route → 405
    let resp = c.request("GET", "/nope", "").unwrap();
    assert_eq!(resp.status, 404);
    let resp = c.request("GET", "/v1/infer", "").unwrap();
    assert_eq!(resp.status, 405);

    // POST without Content-Length → 411
    let resp = c
        .send_raw(b"POST /v1/infer HTTP/1.1\r\nHost: x\r\n\r\n")
        .unwrap();
    assert_eq!(resp.status, 411);

    // oversized body → 413 (connection closes: body was never read)
    let mut c2 = SimpleClient::connect(&addr).unwrap();
    let resp = c2
        .send_raw(b"POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 999999\r\n\r\n")
        .unwrap();
    assert_eq!(resp.status, 413);

    // after all of that, a valid request still succeeds: no worker died
    let resp = c.request("POST", "/v1/infer", &body_for(&img, "")).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    http.shutdown();
}

#[test]
fn http_keep_alive_reuses_one_connection() {
    let (http, addr) = boot_http(quick_policy(), 2, 1 << 20);
    let img = vec![0.25f32; 32];
    let body = body_for(&img, "");
    let mut c = SimpleClient::connect(&addr).unwrap();
    for _ in 0..3 {
        // a second/third request on the same socket only works if the
        // server honoured keep-alive after the first response
        let resp = c.request("POST", "/v1/infer", &body).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(resp.header("Connection"), Some("keep-alive"));
    }
    http.shutdown();
}

#[test]
fn http_expired_deadline_returns_shed_response() {
    let (http, addr) = boot_http(quick_policy(), 2, 1 << 20);
    let img = vec![0.5f32; 32];
    // deadline_ms 0: already expired at submit, so the batcher must shed
    // it before the GEMM and the front-end answers 504
    let mut c = SimpleClient::connect(&addr).unwrap();
    let resp = c
        .request("POST", "/v1/infer", &body_for(&img, "\"deadline_ms\":0,"))
        .unwrap();
    assert_eq!(resp.status, 504, "{}", resp.body);
    assert!(resp.body.contains("shed"), "{}", resp.body);

    let metrics = c.request("GET", "/metrics", "").unwrap();
    assert_eq!(metrics.status, 200);
    assert!(
        metrics.body.contains("rmsmp_shed_total{model=\"tiny\"} 1"),
        "{}",
        metrics.body
    );
    http.shutdown();
}

#[test]
fn http_backpressure_maps_to_429_with_retry_after() {
    // queue_cap 2 and a 30ms dispatch delay: 32 near-simultaneous clients
    // can't all fit — the surplus must see 429 + Retry-After
    let policy = BatchPolicy {
        max_batch: 64,
        max_wait: Duration::from_millis(30),
        queue_cap: 2,
    };
    let (http, addr) = boot_http(policy, 32, 1 << 20);
    let img = vec![0.5f32; 32];
    let body = body_for(&img, "");
    let handles: Vec<_> = (0..32)
        .map(|_| {
            let addr = addr.clone();
            let body = body.clone();
            std::thread::spawn(move || {
                let mut c = SimpleClient::connect(&addr).unwrap();
                let resp = c.request("POST", "/v1/infer", &body).unwrap();
                let retry = resp.header("Retry-After").map(|s| s.to_string());
                (resp.status, retry)
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let oks = results.iter().filter(|(s, _)| *s == 200).count();
    let rejected: Vec<_> = results.iter().filter(|(s, _)| *s == 429).collect();
    assert_eq!(oks + rejected.len(), 32, "unexpected statuses: {results:?}");
    assert!(oks >= 1, "someone must get through");
    assert!(!rejected.is_empty(), "queue_cap 2 must reject some of 32 clients");
    for (_, retry) in &rejected {
        assert!(retry.is_some(), "429 must carry Retry-After");
    }
    http.shutdown();
}

#[test]
fn http_metrics_exposes_per_stage_timers() {
    let (http, addr) = boot_http(quick_policy(), 2, 1 << 20);
    let img = vec![0.75f32; 32];
    let mut c = SimpleClient::connect(&addr).unwrap();
    let resp = c.request("POST", "/v1/infer", &body_for(&img, "")).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);

    let resp = c.request("GET", "/metrics", "").unwrap();
    assert_eq!(resp.status, 200);
    for needle in [
        "rmsmp_requests_total{model=\"tiny\"} 1",
        "rmsmp_responses_total{model=\"tiny\"} 1",
        "rmsmp_latency_ms{model=\"tiny\",quantile=\"0.5\"}",
        "rmsmp_stage_seconds_total{model=\"tiny\",stage=\"gemm\"}",
        "rmsmp_stage_seconds_total{model=\"tiny\",stage=\"epilogue\"}",
    ] {
        assert!(resp.body.contains(needle), "missing {needle} in:\n{}", resp.body);
    }
    let resp = c.request("GET", "/healthz", "").unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body, "ok\n");
    http.shutdown();
}

/// A `tiny`-shaped model under a caller-chosen name, returned with its
/// manifest JSON so the multi-model test can pack it into a `.rmsa`.
fn tiny_named(name: &str, seed: u64) -> (String, ModelWeights) {
    let json = format!(
        r#"{{
        "model": "{name}", "arch": "resnet", "num_classes": 3,
        "input_shape": [1, 2, 4, 4], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {{"name": "fc", "kind": "linear", "rows": 3, "cols": 2,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [1, 1, 1, 0]}}
        ],
        "program": [
          {{"op": "gap", "in": "in0", "out": "b0"}},
          {{"op": "linear", "layer": "fc", "in": "b0", "out": "logits"}}
        ]
      }}"#
    );
    let (_, weights) = tiny(seed);
    (json, weights)
}

/// Multi-model resident serving end to end: two differently named models
/// packed into `.rmsa` artifacts, loaded back (mapped planes), booted
/// under one Router sharing a thread pool, and served over real sockets.
/// Requests route on the `model` field, each model keeps its own
/// `/metrics` labels, and an unknown model maps to 404.
#[test]
fn http_serves_two_resident_rmsa_models() {
    use rmsmp::model::artifact;

    let tmp = std::env::temp_dir();
    let mut models = Vec::new();
    for (name, seed) in [("alpha", 1u64), ("beta", 2)] {
        let (json, weights) = tiny_named(name, seed);
        let path = tmp.join(format!("rmsmp-serve-{name}-{}.rmsa", std::process::id()));
        artifact::pack_to_file(&json, &weights, &path).unwrap();
        let (m, w) = artifact::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(m.model, name);
        models.push((
            m.model.clone(),
            m,
            w,
            ServerConfig { workers: 1, policy: quick_policy(), parallel: ParallelConfig::sequential() },
        ));
    }
    let router = Router::start(models).unwrap();
    let http = HttpServer::start_router(
        router,
        HttpConfig { conn_threads: 4, ..HttpConfig::default() },
    )
    .unwrap();
    let addr = http.addr().to_string();

    // per-model reference logits straight from legacy (unpacked) weights
    let img: Vec<f32> = (0..32).map(|i| ((i * 5) % 13) as f32 / 13.0).collect();
    let mut want = std::collections::BTreeMap::new();
    for (name, seed) in [("alpha", 1u64), ("beta", 2)] {
        let (m, w) = tiny(seed);
        let mut exec = Executor::new(m, w).unwrap();
        let mut x = rmsmp::quant::tensor::Tensor4::zeros(1, 2, 4, 4);
        x.data.copy_from_slice(&img);
        want.insert(name, exec.infer(&x).unwrap().row(0).to_vec());
    }
    assert_ne!(want["alpha"], want["beta"], "seeds must give distinct models");

    let mut c = SimpleClient::connect(&addr).unwrap();
    for name in ["alpha", "beta"] {
        let body = body_for(&img, &format!("\"model\":\"{name}\","));
        let resp = c.request("POST", "/v1/infer", &body).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let j = Json::parse(&resp.body).unwrap();
        let got = j.get("logits").unwrap().as_f32_vec().unwrap();
        assert_eq!(got, want[name], "model {name} served wrong logits");
    }

    // no model field -> the first registered variant (alpha) answers
    let resp = c.request("POST", "/v1/infer", &body_for(&img, "")).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = Json::parse(&resp.body).unwrap();
    assert_eq!(j.get("logits").unwrap().as_f32_vec().unwrap(), want["alpha"]);

    // unknown model -> 404, connection stays usable
    let resp = c
        .request("POST", "/v1/infer", &body_for(&img, "\"model\":\"gamma\","))
        .unwrap();
    assert_eq!(resp.status, 404, "{}", resp.body);

    // per-model metrics: each variant counts its own traffic
    let metrics = c.request("GET", "/metrics", "").unwrap();
    assert_eq!(metrics.status, 200);
    for needle in [
        "rmsmp_requests_total{model=\"alpha\"} 2",
        "rmsmp_requests_total{model=\"beta\"} 1",
    ] {
        assert!(metrics.body.contains(needle), "missing {needle} in:\n{}", metrics.body);
    }
    http.shutdown();
}

#[test]
fn submit_error_granularity_at_the_library_level() {
    let (m, w) = tiny(1);
    let server = Server::start(
        m,
        w,
        ServerConfig { workers: 1, policy: quick_policy(), parallel: ParallelConfig::sequential() },
    )
    .unwrap();
    // wrong input length is a validation error, not backpressure
    match server.submit(vec![0.0; 3]) {
        Err(SubmitError::Invalid(msg)) => assert!(msg.contains("input length"), "{msg}"),
        other => panic!("want Invalid, got {other:?}"),
    }
    server.shutdown();
}
