//! Coordinator end-to-end: dynamic batching server over the real artifacts
//! (integer executor backend), plus failure/backpressure behaviour.

use std::path::PathBuf;
use std::time::Duration;

use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{OpenLoopGen, Server, ServerConfig};
use rmsmp::gemm::ParallelConfig;
use rmsmp::model::{Manifest, ModelWeights};

fn artifacts() -> Option<PathBuf> {
    let dir = rmsmp::runtime::artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

fn load() -> Option<(Manifest, ModelWeights)> {
    let dir = artifacts()?;
    Some((
        Manifest::load(&dir.join("manifest.json")).unwrap(),
        ModelWeights::load(&dir.join("weights.bin")).unwrap(),
    ))
}

macro_rules! require {
    () => {
        match load() {
            Some(x) => x,
            None => {
                eprintln!("skipping: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn serves_requests_and_batches() {
    let (m, w) = require!();
    let num_classes = m.num_classes;
    let server = Server::start(
        m,
        w,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(5),
                queue_cap: 64,
            },
            parallel: ParallelConfig::sequential(),
        },
    )
    .unwrap();

    let mut gen = OpenLoopGen::new(3, 1000.0, server.input_len());
    let n = 12;
    let mut rxs = Vec::new();
    for _ in 0..n {
        rxs.push(server.submit(gen.next_event().image).unwrap());
    }
    let mut seen = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.logits.len(), num_classes);
        assert!(resp.total_ms >= 0.0);
        assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
        seen += 1;
    }
    assert_eq!(seen, n);
    assert_eq!(
        server.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
        n as u64
    );
    // batching actually happened (12 requests at 1000 rps into batch=4)
    assert!(server.metrics.mean_batch_size() > 1.0);
    server.shutdown();
}

#[test]
fn identical_inputs_get_identical_logits() {
    let (m, w) = require!();
    let server = Server::start(m, w, ServerConfig::default()).unwrap();
    let img: Vec<f32> = (0..server.input_len())
        .map(|i| (i % 17) as f32 / 17.0)
        .collect();
    let a = server.infer(img.clone()).unwrap();
    let b = server.infer(img).unwrap();
    assert_eq!(a.logits, b.logits, "determinism across batches");
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_full() {
    let (m, w) = require!();
    let server = Server::start(
        m,
        w,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(50),
                queue_cap: 2,
            },
            parallel: ParallelConfig::sequential(),
        },
    )
    .unwrap();
    let img = vec![0.5f32; server.input_len()];
    // flood faster than the worker drains
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for _ in 0..64 {
        match server.submit(img.clone()) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
    for rx in rxs {
        let _ = rx.recv_timeout(Duration::from_secs(120));
    }
    assert_eq!(
        server.metrics.rejected.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    server.shutdown();
}

#[test]
fn multi_worker_consistency() {
    let (m, w) = require!();
    let server = Server::start(
        m,
        w,
        ServerConfig {
            workers: 2,
            policy: BatchPolicy {
                max_batch: 1, // force per-request batches across workers
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
            },
            parallel: ParallelConfig { threads: 2, ..ParallelConfig::default() },
        },
    )
    .unwrap();
    let img: Vec<f32> = (0..server.input_len())
        .map(|i| ((i * 7) % 23) as f32 / 23.0)
        .collect();
    let first = server.infer(img.clone()).unwrap().logits;
    let rxs: Vec<_> = (0..6)
        .map(|_| server.submit(img.clone()).unwrap())
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(r.logits, first, "workers disagree");
    }
    server.shutdown();
}
