//! Full-stack integration over the AOT artifacts: manifest/weights loading,
//! integer executor vs recorded JAX logits, and layer-wise uniformality of
//! the shipped assignment. (HLO-artifact parity via PJRT moved to the
//! Python side with the zero-dependency build — `python -m compile.aot`.)
//!
//! Skipped with a notice when `artifacts/` is missing.

use std::path::PathBuf;

use rmsmp::assign::validate_ratio;
use rmsmp::model::{Executor, Manifest, ModelWeights};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::util::json::Json;

fn artifacts() -> Option<PathBuf> {
    let dir = rmsmp::runtime::artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_and_weights_agree() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    assert_eq!(m.layers.len(), w.layers.len());
    for (lm, lw) in m.layers.iter().zip(&w.layers) {
        assert_eq!(lm.name, lw.name);
        assert_eq!(lm.rows, lw.rows);
        assert_eq!(lm.cols, lw.cols);
        assert_eq!(lm.kind, lw.kind);
        // manifest scheme counts match the packed schemes
        for (i, count) in lm.scheme_counts.iter().enumerate() {
            let got = lw.scheme.iter().filter(|&&s| s as usize == i).count();
            assert_eq!(got, *count, "layer {} scheme {i}", lm.name);
        }
    }
}

#[test]
fn shipped_assignment_is_layerwise_uniform() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    for lw in &w.layers {
        validate_ratio(&lw.scheme, m.ratio)
            .unwrap_or_else(|e| panic!("layer {}: {e}", lw.name));
    }
}

#[test]
fn integer_executor_matches_recorded_jax_logits() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    let parity = Json::load(&dir.join("parity.json")).unwrap();
    let input = parity.get("input").unwrap().as_f32_vec().unwrap();
    let shape = parity.get("input_shape").unwrap().as_usize_vec().unwrap();
    let want = parity.get("logits").unwrap().as_f32_vec().unwrap();

    let mut exec = Executor::new(m, w).unwrap();
    let mut x = Tensor4::zeros(shape[0], shape[1], shape[2], shape[3]);
    x.data.copy_from_slice(&input);
    let got = exec.infer(&x).unwrap();
    let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let err = got
        .data
        .iter()
        .zip(&want)
        .fold(0.0f32, |e, (a, b)| e.max((a - b).abs()));
    assert!(err / scale < 1e-4, "integer executor err {err} (scale {scale})");
    assert!(exec.macs > 0);
}

#[test]
fn parallel_executor_matches_sequential_on_artifacts() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    let parity = Json::load(&dir.join("parity.json")).unwrap();
    let input = parity.get("input").unwrap().as_f32_vec().unwrap();
    let shape = parity.get("input_shape").unwrap().as_usize_vec().unwrap();

    let rt = rmsmp::runtime::Runtime::new(rmsmp::ParallelConfig {
        threads: 4,
        ..rmsmp::ParallelConfig::default()
    });
    let mut seq = Executor::new(m.clone(), w.clone()).unwrap();
    let mut par = rt.executor(m, w).unwrap();
    let mut x = Tensor4::zeros(shape[0], shape[1], shape[2], shape[3]);
    x.data.copy_from_slice(&input);
    let a = seq.infer(&x).unwrap();
    let b = par.infer(&x).unwrap();
    assert_eq!(a.data, b.data, "parallel executor diverged on real model");
}
