//! Full-stack integration over the AOT artifacts: manifest/weights loading,
//! integer executor vs recorded JAX logits, HLO artifact execution via
//! PJRT, layer-wise uniformality of the shipped assignment, and the
//! standalone Pallas GEMM artifact vs the Rust cores.
//!
//! Skipped with a notice when `artifacts/` is missing.

use std::path::PathBuf;

use rmsmp::assign::validate_ratio;
use rmsmp::gemm::{MixedGemm, PackedActs, PackedWeights};
use rmsmp::model::{Executor, Manifest, ModelWeights};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{Mat, Scheme};
use rmsmp::runtime::{ArtifactInput, Runtime};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = rmsmp::runtime::artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_and_weights_agree() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    assert_eq!(m.layers.len(), w.layers.len());
    for (lm, lw) in m.layers.iter().zip(&w.layers) {
        assert_eq!(lm.name, lw.name);
        assert_eq!(lm.rows, lw.rows);
        assert_eq!(lm.cols, lw.cols);
        assert_eq!(lm.kind, lw.kind);
        // manifest scheme counts match the packed schemes
        for (i, count) in lm.scheme_counts.iter().enumerate() {
            let got = lw
                .scheme
                .iter()
                .filter(|&&s| s as usize == i)
                .count();
            assert_eq!(got, *count, "layer {} scheme {i}", lm.name);
        }
    }
}

#[test]
fn shipped_assignment_is_layerwise_uniform() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    for lw in &w.layers {
        validate_ratio(&lw.scheme, m.ratio)
            .unwrap_or_else(|e| panic!("layer {}: {e}", lw.name));
    }
}

#[test]
fn integer_executor_matches_recorded_jax_logits() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let w = ModelWeights::load(&dir.join("weights.bin")).unwrap();
    let parity = Json::load(&dir.join("parity.json")).unwrap();
    let input = parity.get("input").unwrap().as_f32_vec().unwrap();
    let shape = parity.get("input_shape").unwrap().as_usize_vec().unwrap();
    let want = parity.get("logits").unwrap().as_f32_vec().unwrap();

    let mut exec = Executor::new(m, w).unwrap();
    let mut x = Tensor4::zeros(shape[0], shape[1], shape[2], shape[3]);
    x.data.copy_from_slice(&input);
    let got = exec.infer(x).unwrap();
    let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    let err = got
        .data
        .iter()
        .zip(&want)
        .fold(0.0f32, |e, (a, b)| e.max((a - b).abs()));
    assert!(err / scale < 1e-4, "integer executor err {err} (scale {scale})");
    assert!(exec.macs > 0);
}

#[test]
fn hlo_artifact_matches_recorded_jax_logits() {
    let dir = require_artifacts!();
    let parity = Json::load(&dir.join("parity.json")).unwrap();
    let input = parity.get("input").unwrap().as_f32_vec().unwrap();
    let shape = parity.get("input_shape").unwrap().as_usize_vec().unwrap();
    let want = parity.get("logits").unwrap().as_f32_vec().unwrap();

    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&dir.join("model.hlo.txt")).unwrap();
    let out = exe.run_f32(&[(&input, &shape)]).unwrap();
    let err = out
        .iter()
        .zip(&want)
        .fold(0.0f32, |e, (a, b)| e.max((a - b).abs()));
    assert!(err < 1e-3, "hlo artifact err {err}");
}

#[test]
fn pallas_gemm_artifact_matches_rust_cores() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir.join("manifest.json")).unwrap();
    let Some((batch, rows, cols)) = m.gemm_shape else {
        eprintln!("skipping: manifest has no gemm_shape");
        return;
    };
    let mut rng = Rng::new(11);
    let x = Mat::from_vec(batch, cols, (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect());
    let w = Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() * 0.4).collect());
    let alpha: Vec<f32> = (0..rows)
        .map(|r| rmsmp::quant::default_alpha(w.row(r)))
        .collect();
    let schemes: Vec<Scheme> = (0..rows)
        .map(|r| Scheme::from_code((r % 3) as u8).unwrap())
        .collect();
    let scheme_codes: Vec<i32> = schemes.iter().map(|&s| s as i32).collect();

    // run the Pallas-lowered HLO artifact
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&dir.join("gemm.hlo.txt")).unwrap();
    let out = exe
        .run_mixed(&[
            ArtifactInput::F32(&x.data, &[batch, cols]),
            ArtifactInput::F32(&w.data, &[rows, cols]),
            ArtifactInput::F32(&alpha, &[rows]),
            ArtifactInput::I32(&scheme_codes, &[rows]),
        ])
        .unwrap();

    // vs the Rust integer cores (act_alpha = 1.0, matching aot.py)
    let g = MixedGemm::new();
    let acts = PackedActs::quantize(&x, 1.0, 4);
    let pw = PackedWeights::quantize(&w, &schemes, &alpha);
    let int_out = g.run(&acts, &pw);
    assert_eq!(out.len(), int_out.data.len());
    let scale = int_out.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
    let err = out
        .iter()
        .zip(&int_out.data)
        .fold(0.0f32, |e, (a, b)| e.max((a - b).abs()));
    assert!(err / scale < 1e-3, "pallas artifact vs rust cores err {err}");
}
