//! End-to-end `.rmsa` artifact contract.
//!
//! Two guarantees, both load-bearing for deployment:
//!
//! * **Bit-identical logits** — a model loaded from a packed artifact
//!   (code planes aliasing the mapped file) must produce exactly the
//!   same logits as the same model built in memory from float weights,
//!   across batch {1, 8} x threads {1, 8} x {scalar, native} ISA. Not
//!   "close": the artifact stores the exact quantized planes, so any
//!   difference is a format bug.
//! * **No undefined behavior on corrupt input** — an artifact with any
//!   single bit flipped, or truncated at any offset, must fail loading
//!   with a typed error. Property-tested at random offsets.

use std::path::PathBuf;

use rmsmp::gemm::{PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{artifact, Manifest};
use rmsmp::prop_assert;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::runtime::Runtime;
use rmsmp::util::json::Json;
use rmsmp::util::prop::check;
use rmsmp::util::rng::Rng;

const MANIFEST_JSON: &str = r#"{
    "model": "artifact-test", "arch": "resnet", "num_classes": 3,
    "input_shape": [8, 2, 6, 6], "ratio": [65, 30, 5], "act_bits": 4,
    "layers": [
      {"name": "c1", "kind": "conv", "rows": 4, "cols": 18,
       "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
       "scheme_counts": [1, 1, 1, 1]},
      {"name": "fc", "kind": "linear", "rows": 3, "cols": 4,
       "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
       "scheme_counts": [1, 2, 0, 0]}
    ],
    "program": [
      {"op": "conv", "layer": "c1", "in": "in0", "out": "b0", "relu": true},
      {"op": "gap", "in": "b0", "out": "b1"},
      {"op": "linear", "layer": "fc", "in": "b1", "out": "logits"}
    ]
  }"#;

fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    schemes: Vec<Scheme>,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups: 1,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias: vec![0.02; w.rows],
        w: Some(w),
        packed,
        sorted,
    }
}

/// conv (all four row schemes, PoT rows included so the artifact carries
/// a pre-decoded multiplier plane) -> gap -> fc.
fn model() -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(&Json::parse(MANIFEST_JSON).unwrap()).unwrap();
    let mut rng = Rng::new(21);
    let layers = vec![
        layer(
            "c1",
            "conv",
            Mat::from_vec(4, 18, rng.normal_vec(4 * 18, 0.5)),
            (4, 2, 3, 3),
            1,
            1,
            vec![
                Scheme::PotW4A4,
                Scheme::FixedW4A4,
                Scheme::FixedW8A4,
                Scheme::ApotW4A4,
            ],
        ),
        layer(
            "fc",
            "linear",
            Mat::from_vec(3, 4, rng.normal_vec(12, 0.5)),
            (3, 4, 1, 1),
            0,
            0,
            vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW4A4],
        ),
    ];
    (manifest, ModelWeights { layers })
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rmsmp-test-{tag}-{}.rmsa", std::process::id()))
}

fn rand_input(n: usize, seed: u64) -> Tensor4 {
    let mut rng = Rng::new(seed);
    let mut x = Tensor4::zeros(n, 2, 6, 6);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.0);
    }
    x
}

/// The headline acceptance criterion: legacy in-memory weights and the
/// mapped artifact agree to the bit over every execution configuration.
/// One test function so the `RMSMP_ISA` override cannot race a
/// concurrently running executor build in this binary.
#[test]
fn artifact_logits_bit_identical_to_legacy() {
    let (manifest, weights) = model();
    let path = tmp_path("parity");
    artifact::pack_to_file(MANIFEST_JSON, &weights, &path).unwrap();
    let (am, aw) = artifact::load(&path).unwrap();
    assert_eq!(am.model, manifest.model);
    assert!(aw.layers.iter().all(|l| l.w.is_none()));

    for isa in [Some("scalar"), None] {
        match isa {
            Some(v) => std::env::set_var("RMSMP_ISA", v),
            None => std::env::remove_var("RMSMP_ISA"),
        }
        for threads in [1usize, 8] {
            let cfg = ParallelConfig { threads, ..ParallelConfig::default() };
            let rt = Runtime::new(cfg);
            let mut legacy = rt.executor(manifest.clone(), weights.clone()).unwrap();
            let (am, aw) = artifact::load(&path).unwrap();
            let mut mapped = rt.executor(am, aw).unwrap();
            for batch in [1usize, 8] {
                let x = rand_input(batch, 31 + batch as u64);
                let want = legacy.infer(&x).unwrap().clone();
                let got = mapped.infer(&x).unwrap();
                let same = want
                    .data
                    .iter()
                    .zip(&got.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(
                    same && want.data.len() == got.data.len(),
                    "logits diverge at isa={isa:?} threads={threads} batch={batch}"
                );
            }
        }
    }
    std::env::remove_var("RMSMP_ISA");
    let _ = std::fs::remove_file(&path);
}

/// Any single bit flip anywhere in the artifact — header fields, layer
/// table, quantized planes, manifest JSON, padding — must turn the load
/// into a clean `Err`, never a wrong model or UB.
#[test]
fn any_single_bit_flip_fails_to_load() {
    let (_, weights) = model();
    let bytes = artifact::pack(MANIFEST_JSON, &weights).unwrap();
    let path = tmp_path("bitflip");
    check("artifact-bit-flip", 64, |g| {
        let bit = g.usize_in(0, bytes.len() * 8 - 1);
        let mut corrupt = bytes.clone();
        corrupt[bit / 8] ^= 1 << (bit % 8);
        std::fs::write(&path, &corrupt).map_err(|e| e.to_string())?;
        let res = artifact::load(&path);
        prop_assert!(
            res.is_err(),
            "flip of bit {} (byte {} of {}) loaded successfully",
            bit,
            bit / 8,
            corrupt.len()
        );
        Ok(())
    });
    let _ = std::fs::remove_file(&path);
}

/// Truncating the artifact at any offset — mid-header, mid-plane, or
/// just shy of the final byte — must fail with a typed error.
#[test]
fn any_truncation_fails_to_load() {
    let (_, weights) = model();
    let bytes = artifact::pack(MANIFEST_JSON, &weights).unwrap();
    let path = tmp_path("truncate");
    check("artifact-truncate", 64, |g| {
        let keep = g.usize_in(0, bytes.len() - 1);
        std::fs::write(&path, &bytes[..keep]).map_err(|e| e.to_string())?;
        let res = artifact::load(&path);
        prop_assert!(res.is_err(), "truncation to {keep} of {} bytes loaded", bytes.len());
        Ok(())
    });
    let _ = std::fs::remove_file(&path);
}

/// Appending trailing garbage must also fail: `file_len` in the header
/// pins the exact byte length, so a concatenated or padded file cannot
/// silently alias the wrong tail.
#[test]
fn trailing_garbage_fails_to_load() {
    let (_, weights) = model();
    let mut bytes = artifact::pack(MANIFEST_JSON, &weights).unwrap();
    bytes.extend_from_slice(&[0xAB; 7]);
    let path = tmp_path("tail");
    std::fs::write(&path, &bytes).unwrap();
    assert!(artifact::load(&path).is_err());
    let _ = std::fs::remove_file(&path);
}
