//! Property-based tests (in-repo `util::prop` framework) over the
//! coordinator-facing invariants: quantizers, assignment, row partitioning,
//! GEMM consistency, batching policy, and the FPGA design allocator.

use rmsmp::assign::{assign_layer, validate_ratio, Sensitivity};
use rmsmp::fpga::{Board, CoreCosts, Design, QuantConfig};
use rmsmp::gemm::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, PackedActs,
    PackedWeights, RowPartition, SortedWeights,
};
use rmsmp::prop_assert;
use rmsmp::quant::{self, Mat, Ratio, Scheme};
use rmsmp::util::prop::{check, Gen};

const ALL_SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

fn gen_ratio(g: &mut Gen) -> Ratio {
    let a = g.usize_in(0, 100) as u32;
    let c = g.usize_in(0, (100 - a as usize).min(20)) as u32;
    Ratio::new(a, 100 - a - c, c)
}

fn gen_mat(g: &mut Gen, max_rows: usize, max_cols: usize) -> Mat {
    let rows = g.usize_in(1, max_rows);
    let cols = g.usize_in(1, max_cols);
    Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.6))
}

#[test]
fn prop_fixed_quant_on_grid_and_bounded() {
    check("fixed-grid", 200, |g| {
        let m = *g.choice(&[2u32, 3, 4, 8]);
        let alpha = g.f32_in(0.05, 4.0);
        let w = g.f32_in(-6.0, 6.0);
        let q = quant::fixed_quant(w, alpha, m);
        prop_assert!(q.abs() <= alpha + 1e-6, "|q|={} > alpha={alpha}", q.abs());
        let n = ((1i64 << (m - 1)) - 1) as f32;
        let steps = q / alpha * n;
        prop_assert!(
            (steps - steps.round()).abs() < 1e-4,
            "off grid: q={q} alpha={alpha} m={m}"
        );
        // idempotent
        prop_assert!((quant::fixed_quant(q, alpha, m) - q).abs() < 1e-6);
        Ok(())
    });
}

#[test]
fn prop_pot_levels_are_powers_of_two() {
    check("pot-grid", 200, |g| {
        let m = *g.choice(&[3u32, 4, 5]);
        let alpha = g.f32_in(0.05, 4.0);
        let w = g.f32_in(-6.0, 6.0);
        let q = quant::pot_quant(w, alpha, m);
        if q != 0.0 {
            let e = (q.abs() / alpha).log2();
            prop_assert!((e - e.round()).abs() < 1e-5, "not PoT: q={q} alpha={alpha}");
        }
        prop_assert!((quant::pot_quant(q, alpha, m) - q).abs() < 1e-6);
        Ok(())
    });
}

#[test]
fn prop_quant_error_half_step_bound() {
    // |w - Q(w)| <= alpha / (2 * (2^{m-1} - 1)) inside the clip range.
    // (Note: NOT "e8 <= e4 pointwise" — the 4- and 8-bit symmetric grids
    // are not nested (7 does not divide 127), so 8-bit can be locally
    // worse; only the bound — and hence the MSE — improves with bits.)
    check("err-bound", 300, |g| {
        let alpha = g.f32_in(0.1, 3.0);
        let w = g.f32_in(-1.0, 1.0) * alpha; // inside clip range
        for m in [4u32, 8] {
            let e = (w - quant::fixed_quant(w, alpha, m)).abs();
            let bound = alpha / (2.0 * ((1 << (m - 1)) - 1) as f32);
            prop_assert!(
                e <= bound + 1e-6,
                "w={w} alpha={alpha} m={m} e={e} bound={bound}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_assignment_ratio_exact_and_stable() {
    check("assign-ratio", 60, |g| {
        let w = gen_mat(g, 128, 32);
        let ratio = gen_ratio(g);
        let s = assign_layer(&w, ratio, Sensitivity::WeightNorm, Scheme::PotW4A4);
        prop_assert!(
            validate_ratio(&s, ratio).is_ok(),
            "ratio {ratio} rows {}: {:?}",
            w.rows,
            validate_ratio(&s, ratio).err()
        );
        // determinism
        let s2 = assign_layer(&w, ratio, Sensitivity::WeightNorm, Scheme::PotW4A4);
        prop_assert!(s == s2, "assignment not deterministic");
        Ok(())
    });
}

#[test]
fn prop_partition_ranges_tile_rows_with_unit_fractions() {
    check("partition", 100, |g| {
        let n = g.usize_in(1, 200);
        let schemes: Vec<Scheme> = (0..n).map(|_| *g.choice(&ALL_SCHEMES)).collect();
        let p = RowPartition::from_schemes(&schemes);
        prop_assert!(p.total() == n);
        // class ranges are contiguous, tile 0..n in CLASS_ORDER, and
        // each holds exactly that class's row count
        let mut next = 0usize;
        for s in RowPartition::CLASS_ORDER {
            let r = p.range(s);
            prop_assert!(r.start == next, "{s} range not contiguous");
            prop_assert!(
                r.len() == schemes.iter().filter(|x| **x == s).count(),
                "{s} range holds the wrong row count"
            );
            next = r.end;
        }
        prop_assert!(next == n, "ranges do not tile 0..{n}");
        // all four class fractions are reported and sum to 1 (the old
        // 3-tuple silently dropped the APoT share)
        let f = p.fractions();
        let sum: f64 = f.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-9, "fractions sum {sum} != 1");
        let apot = schemes.iter().filter(|s| **s == Scheme::ApotW4A4).count();
        prop_assert!(
            (f[3] - apot as f64 / n as f64).abs() < 1e-12,
            "apot fraction missing"
        );
        Ok(())
    });
}

#[test]
fn prop_integer_gemm_equals_fake_quant() {
    check("gemm-consistency", 25, |g| {
        let batch = g.usize_in(1, 6);
        let rows = g.usize_in(1, 12);
        let cols = g.usize_in(1, 48);
        let x = Mat::from_vec(batch, cols, g.vec_f32(batch * cols, batch * cols, 0.0, 1.5));
        let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
        let schemes: Vec<Scheme> = (0..rows)
            .map(|_| *g.choice(&[Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4]))
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
        let act_alpha = g.f32_in(0.3, 2.0);

        let gm = MixedGemm::new();
        let acts = PackedActs::quantize(&x, act_alpha, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), gm.config().min_rows_per_task);
        let mut scratch = GemmScratch::new(gm.lanes());
        let mut int_out = Mat::zeros(acts.rows, pw.rows);
        gm.dispatch(
            GemmCall {
                acts: GemmActs::Packed(&acts),
                weights: &sw,
                chunks: &chunks,
                parallel: false,
                fill: true,
                out: GemmOut::F32(&mut int_out),
            },
            &mut scratch,
        );
        let f_out = gm.run_float(&x, &w, &schemes, &alpha, act_alpha, 4);
        let scale = f_out.data.iter().fold(1.0f32, |m, v| m.max(v.abs()));
        let err = int_out.max_abs_err(&f_out);
        prop_assert!(
            err / scale < 1e-3,
            "int vs fake-quant err {err} (batch={batch} rows={rows} cols={cols})"
        );
        Ok(())
    });
}

#[test]
fn prop_storage_bits_match_ratio() {
    check("storage", 60, |g| {
        let rows = g.usize_in(1, 100);
        let cols = g.usize_in(1, 64);
        let ratio = gen_ratio(g);
        let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
        let s = assign_layer(&w, ratio, Sensitivity::WeightNorm, Scheme::PotW4A4);
        let alpha = vec![1.0f32; rows];
        let p = PackedWeights::quantize(&w, &s, &alpha);
        let (_, _, nc) = ratio.counts(rows);
        let expect = cols * (4 * (rows - nc) + 8 * nc);
        prop_assert!(p.storage_bits() == expect, "bits {} != {expect}", p.storage_bits());
        Ok(())
    });
}

#[test]
fn prop_fpga_design_within_budget() {
    check("fpga-budget", 80, |g| {
        let board = *g.choice(&[Board::XC7Z020, Board::XC7Z045]);
        let ratio = gen_ratio(g);
        let cfg = QuantConfig { ratio, first_last_8bit: g.bool(), apot: g.bool() };
        let d = Design::allocate(board, cfg, CoreCosts::default());
        prop_assert!(d.lut_util() <= 1.0 + 1e-9, "LUT over budget: {}", d.lut_util());
        prop_assert!(d.dsp_util() <= 1.0 + 1e-9, "DSP over budget: {}", d.dsp_util());
        prop_assert!(d.pot_pes >= 0.0 && d.fixed4_pes >= 0.0 && d.fixed8_pes >= 0.0);
        // some capacity must exist whenever any class has share > 0
        if ratio.pot4 > 0 || ratio.fixed4 > 0 || ratio.fixed8 > 0 {
            prop_assert!(d.peak_macs_per_cycle() > 0.0);
        }
        Ok(())
    });
}

#[test]
fn prop_fpga_more_resources_never_slower() {
    check("fpga-monotone", 40, |g| {
        let ratio = gen_ratio(g);
        let cfg = QuantConfig { ratio, first_last_8bit: false, apot: false };
        let small = Design::allocate(Board::XC7Z020, cfg, CoreCosts::default());
        let big = Design::allocate(Board::XC7Z045, cfg, CoreCosts::default());
        let layers = rmsmp::fpga::sim::resnet18_imagenet_layers();
        let rs = rmsmp::fpga::simulate(&small, &layers);
        let rb = rmsmp::fpga::simulate(&big, &layers);
        prop_assert!(
            rb.latency_ms <= rs.latency_ms * 1.001,
            "bigger board slower: {} vs {}",
            rb.latency_ms,
            rs.latency_ms
        );
        Ok(())
    });
}
