//! Pass-pipeline invariants: the plan optimizer is a pipeline of five
//! graph-rewrite passes (`epilogue_fusion`, `integer_resident`,
//! `implicit`, `depthwise`, `dead_slot_elim`), each individually
//! toggleable through `PlanBuilder::disable_pass`. Every one of the 32
//! enable/disable subsets must produce logits **bit-identical** to the
//! reference interpreter — on a residual topology (exercising epilogue
//! fusion and dead-slot elimination) and a depthwise chain (exercising
//! the per-group streamed schedule) — across batch {1, 8}, threads
//! {1, 8}, and the scalar vs native SIMD kernels. A golden test pins
//! the per-pass reports (`Plan::pass_reports`) the `rmsmp plan` command
//! prints.

use std::sync::Arc;

use rmsmp::gemm::{Isa, PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan, PlanOp, PASS_NAMES};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

const SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

#[allow(clippy::too_many_arguments)]
fn layer(
    rng: &mut Rng,
    name: &str,
    kind: &str,
    rows: usize,
    cols: usize,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
) -> LayerWeights {
    let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
    let schemes: Vec<Scheme> =
        (0..rows).map(|r| SCHEMES[(rng.below(4) as usize + r) % 4]).collect();
    let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let bias: Vec<f32> = (0..rows).map(|_| rng.uniform(-0.1, 0.1)).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows,
        cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        // non-unit clip scales so requantization differs per edge
        a_alpha: rng.uniform(0.6, 1.4),
        scheme: schemes,
        alpha,
        bias,
        w: Some(w),
        packed,
        sorted,
    }
}

fn conv_meta(name: &str, rows: usize, cols: usize, s: usize, p: usize, groups: usize) -> String {
    format!(
        r#"{{"name":"{name}","kind":"conv","rows":{rows},"cols":{cols},"stride":{s},"pad":{p},"groups":{groups},"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
    )
}

fn finish_model(
    seed: u64,
    n: usize,
    c_in: usize,
    hw: usize,
    meta: String,
    prog: String,
    layers: Vec<LayerWeights>,
) -> (Manifest, ModelWeights, Tensor4) {
    let json = format!(
        r#"{{"model":"passes","arch":"resnet","num_classes":3,
            "input_shape":[{n},{c_in},{hw},{hw}],"ratio":[65,30,5],"act_bits":4,
            "layers":[{meta}],"program":[{prog}]}}"#
    );
    let manifest = Manifest::from_json(&Json::parse(&json).unwrap()).unwrap();
    let mut rng = Rng::new(seed ^ 0x5eed);
    let mut x = Tensor4::zeros(n, c_in, hw, hw);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.2);
    }
    (manifest, ModelWeights { layers }, x)
}

/// Residual topology — the epilogue-fusion shape:
///   c1 (k3, relu) in0 -> b0
///   c2 (k3)           b0 -> b1
///   add b1 + b0 (relu)     -> b2   <- folds into c2's epilogue
///   c3 (k3, relu)     b2 -> b3
///   gap -> fc
/// After fusion b1 has no writer and no reader: dead_slot_elim drops it.
fn residual_model(seed: u64, n: usize) -> (Manifest, ModelWeights, Tensor4) {
    let (c_in, hw, c1) = (3usize, 6usize, 4usize);
    let mut rng = Rng::new(seed);
    let layers = vec![
        layer(&mut rng, "c1", "conv", c1, c_in * 9, (c1, c_in, 3, 3), 1, 1, 1),
        layer(&mut rng, "c2", "conv", c1, c1 * 9, (c1, c1, 3, 3), 1, 1, 1),
        layer(&mut rng, "c3", "conv", c1, c1 * 9, (c1, c1, 3, 3), 1, 1, 1),
        layer(&mut rng, "fc", "linear", 3, c1, (3, c1, 1, 1), 0, 0, 1),
    ];
    let meta = [
        conv_meta("c1", c1, c_in * 9, 1, 1, 1),
        conv_meta("c2", c1, c1 * 9, 1, 1, 1),
        conv_meta("c3", c1, c1 * 9, 1, 1, 1),
        format!(
            r#"{{"name":"fc","kind":"linear","rows":3,"cols":{c1},"stride":0,"pad":0,"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
        ),
    ]
    .join(",");
    let prog = concat!(
        r#"{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true},"#,
        r#"{"op":"conv","layer":"c2","in":"b0","out":"b1","relu":false},"#,
        r#"{"op":"add","a":"b1","b":"b0","out":"b2","relu":true},"#,
        r#"{"op":"conv","layer":"c3","in":"b2","out":"b3","relu":true},"#,
        r#"{"op":"gap","in":"b3","out":"g0"},"#,
        r#"{"op":"linear","layer":"fc","in":"g0","out":"logits"}"#
    )
    .to_string();
    finish_model(seed, n, c_in, hw, meta, prog, layers)
}

/// Depthwise chain — the per-group streamed-schedule shape:
///   c1 (k3, relu) in0 -> b0
///   dw (k3, groups = channels) b0 -> b1
///   c2 (k3, relu) b1 -> b2
///   gap -> fc
fn depthwise_model(seed: u64, n: usize) -> (Manifest, ModelWeights, Tensor4) {
    let (c_in, hw, c1) = (3usize, 6usize, 4usize);
    let mut rng = Rng::new(seed);
    let layers = vec![
        layer(&mut rng, "c1", "conv", c1, c_in * 9, (c1, c_in, 3, 3), 1, 1, 1),
        layer(&mut rng, "dw", "conv", c1, 9, (c1, c1, 3, 3), 1, 1, c1),
        layer(&mut rng, "c2", "conv", c1, c1 * 9, (c1, c1, 3, 3), 1, 1, 1),
        layer(&mut rng, "fc", "linear", 3, c1, (3, c1, 1, 1), 0, 0, 1),
    ];
    let meta = [
        conv_meta("c1", c1, c_in * 9, 1, 1, 1),
        conv_meta("dw", c1, 9, 1, 1, c1),
        conv_meta("c2", c1, c1 * 9, 1, 1, 1),
        format!(
            r#"{{"name":"fc","kind":"linear","rows":3,"cols":{c1},"stride":0,"pad":0,"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
        ),
    ]
    .join(",");
    let prog = concat!(
        r#"{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true},"#,
        r#"{"op":"conv","layer":"dw","in":"b0","out":"b1","relu":false},"#,
        r#"{"op":"conv","layer":"c2","in":"b1","out":"b2","relu":true},"#,
        r#"{"op":"gap","in":"b2","out":"g0"},"#,
        r#"{"op":"linear","layer":"fc","in":"g0","out":"logits"}"#
    )
    .to_string();
    finish_model(seed, n, c_in, hw, meta, prog, layers)
}

/// Executor over a plan with the named passes disabled.
fn executor_with(
    manifest: &Manifest,
    weights: &ModelWeights,
    cfg: ParallelConfig,
    disabled: &[&str],
) -> Executor {
    let capacity = manifest.input_shape.first().copied().unwrap_or(1);
    let mut b = Plan::builder(manifest, weights).capacity(capacity).config(&cfg);
    for pass in disabled {
        b = b.disable_pass(pass);
    }
    let plan = Arc::new(b.build().unwrap());
    Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        plan,
        cfg,
        None,
    )
    .unwrap()
}

#[test]
fn every_pass_subset_is_bit_exact_vs_reference() {
    type Build = fn(u64, usize) -> (Manifest, ModelWeights, Tensor4);
    let topos: [(&str, Build); 2] =
        [("residual", residual_model), ("depthwise", depthwise_model)];
    for (tname, build) in topos {
        for &n in &[1usize, 8] {
            let (manifest, weights, x) = build(21, n);
            for mask in 0u32..(1 << PASS_NAMES.len()) {
                let disabled: Vec<&str> = PASS_NAMES
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| mask & (1 << i) != 0)
                    .map(|(_, p)| *p)
                    .collect();
                for &threads in &[1usize, 8] {
                    let cfg =
                        ParallelConfig { threads, tile_cols: 32, min_rows_per_task: 2, ..ParallelConfig::default() };
                    let mut ex = executor_with(&manifest, &weights, cfg, &disabled);
                    // every disabled pass must show up as off in the report
                    for rep in &ex.plan().pass_reports {
                        assert_eq!(
                            rep.enabled,
                            !disabled.contains(&rep.pass),
                            "{tname}: pass {} enabled flag wrong for mask {mask:05b}",
                            rep.pass
                        );
                    }
                    for isa in [Isa::Scalar, Isa::detect()] {
                        ex.set_isa(isa);
                        let got = ex.infer(&x).unwrap().clone();
                        let want = ex.reference_infer(&x).unwrap();
                        assert_eq!(
                            got.data, want.data,
                            "{tname} n={n} mask={mask:05b} threads={threads} {isa:?}: \
                             pass subset diverged from reference"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn pass_reports_pin_the_residual_pipeline() {
    let (manifest, weights, _) = residual_model(5, 2);
    let cfg = ParallelConfig::sequential();
    let plan =
        Plan::builder(&manifest, &weights).capacity(2).config(&cfg).build().unwrap();

    // one report per pass, in pipeline order, all enabled by default
    let names: Vec<&str> = plan.pass_reports.iter().map(|r| r.pass).collect();
    assert_eq!(names, PASS_NAMES.to_vec());
    assert!(plan.pass_reports.iter().all(|r| r.enabled));
    let by = |p: &str| plan.pass_reports.iter().find(|r| r.pass == p).unwrap();

    // fusion folds exactly the one add (+relu) into c2's epilogue
    let fusion = by("epilogue_fusion");
    assert_eq!(fusion.rewrites, 1, "fusion rewrites: {:?}", fusion.details);
    assert!(
        fusion.details[0].contains("fold add+relu -> conv c2 epilogue"),
        "fusion detail: {}",
        fusion.details[0]
    );
    assert!(by("integer_resident").rewrites >= 1);
    assert_eq!(by("implicit").rewrites, 3, "c1, c2, c3 must all stream");
    assert_eq!(by("depthwise").rewrites, 0, "no grouped conv here");
    // b1 lost its only writer (c2 now writes b2) and only reader (the
    // add): it must be eliminated
    let dead = by("dead_slot_elim");
    assert_eq!(dead.rewrites, 1, "dead slots: {:?}", dead.details);
    assert!(dead.details[0].contains("b1"), "dead detail: {}", dead.details[0]);

    // the fused plan has no standalone Add left, and c2 carries the
    // addend + relu in its epilogue, retargeted to the add's output
    assert!(!plan.ops.iter().any(|op| matches!(op, PlanOp::Add { .. })));
    let b0 = plan.slots.iter().position(|s| s.name == "b0").unwrap();
    let b1 = plan.slots.iter().position(|s| s.name == "b1").unwrap();
    let b2 = plan.slots.iter().position(|s| s.name == "b2").unwrap();
    let fused = plan
        .ops
        .iter()
        .find_map(|op| match op {
            PlanOp::Conv { layer, out, fused_add: Some(fa), .. }
                if weights.layers[*layer].name == "c2" =>
            {
                Some((*out, fa.clone()))
            }
            _ => None,
        })
        .expect("c2 lost its fused add");
    assert_eq!(fused.0, b2, "fused conv must write the add's output");
    assert_eq!(fused.1.addend, b0);
    assert!(fused.1.relu);
    // the dead slot holds neither f32 nor codes and costs no bytes
    assert!(!plan.slots[b1].holds_f32 && !plan.slots[b1].holds_codes);
    assert_eq!(plan.footprint(1).slot_bytes(b1), 0);

    // disabling fusion keeps the standalone add and reports the pass off
    let nofuse = Plan::builder(&manifest, &weights)
        .capacity(2)
        .config(&cfg)
        .disable_pass("epilogue_fusion")
        .build()
        .unwrap();
    let rep = nofuse.pass_reports.iter().find(|r| r.pass == "epilogue_fusion").unwrap();
    assert!(!rep.enabled && rep.rewrites == 0 && rep.details.is_empty());
    assert!(nofuse.ops.iter().any(|op| matches!(op, PlanOp::Add { .. })));
}

#[test]
fn pass_reports_pin_the_depthwise_schedule() {
    let (manifest, weights, _) = depthwise_model(11, 2);
    let cfg = ParallelConfig::sequential();
    let plan =
        Plan::builder(&manifest, &weights).capacity(2).config(&cfg).build().unwrap();
    let by = |p: &str| plan.pass_reports.iter().find(|r| r.pass == p).unwrap();
    assert_eq!(by("epilogue_fusion").rewrites, 0, "no add to fold");
    let dw_rep = by("depthwise");
    assert_eq!(dw_rep.rewrites, 1, "depthwise rewrites: {:?}", dw_rep.details);
    assert!(
        dw_rep.details[0].contains("conv dw depthwise (4 groups"),
        "depthwise detail: {}",
        dw_rep.details[0]
    );
    // the grouped conv carries a per-group schedule and a panel, and
    // did not take the implicit path
    let (chunks_len, positions) = plan
        .ops
        .iter()
        .find_map(|op| match op {
            PlanOp::Conv { layer, implicit, group_chunks, panel_positions, .. }
                if weights.layers[*layer].name == "dw" =>
            {
                assert!(!implicit);
                Some((group_chunks.len(), *panel_positions))
            }
            _ => None,
        })
        .expect("dw conv missing");
    assert!(chunks_len >= 1, "dw has no group schedule");
    assert!(positions >= 1, "dw has no panel");

    // with the pass off, the schedule disappears and the report says so
    let nodw = Plan::builder(&manifest, &weights)
        .capacity(2)
        .config(&cfg)
        .disable_pass("depthwise")
        .build()
        .unwrap();
    let rep = nodw.pass_reports.iter().find(|r| r.pass == "depthwise").unwrap();
    assert!(!rep.enabled && rep.rewrites == 0);
    for op in &nodw.ops {
        if let PlanOp::Conv { layer, group_chunks, .. } = op {
            if weights.layers[*layer].name == "dw" {
                assert!(group_chunks.is_empty(), "disabled pass left a schedule");
            }
        }
    }
}
