//! SIMD / micro-kernel invariants (the class-sorted kernel layer).
//!
//! The block micro-kernels behind `run_block_tiled` — the full ISA
//! ladder: scalar, SSE4.1, AVX2, AVX-512 VNNI, and NEON dot-product
//! (each clamped to what the host supports, so the grid degrades
//! gracefully on machines without a tier) — must be **bit-exact**
//! against the scalar row-at-a-time `run_row_tiled` path for every
//! scheme, batch size, tile size, activation width, and column count
//! (including lengths that are not multiples of the vector width, which
//! exercise the remainder loops); and the class-sorted layout's
//! permutation must scatter outputs back to exactly the unsorted row
//! order. Integer accumulation makes the first guarantee exact; the
//! bijective permutation makes the second one.

use rmsmp::gemm::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, Isa, MixedGemm, PackedActs,
    PackedWeights, ParallelConfig, SortedWeights, ISA_LADDER, MICRO_ROWS,
    MICRO_ROWS_CANDIDATES,
};
use rmsmp::prop_assert;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::prop::{check, Gen};
use rmsmp::util::rng::Rng;

const SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

fn problem(
    rows: usize,
    cols: usize,
    batch: usize,
    seed: u64,
) -> (PackedActs, PackedWeights) {
    let mut rng = Rng::new(seed);
    let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.3)).collect();
    let x = Mat::from_vec(batch, cols, xd);
    let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
    let schemes: Vec<Scheme> =
        (0..rows).map(|r| SCHEMES[(rng.below(4) as usize + r) % 4]).collect();
    let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let acts = PackedActs::quantize(&x, 1.0, 4);
    let pw = PackedWeights::quantize(&w, &schemes, &alpha);
    (acts, pw)
}

/// The PR-2-era scalar baseline: one `run_row_tiled` call per weight row
/// over the unsorted layout.
fn rowwise_reference(
    engine: &MixedGemm,
    acts: &PackedActs,
    pw: &PackedWeights,
    tile: usize,
) -> Mat {
    let mut out = Mat::zeros(acts.rows, pw.rows);
    let mut acc = vec![0i32; acts.rows];
    let mut col = vec![0.0f32; acts.rows];
    for r in 0..pw.rows {
        col.fill(0.0);
        engine
            .core_for(pw.scheme[r])
            .run_row_tiled(acts, pw, r, tile, &mut acc, &mut col);
        for (b, &v) in col.iter().enumerate() {
            out.set(b, r, v);
        }
    }
    out
}

/// The new path: class-sorted layout + block micro-kernels at `isa`,
/// `micro_rows` rows per block (the tuned 4/6/8 grid plus degenerate
/// heights).
fn sorted_block(
    acts: &PackedActs,
    pw: &PackedWeights,
    tile: usize,
    chunk_rows: usize,
    micro_rows: usize,
    isa: Isa,
) -> Mat {
    let mut engine = MixedGemm::with_config(ParallelConfig {
        threads: 1,
        tile_cols: tile,
        min_rows_per_task: chunk_rows,
        micro_rows,
    });
    engine.set_isa(isa);
    let sw = SortedWeights::from_packed(pw);
    let chunks = chunk_tasks(sw.partition(), chunk_rows);
    let mut scratch = GemmScratch::new(1);
    let mut out = Mat::zeros(acts.rows, pw.rows);
    out.data.fill(f32::NAN); // every cell must be overwritten
    engine.dispatch(
        GemmCall {
            acts: GemmActs::Packed(acts),
            weights: &sw,
            chunks: &chunks,
            parallel: false,
            fill: true,
            out: GemmOut::F32(&mut out),
        },
        &mut scratch,
    );
    out
}

#[test]
fn block_simd_bit_exact_vs_scalar_rows_at_fixed_shapes() {
    // The acceptance grid: batch 1/5/8, column counts that are not
    // multiples of the 16/32-byte vector widths, several tile sizes.
    let seq = MixedGemm::with_config(ParallelConfig::sequential());
    let mut seed = 100u64;
    for &batch in &[1usize, 5, 8] {
        for &cols in &[3usize, 31, 33, 64, 257] {
            for &tile in &[0usize, 7, 48] {
                seed += 1;
                let (acts, pw) = problem(13, cols, batch, seed);
                let want = rowwise_reference(&seq, &acts, &pw, tile);
                for isa in ISA_LADDER.map(Isa::available) {
                    for chunk_rows in [1usize, MICRO_ROWS, 64] {
                        for micro_rows in MICRO_ROWS_CANDIDATES {
                            let got = sorted_block(
                                &acts, &pw, tile, chunk_rows, micro_rows, isa,
                            );
                            assert_eq!(
                                got.data, want.data,
                                "isa {isa:?} batch {batch} cols {cols} tile {tile} \
                                 chunk {chunk_rows} mr {micro_rows}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_block_simd_bit_exact_vs_scalar_rows() {
    let seq = MixedGemm::with_config(ParallelConfig::sequential());
    check("simd-block-exact", 40, |g: &mut Gen| {
        let rows = g.usize_in(1, 40);
        let cols = g.usize_in(1, 130);
        let batch = g.usize_in(0, 9);
        let tile = *g.choice(&[0usize, 5, 32, 100]);
        let chunk_rows = g.usize_in(1, 9);
        let micro_rows = *g.choice(&[1usize, 4, 6, 8]);
        let (acts, pw) = problem(rows, cols, batch, g.usize_in(0, 1 << 30) as u64);
        let want = rowwise_reference(&seq, &acts, &pw, tile);
        for isa in [Isa::Scalar, Isa::detect_cpu()] {
            let got = sorted_block(&acts, &pw, tile, chunk_rows, micro_rows, isa);
            prop_assert!(
                got.data == want.data,
                "isa {isa:?} rows {rows} cols {cols} batch {batch} tile {tile} \
                 mr {micro_rows}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sorted_permutation_round_trips() {
    check("sorted-perm", 60, |g: &mut Gen| {
        let rows = g.usize_in(1, 120);
        let (_, pw) = problem(rows, 6, 1, g.usize_in(0, 1 << 30) as u64);
        let sw = SortedWeights::from_packed(&pw);
        // perm and inv are mutually inverse bijections
        for orig in 0..rows {
            prop_assert!(sw.perm[sw.inv[orig]] == orig, "perm . inv != id at {orig}");
            prop_assert!(sw.inv[sw.perm[orig]] == orig, "inv . perm != id at {orig}");
        }
        // the sorted class of each row matches its source scheme, and the
        // class ranges are exactly the partition's
        for sr in 0..rows {
            prop_assert!(
                sw.scheme_of(sr) == pw.scheme[sw.perm[sr]],
                "scheme mismatch at sorted row {sr}"
            );
        }
        Ok(())
    });
}

#[test]
fn parallel_simd_dispatch_is_bit_exact_vs_scalar_sequential() {
    let (acts, pw) = problem(57, 67, 6, 77);
    let seq = MixedGemm::with_config(ParallelConfig::sequential());
    let want = rowwise_reference(&seq, &acts, &pw, 16);
    let mut par = MixedGemm::with_config(ParallelConfig {
        threads: 4,
        tile_cols: 16,
        min_rows_per_task: 3,
        micro_rows: 6,
    });
    par.set_isa(Isa::detect_cpu());
    let sw = SortedWeights::from_packed(&pw);
    let chunks = chunk_tasks(sw.partition(), 3);
    let mut scratch = GemmScratch::new(par.lanes());
    let mut out = Mat::zeros(acts.rows, pw.rows);
    for _ in 0..3 {
        out.data.fill(f32::NAN);
        par.dispatch(
            GemmCall {
                acts: GemmActs::Packed(&acts),
                weights: &sw,
                chunks: &chunks,
                parallel: true,
                fill: true,
                out: GemmOut::F32(&mut out),
            },
            &mut scratch,
        );
        assert_eq!(out.data, want.data, "parallel SIMD dispatch diverged");
    }
}

#[test]
fn wide_activation_codes_stay_bit_exact_on_every_tier() {
    // The saturation boundary: 7-bit activation codes (max 127) are the
    // widest the maddubs-based tiers handle in-vector; 8-bit codes (max
    // 255) would saturate their i16 intermediate and flip sign under
    // NEON sdot, so those tiers must degrade to the scalar kernel —
    // while AVX-512 VNNI (u8 x i8 -> i32, no i16 intermediate) keeps its
    // vector path and must be exact anyway. Either way the contract is
    // the same: bit-exact vs the scalar row path at bits ∈ {7, 8}.
    // (That VNNI does NOT take the scalar fallback is pinned by the
    // simd unit tests on Isa::wide_code_tier; here we pin the numbers.)
    let seq = MixedGemm::with_config(ParallelConfig::sequential());
    for &bits in &[7u32, 8] {
        for &cols in &[3usize, 33, 64, 257] {
            let mut rng = Rng::new(500 + bits as u64 + cols as u64);
            let batch = 5usize;
            let rows = 13usize;
            let xd: Vec<f32> =
                (0..batch * cols).map(|_| rng.uniform(0.0, 1.3)).collect();
            let x = Mat::from_vec(batch, cols, xd);
            let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
            let schemes: Vec<Scheme> =
                (0..rows).map(|r| SCHEMES[(rng.below(4) as usize + r) % 4]).collect();
            let alpha: Vec<f32> =
                (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
            // codes span the full 2^bits range — 8-bit hits the u8 max
            let acts = PackedActs::quantize(&x, 1.0, bits);
            let pw = PackedWeights::quantize(&w, &schemes, &alpha);
            let want = rowwise_reference(&seq, &acts, &pw, 16);
            for isa in ISA_LADDER.map(Isa::available) {
                // every tuned block height must reroute (or stay exact)
                // identically — the 6/8-row variants have their own
                // wide-code guards
                for micro_rows in MICRO_ROWS_CANDIDATES {
                    let got = sorted_block(&acts, &pw, 16, MICRO_ROWS, micro_rows, isa);
                    assert_eq!(
                        got.data, want.data,
                        "isa {isa:?} bits {bits} cols {cols} mr {micro_rows}"
                    );
                }
            }
        }
    }
}

#[test]
fn isa_env_overrides_are_respected_by_engines_built_now() {
    // Engines resolve the ISA at construction; whatever RMSMP_ISA or the
    // deprecated RMSMP_NO_SIMD alias say for this process (the CI matrix
    // runs this suite once per forced tier), a freshly built engine must
    // agree with Isa::detect(), and a forced-scalar engine must report
    // Scalar.
    let engine = MixedGemm::new();
    assert_eq!(engine.isa(), Isa::detect());
    let mut forced = MixedGemm::new();
    forced.set_isa(Isa::Scalar);
    assert_eq!(forced.isa(), Isa::Scalar);
    // forcing any rung of the ladder lands on a supported tier
    for isa in ISA_LADDER {
        let mut e = MixedGemm::new();
        e.set_isa(isa);
        assert_eq!(e.isa(), isa.available(), "forced {isa:?}");
    }
}
