//! Compiled-plan invariants: the plan-walking executor must be bit-exact
//! vs the reference interpreter (`reference_infer`) across randomized
//! programs — conv with stride/pad, grouped conv, residual Add+ReLU, Gap,
//! linear head — and thread counts {1, 8}; the `_into` buffer-reuse
//! variants must equal their allocating originals; and steady-state
//! workspace buffers must stay pointer-stable across calls.

use rmsmp::gemm::{PackedActs, PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::im2col::{im2col, im2col_group, im2col_group_into, im2col_into};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan};
use rmsmp::prop_assert;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::prop::{check, Gen};
use rmsmp::util::rng::Rng;

const SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
    schemes: Vec<Scheme>,
    bias: Vec<f32>,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias,
        w: Some(w),
        packed,
        sorted,
    }
}

fn rand_layer(
    g: &mut Gen,
    name: &str,
    kind: &str,
    rows: usize,
    cols: usize,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
) -> LayerWeights {
    let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
    let schemes: Vec<Scheme> = (0..rows).map(|_| *g.choice(&SCHEMES)).collect();
    let bias = g.vec_normal(rows, rows, 0.1);
    layer(name, kind, w, conv, stride, pad, groups, schemes, bias)
}

/// Build a random model of one of three topologies:
///   0 — conv(k3, random stride/pad) → gap → fc
///   1 — conv(k3 s1 p1) → depthwise conv (groups = channels) → gap → fc
///   2 — conv(k3 s1 p1, relu) → conv(k3 s1 p1) → add(+relu) → gap → fc
fn build_model(g: &mut Gen, topo: usize) -> (Manifest, ModelWeights, Tensor4) {
    let n = g.usize_in(1, 3);
    let c_in = *g.choice(&[2usize, 3]);
    let hw = *g.choice(&[6usize, 7]);
    let c1 = 4usize;
    let classes = 3usize;
    let (stride, pad) = if topo == 0 {
        (*g.choice(&[1usize, 2]), *g.choice(&[0usize, 1]))
    } else {
        (1, 1)
    };

    let mut layers = vec![rand_layer(
        g,
        "c1",
        "conv",
        c1,
        c_in * 9,
        (c1, c_in, 3, 3),
        stride,
        pad,
        1,
    )];
    let mut meta = format!(
        r#"{{"name":"c1","kind":"conv","rows":{c1},"cols":{},"stride":{stride},"pad":{pad},"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#,
        c_in * 9
    );
    let mut prog =
        r#"{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true}"#.to_string();

    let gap_in = match topo {
        1 => {
            layers.push(rand_layer(g, "dw", "conv", c1, 9, (c1, c1, 3, 3), 1, 1, c1));
            meta.push_str(&format!(
                r#",{{"name":"dw","kind":"conv","rows":{c1},"cols":9,"stride":1,"pad":1,"groups":{c1},"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
            ));
            prog.push_str(r#",{"op":"conv","layer":"dw","in":"b0","out":"b1","relu":false}"#);
            "b1"
        }
        2 => {
            layers.push(rand_layer(
                g,
                "c2",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&format!(
                r#",{{"name":"c2","kind":"conv","rows":{c1},"cols":{},"stride":1,"pad":1,"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#,
                c1 * 9
            ));
            prog.push_str(r#",{"op":"conv","layer":"c2","in":"b0","out":"b1","relu":false}"#);
            prog.push_str(r#",{"op":"add","a":"b0","b":"b1","out":"b2","relu":true}"#);
            "b2"
        }
        _ => "b0",
    };

    layers.push(rand_layer(g, "fc", "linear", classes, c1, (classes, c1, 1, 1), 0, 0, 1));
    meta.push_str(&format!(
        r#",{{"name":"fc","kind":"linear","rows":{classes},"cols":{c1},"stride":0,"pad":0,"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
    ));
    prog.push_str(&format!(
        r#",{{"op":"gap","in":"{gap_in}","out":"g0"}},{{"op":"linear","layer":"fc","in":"g0","out":"logits"}}"#
    ));

    let json = format!(
        r#"{{"model":"prop","arch":"resnet","num_classes":{classes},
            "input_shape":[{n},{c_in},{hw},{hw}],"ratio":[65,30,5],"act_bits":4,
            "layers":[{meta}],"program":[{prog}]}}"#
    );
    let manifest = Manifest::from_json(&Json::parse(&json).unwrap()).unwrap();

    let mut x = Tensor4::zeros(n, c_in, hw, hw);
    for v in x.data.iter_mut() {
        *v = g.f32_in(0.0, 1.2);
    }
    (manifest, ModelWeights { layers }, x)
}

#[test]
fn prop_plan_bit_exact_vs_reference_interpreter() {
    check("plan-vs-reference", 24, |g| {
        let topo = g.usize_in(0, 2);
        let (manifest, weights, x) = build_model(g, topo);
        let mut per_thread: Vec<Vec<f32>> = Vec::new();
        for &threads in &[1usize, 8] {
            let cfg = ParallelConfig { threads, tile_cols: 32, min_rows_per_task: 2, ..ParallelConfig::default() };
            let mut exec =
                Executor::with_parallel(manifest.clone(), weights.clone(), cfg, None)
                    .map_err(|e| format!("compile failed (topo {topo}): {e}"))?;
            let plan_out = exec.infer(&x).unwrap().clone();
            let ref_out = exec.reference_infer(&x).unwrap();
            prop_assert!(
                plan_out.data == ref_out.data,
                "plan != reference at {threads} threads (topo {topo})"
            );
            // second call over warm buffers must not drift
            let again = exec.infer(&x).unwrap().clone();
            prop_assert!(again.data == plan_out.data, "warm re-run drifted (topo {topo})");
            per_thread.push(plan_out.data);
        }
        prop_assert!(
            per_thread[0] == per_thread[1],
            "thread count changed plan output (topo {topo})"
        );
        Ok(())
    });
}

#[test]
fn plan_handles_aliased_add() {
    // add writing one of its own operands (out == a == b) must match the
    // interpreter's copy semantics
    let mut g = Gen { rng: Rng::new(17), size: 1.0 };
    let (manifest, weights, x) = build_model(&mut g, 0);
    let mut m2 = manifest.clone();
    let alias = Manifest::from_json(
        &Json::parse(
            r#"{"model":"t","arch":"resnet","num_classes":3,"input_shape":[1,2,6,6],
                "ratio":[65,30,5],"act_bits":4,"layers":[],
                "program":[{"op":"add","a":"b0","b":"b0","out":"b0","relu":false}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    m2.program.insert(1, alias.program[0].clone());
    let mut exec = Executor::new(m2, weights).unwrap();
    let plan_out = exec.infer(&x).unwrap().clone();
    let ref_out = exec.reference_infer(&x).unwrap();
    assert_eq!(plan_out.data, ref_out.data, "aliased add diverged");
}

#[test]
fn im2col_into_matches_im2col() {
    let mut rng = Rng::new(3);
    let mut x = Tensor4::zeros(2, 3, 7, 7);
    for v in x.data.iter_mut() {
        *v = rng.normal();
    }
    let mut got = Mat::zeros(5, 4); // deliberately dirty + wrong-shaped
    for (k, s, p) in [(3, 1, 1), (3, 2, 0), (1, 1, 0), (5, 2, 2)] {
        let (want, oh, ow) = im2col(&x, k, s, p);
        let (oh2, ow2) = im2col_into(&x, k, s, p, &mut got);
        assert_eq!((oh, ow), (oh2, ow2), "k={k} s={s} p={p}");
        assert_eq!((got.rows, got.cols), (want.rows, want.cols));
        assert_eq!(got.data, want.data, "k={k} s={s} p={p}");
    }
}

#[test]
fn im2col_group_into_matches_im2col_group() {
    let mut rng = Rng::new(4);
    let mut x = Tensor4::zeros(1, 4, 6, 6);
    for v in x.data.iter_mut() {
        *v = rng.normal();
    }
    let mut got = Mat::zeros(0, 0);
    for group in 0..2 {
        let (want, oh, ow) = im2col_group(&x, group, 2, 3, 1, 1);
        let (oh2, ow2) = im2col_group_into(&x, group, 2, 3, 1, 1, &mut got);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(got.data, want.data, "group {group}");
    }
}

#[test]
fn quantize_into_matches_quantize() {
    let mut rng = Rng::new(5);
    let x = Mat::from_vec(3, 5, (0..15).map(|_| rng.uniform(-0.2, 1.4)).collect());
    let want = PackedActs::quantize(&x, 0.9, 4);
    let mut got = PackedActs::with_capacity(2); // must grow correctly
    PackedActs::quantize_into(&x, 0.9, 4, &mut got);
    assert_eq!((got.rows, got.cols), (want.rows, want.cols));
    assert_eq!(got.codes, want.codes);
    assert_eq!((got.alpha, got.bits), (want.alpha, want.bits));
    // reuse the same buffer for a different shape/alpha
    let y = Mat::from_vec(2, 4, (0..8).map(|_| rng.uniform(0.0, 2.0)).collect());
    let want2 = PackedActs::quantize(&y, 1.7, 4);
    PackedActs::quantize_into(&y, 1.7, 4, &mut got);
    assert_eq!(got.codes, want2.codes);
    assert_eq!((got.rows, got.cols), (2, 4));
}

#[test]
fn workspace_buffers_are_stable_across_calls() {
    for threads in [1usize, 8] {
        let mut g = Gen { rng: Rng::new(11), size: 1.0 };
        let (manifest, weights, x) = build_model(&mut g, 2);
        let cfg = ParallelConfig { threads, tile_cols: 32, min_rows_per_task: 2, ..ParallelConfig::default() };
        let mut exec = Executor::with_parallel(manifest, weights, cfg, None).unwrap();
        let _ = exec.infer(&x).unwrap(); // warm-up
        let ptrs = exec.workspace().buffer_ptrs();
        let out1 = exec.infer(&x).unwrap().clone();
        let out2 = exec.infer(&x).unwrap().clone();
        assert_eq!(out1.data, out2.data);
        assert_eq!(
            ptrs,
            exec.workspace().buffer_ptrs(),
            "workspace reallocated in steady state ({threads} threads)"
        );
    }
}

#[test]
fn plan_compile_rejects_bad_programs() {
    let mut g = Gen { rng: Rng::new(23), size: 1.0 };
    let (manifest, weights, _) = build_model(&mut g, 0);
    let cfg = ParallelConfig::sequential();

    // program reading a buffer nothing produced
    let mut m = manifest.clone();
    if let rmsmp::model::manifest::OpMeta::Conv { input, .. } = &mut m.program[0] {
        *input = "bogus".into();
    }
    assert!(Plan::builder(&m, &weights).config(&cfg).build().is_err());

    // program that never produces logits
    let mut m = manifest.clone();
    if let rmsmp::model::manifest::OpMeta::Linear { out, .. } = &mut m.program[2] {
        *out = "not_logits".into();
    }
    assert!(Plan::builder(&m, &weights).config(&cfg).build().is_err());

    // unknown pass names fail at build
    assert!(Plan::builder(&manifest, &weights)
        .config(&cfg)
        .disable_pass("no_such_pass")
        .build()
        .is_err());

    // well-formed program compiles
    assert!(Plan::builder(&manifest, &weights).config(&cfg).build().is_ok());
}

/// The deprecated one-PR compatibility shims still compile and agree
/// with the builder they forward to.
#[test]
#[allow(deprecated)]
fn deprecated_compile_shims_match_builder() {
    let mut g = Gen { rng: Rng::new(31), size: 1.0 };
    let (manifest, weights, _) = build_model(&mut g, 2);
    let cfg = ParallelConfig::sequential();
    let built = Plan::builder(&manifest, &weights).capacity(2).config(&cfg).build().unwrap();
    let shim = Plan::compile(&manifest, &weights, 2, &cfg).unwrap();
    assert_eq!(shim.ops.len(), built.ops.len());
    assert_eq!(shim.footprint(1).total_bytes(), built.footprint(1).total_bytes());
    let f32res =
        Plan::compile_with(&manifest, &weights, 2, &cfg, false).unwrap();
    assert!(!f32res.integer_resident);
    let explicit = Plan::compile_opts(
        &manifest,
        &weights,
        2,
        &cfg,
        rmsmp::model::PlanOptions { implicit: false, ..Default::default() },
    )
    .unwrap();
    assert!(!explicit.implicit);
}

#[test]
fn plan_reports_footprint_and_describe() {
    let mut g = Gen { rng: Rng::new(29), size: 1.0 };
    let (manifest, weights, _x) = build_model(&mut g, 2);
    let plan = Plan::builder(&manifest, &weights)
        .capacity(4)
        .config(&ParallelConfig::sequential())
        .build()
        .unwrap();
    let fp = plan.footprint(1);
    assert_eq!(fp.slot_elems.len(), plan.slots.len());
    assert!(fp.total_bytes() > 0);
    assert!(fp.total_slot_bytes() + fp.scratch_bytes() == fp.total_bytes());
    let desc = plan.describe(&weights, 1);
    assert!(desc.contains("passes:"), "{desc}");
    assert!(desc.contains("slots:"), "{desc}");
    assert!(desc.contains("ops:"), "{desc}");
    assert!(desc.contains("workspace"), "{desc}");
    // the executor's workspace reserves at least the promised footprint
    let exec = Executor::new(manifest, weights).unwrap();
    let promised = exec.plan().footprint(1).total_bytes();
    assert!(
        exec.workspace().allocated_bytes() >= promised,
        "workspace under-reserves: {} < {promised}",
        exec.workspace().allocated_bytes()
    );
}
