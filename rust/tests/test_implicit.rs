//! Implicit-GEMM pipeline invariants: the panel-packed conv path (no
//! materialized im2col buffer) must produce **bit-identical** logits and
//! per-slot activation codes to the reference interpreter and to the
//! explicit-im2col plan (`disable_pass("implicit")` — the PR 4
//! dataflow), across conv stride/pad, grouped conv, the 1×1 stride-1
//! pad-0 NHWC alias fast path, batch {1, 5, 8}, threads {1, 8}, and the
//! scalar vs native SIMD kernels. Also pins the plan-compile decisions
//! (which convs run implicitly, which slots retarget to NHWC) and the
//! workspace footprint win from dropping the patches slot.

use std::sync::Arc;

use rmsmp::gemm::{Isa, PackedWeights, ParallelConfig, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan, PlanOp};
use rmsmp::prop_assert;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::prop::{check, Gen};
use rmsmp::util::rng::Rng;

const SCHEMES: [Scheme; 4] = [
    Scheme::PotW4A4,
    Scheme::FixedW4A4,
    Scheme::FixedW8A4,
    Scheme::ApotW4A4,
];

#[allow(clippy::too_many_arguments)]
fn rand_layer(
    g: &mut Gen,
    name: &str,
    kind: &str,
    rows: usize,
    cols: usize,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
) -> LayerWeights {
    let w = Mat::from_vec(rows, cols, g.vec_normal(rows * cols, rows * cols, 0.5));
    let schemes: Vec<Scheme> = (0..rows).map(|_| *g.choice(&SCHEMES)).collect();
    let bias = g.vec_normal(rows, rows, 0.1);
    let alpha: Vec<f32> = (0..rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows,
        cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        // non-unit clip scales so the fused epilogues' requantization
        // scale actually differs per edge
        a_alpha: g.f32_in(0.6, 1.4),
        scheme: schemes,
        alpha,
        bias,
        w: Some(w),
        packed,
        sorted,
    }
}

/// Three topologies, each exercising a different implicit-path shape:
///   0 — conv(k3, random stride/pad, relu) → conv(k3) → gap → fc
///       (plain implicit chain with one integer edge)
///   1 — conv(k3) → depthwise conv (groups = channels, explicit
///       fallback) → conv(k3) → gap → fc (codes in and out of the
///       grouped fallback)
///   2 — conv(k3) → conv(k1 s1 p0) → conv(k1 s1 p0) → gap → fc
///       (the NHWC alias fast path: both unit convs read their input
///       slot with no gather and no copy)
fn build_model(g: &mut Gen, topo: usize, n: usize) -> (Manifest, ModelWeights, Tensor4) {
    let c_in = *g.choice(&[2usize, 3]);
    let hw = *g.choice(&[6usize, 7]);
    let c1 = 4usize;
    let classes = 3usize;
    let (stride, pad) = if topo == 0 {
        (*g.choice(&[1usize, 2]), *g.choice(&[0usize, 1]))
    } else {
        (1, 1)
    };

    let mut layers = vec![rand_layer(
        g,
        "c1",
        "conv",
        c1,
        c_in * 9,
        (c1, c_in, 3, 3),
        stride,
        pad,
        1,
    )];
    let mut meta = format!(
        r#"{{"name":"c1","kind":"conv","rows":{c1},"cols":{},"stride":{stride},"pad":{pad},"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#,
        c_in * 9
    );
    let mut prog =
        r#"{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true}"#.to_string();

    let conv_meta = |name: &str, rows: usize, cols: usize, s: usize, p: usize, groups: usize| {
        format!(
            r#",{{"name":"{name}","kind":"conv","rows":{rows},"cols":{cols},"stride":{s},"pad":{p},"groups":{groups},"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
        )
    };

    let gap_in = match topo {
        1 => {
            layers.push(rand_layer(g, "dw", "conv", c1, 9, (c1, c1, 3, 3), 1, 1, c1));
            meta.push_str(&conv_meta("dw", c1, 9, 1, 1, c1));
            prog.push_str(r#",{"op":"conv","layer":"dw","in":"b0","out":"b1","relu":false}"#);
            layers.push(rand_layer(
                g,
                "c2",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c2", c1, c1 * 9, 1, 1, 1));
            prog.push_str(r#",{"op":"conv","layer":"c2","in":"b1","out":"b2","relu":true}"#);
            "b2"
        }
        2 => {
            layers.push(rand_layer(g, "u1", "conv", c1, c1, (c1, c1, 1, 1), 1, 0, 1));
            meta.push_str(&conv_meta("u1", c1, c1, 1, 0, 1));
            prog.push_str(r#",{"op":"conv","layer":"u1","in":"b0","out":"b1","relu":false}"#);
            layers.push(rand_layer(g, "u2", "conv", c1, c1, (c1, c1, 1, 1), 1, 0, 1));
            meta.push_str(&conv_meta("u2", c1, c1, 1, 0, 1));
            prog.push_str(r#",{"op":"conv","layer":"u2","in":"b1","out":"b2","relu":true}"#);
            "b2"
        }
        _ => {
            layers.push(rand_layer(
                g,
                "c2",
                "conv",
                c1,
                c1 * 9,
                (c1, c1, 3, 3),
                1,
                1,
                1,
            ));
            meta.push_str(&conv_meta("c2", c1, c1 * 9, 1, 1, 1));
            prog.push_str(r#",{"op":"conv","layer":"c2","in":"b0","out":"b1","relu":false}"#);
            "b1"
        }
    };

    layers.push(rand_layer(g, "fc", "linear", classes, c1, (classes, c1, 1, 1), 0, 0, 1));
    meta.push_str(&format!(
        r#",{{"name":"fc","kind":"linear","rows":{classes},"cols":{c1},"stride":0,"pad":0,"groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}"#
    ));
    prog.push_str(&format!(
        r#",{{"op":"gap","in":"{gap_in}","out":"g0"}},{{"op":"linear","layer":"fc","in":"g0","out":"logits"}}"#
    ));

    let json = format!(
        r#"{{"model":"implicit","arch":"resnet","num_classes":{classes},
            "input_shape":[{n},{c_in},{hw},{hw}],"ratio":[65,30,5],"act_bits":4,
            "layers":[{meta}],"program":[{prog}]}}"#
    );
    let manifest = Manifest::from_json(&Json::parse(&json).unwrap()).unwrap();

    let mut x = Tensor4::zeros(n, c_in, hw, hw);
    for v in x.data.iter_mut() {
        *v = g.f32_in(0.0, 1.2);
    }
    (manifest, ModelWeights { layers }, x)
}

/// Executor over a plan compiled with the named optimizer passes off.
fn executor_with(
    manifest: &Manifest,
    weights: &ModelWeights,
    cfg: ParallelConfig,
    disabled: &[&str],
) -> Executor {
    let capacity = manifest.input_shape.first().copied().unwrap_or(1);
    let mut b = Plan::builder(manifest, weights).capacity(capacity).config(&cfg);
    for pass in disabled {
        b = b.disable_pass(pass);
    }
    let plan = Arc::new(b.build().unwrap());
    Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        plan,
        cfg,
        None,
    )
    .unwrap()
}

/// The slot and element count a GEMM op wrote for batch `n`.
fn out_len(op: &PlanOp, weights: &ModelWeights, n: usize) -> Option<(usize, usize)> {
    match op {
        PlanOp::Conv { layer, out, oh, ow, out_quant, .. } => out_quant
            .map(|_| (*out, n * weights.layers[*layer].out_ch * oh * ow)),
        PlanOp::Linear { out, out_cols, out_quant, .. } => {
            out_quant.map(|_| (*out, n * out_cols))
        }
        _ => None,
    }
}

/// Pin every integer-resident slot's codes of the implicit executor
/// against the explicit executor's, translating NHWC-retargeted slots
/// back to NCHW order. Returns the number of integer-resident ops.
fn assert_codes_match(imp: &Executor, exp: &Executor, n: usize) -> Result<usize, String> {
    let weights = imp.weights();
    let mut integer_ops = 0;
    for op in &imp.plan().ops {
        let Some((slot, len)) = out_len(op, weights, n) else { continue };
        integer_ops += 1;
        let got = &imp.workspace().slot_codes(slot)[..len];
        let want = &exp.workspace().slot_codes(slot)[..len];
        let spec = &imp.plan().slots[slot];
        if !spec.code_nhwc {
            if got != want {
                return Err(format!("slot {slot}: implicit codes diverged"));
            }
            continue;
        }
        // NHWC slot: implicit[(img*hw + pos)*c + ch] vs explicit
        // NCHW[((img*c) + ch)*hw + pos]
        let rmsmp::model::plan::SlotKind::T4 { c, h, w } = spec.kind else {
            return Err(format!("slot {slot}: NHWC slot is not 4-D"));
        };
        let hw = h * w;
        for img in 0..n {
            for ch in 0..c {
                for pos in 0..hw {
                    let gv = got[(img * hw + pos) * c + ch];
                    let wv = want[((img * c) + ch) * hw + pos];
                    if gv != wv {
                        return Err(format!(
                            "slot {slot} img {img} ch {ch} pos {pos}: NHWC code {gv} != {wv}"
                        ));
                    }
                }
            }
        }
    }
    Ok(integer_ops)
}

#[test]
fn prop_implicit_bit_exact_across_grid() {
    check("implicit-gemm", 18, |g| {
        let topo = g.usize_in(0, 2);
        let n = *g.choice(&[1usize, 5, 8]);
        let (manifest, weights, x) = build_model(g, topo, n);
        let isas = [Isa::Scalar, Isa::detect()];
        for &threads in &[1usize, 8] {
            let cfg = ParallelConfig { threads, tile_cols: 32, min_rows_per_task: 2, ..ParallelConfig::default() };
            let mut imp = executor_with(&manifest, &weights, cfg, &[]);
            let mut exp = executor_with(&manifest, &weights, cfg, &["implicit"]);
            prop_assert!(
                imp.plan().implicit && !exp.plan().implicit,
                "plan implicit flags wrong"
            );
            for &isa in &isas {
                imp.set_isa(isa);
                exp.set_isa(isa);
                let imp_out = imp.infer(&x).unwrap().clone();
                let exp_out = exp.infer(&x).unwrap().clone();
                let ref_out = imp.reference_infer(&x).unwrap();
                prop_assert!(
                    imp_out.data == ref_out.data,
                    "implicit != reference (topo {topo}, {threads} thr, {isa:?})"
                );
                prop_assert!(
                    imp_out.data == exp_out.data,
                    "implicit != explicit-im2col (topo {topo}, {threads} thr, {isa:?})"
                );
                // warm re-run over reused buffers must not drift
                let again = imp.infer(&x).unwrap().clone();
                prop_assert!(again.data == imp_out.data, "warm re-run drifted (topo {topo})");
                let pinned = assert_codes_match(&imp, &exp, n)?;
                prop_assert!(
                    pinned >= 1,
                    "topology {topo} produced no integer-resident edge"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn plan_marks_implicit_convs_and_nhwc_slots() {
    let mut g = Gen { rng: Rng::new(7), size: 1.0 };
    // topo 2: c1 (k3) feeds u1 (1×1) feeds u2 (1×1) — both unit edges
    // must retarget to NHWC and both unit convs must alias their input
    let (manifest, weights, _) = build_model(&mut g, 2, 2);
    let cfg = ParallelConfig::sequential();
    let plan = Plan::builder(&manifest, &weights).capacity(2).config(&cfg).build().unwrap();
    assert!(plan.implicit && plan.integer_resident);
    let mut seen = 0;
    for op in &plan.ops {
        if let PlanOp::Conv {
            layer, implicit, panel_positions, in_nhwc, out_nhwc, in_codes, out_quant, groups, ..
        } = op
        {
            let name = weights.layers[*layer].name.as_str();
            assert_eq!(*groups, 1);
            assert!(*implicit, "{name} not implicit");
            assert!(*panel_positions >= 8, "{name} panel unset");
            match name {
                "c1" => {
                    // c1 -> b0 is read only by the unit conv u1: emit NHWC
                    assert!(out_quant.is_some() && *out_nhwc, "c1 must emit NHWC codes");
                    assert!(!*in_codes, "c1 reads the f32 input");
                }
                "u1" => {
                    assert!(*in_codes && *in_nhwc, "u1 must alias its NHWC input");
                    assert!(out_quant.is_some() && *out_nhwc, "u1 must emit NHWC codes");
                }
                "u2" => {
                    assert!(*in_codes && *in_nhwc, "u2 must alias its NHWC input");
                    // b2 feeds gap: f32 fallback
                    assert!(out_quant.is_none(), "u2 -> gap must stay f32");
                }
                other => panic!("unexpected conv {other}"),
            }
            seen += 1;
        }
    }
    assert_eq!(seen, 3);
    let b0 = plan.slots.iter().find(|s| s.name == "b0").unwrap();
    let b1 = plan.slots.iter().find(|s| s.name == "b1").unwrap();
    assert!(b0.code_nhwc && b1.code_nhwc, "unit-conv inputs not NHWC");

    // the explicit twin must keep NCHW everywhere
    let exp = Plan::builder(&manifest, &weights)
        .capacity(2)
        .config(&cfg)
        .disable_pass("implicit")
        .build()
        .unwrap();
    assert!(exp.slots.iter().all(|s| !s.code_nhwc));

    // topo 1: the grouped conv pins its input and output slots to NCHW
    // and takes the depthwise per-group streamed schedule
    let (manifest, weights, _) = build_model(&mut g, 1, 2);
    let plan = Plan::builder(&manifest, &weights).capacity(2).config(&cfg).build().unwrap();
    for op in &plan.ops {
        if let PlanOp::Conv { layer, implicit, groups, group_chunks, in_nhwc, out_nhwc, .. } = op
        {
            let name = weights.layers[*layer].name.as_str();
            if name == "dw" {
                assert!(*groups > 1 && !*implicit, "grouped conv must not take implicit path");
                assert!(!group_chunks.is_empty(), "dw missing a depthwise schedule");
            }
            assert!(!*in_nhwc && !*out_nhwc, "{name}: 3x3/grouped edges must stay NCHW");
        }
    }
}

#[test]
fn implicit_plan_drops_the_patches_slot() {
    let mut g = Gen { rng: Rng::new(19), size: 1.0 };
    // topo 0: every conv is implicit-capable, so the patch buffer (and
    // its activation staging) must vanish from the footprint entirely
    let (manifest, weights, _) = build_model(&mut g, 0, 8);
    let cfg = ParallelConfig::sequential();
    let imp = Plan::builder(&manifest, &weights).capacity(8).config(&cfg).build().unwrap();
    let exp = Plan::builder(&manifest, &weights)
        .capacity(8)
        .config(&cfg)
        .disable_pass("implicit")
        .build()
        .unwrap();
    let fpi = imp.footprint(1);
    let fpe = exp.footprint(1);
    assert_eq!(fpi.patch_elems, 0, "implicit plan still budgets a patch buffer");
    assert!(fpe.patch_elems > 0, "explicit baseline lost its patch buffer");
    assert!(fpi.panel_elems > 0, "implicit plan budgets no panel");
    // the panel is a small constant; the patch matrix scales with the
    // batch — at capacity 8 the implicit workspace must be smaller by at
    // least the patch buffer it dropped
    assert!(
        fpi.total_bytes() + 4 * fpe.patch_elems <= fpe.total_bytes() + fpi.lanes * fpi.panel_elems,
        "footprint shrank less than the dropped patch buffer: implicit {} B vs explicit {} B",
        fpi.total_bytes(),
        fpe.total_bytes()
    );
    assert!(
        fpi.total_bytes() < fpe.total_bytes(),
        "implicit workspace not smaller: {} vs {}",
        fpi.total_bytes(),
        fpe.total_bytes()
    );

    // topo 1: the depthwise pass streams the grouped conv through the
    // panel, so the default plan budgets no patch buffer at all
    let (manifest, weights, _) = build_model(&mut g, 1, 8);
    let imp = Plan::builder(&manifest, &weights).capacity(8).config(&cfg).build().unwrap();
    let dw = weights.layer("dw").unwrap();
    let hw = manifest.input_shape[2] * manifest.input_shape[3];
    assert_eq!(imp.max_patch_per_image, 0, "depthwise-streamed plan still budgets a patch");
    assert!(imp.footprint(1).panel_elems > 0);

    // with depthwise off the grouped fallback stages the dw conv, but
    // its input is integer-resident: codes go through the acts buffer,
    // never the f32 patch matrix
    let nodw = Plan::builder(&manifest, &weights)
        .capacity(8)
        .config(&cfg)
        .disable_pass("depthwise")
        .build()
        .unwrap();
    assert_eq!(nodw.max_patch_per_image, 0, "in_codes grouped fallback budgets no patch");
    assert!(nodw.max_acts_per_image >= hw * dw.cols, "staged dw codes missing from acts");

    // only with integer-resident off too does dw stage f32 patches, and
    // the high-water mark is exactly its im2col matrix
    let f32dw = Plan::builder(&manifest, &weights)
        .capacity(8)
        .config(&cfg)
        .disable_pass("depthwise")
        .disable_pass("integer_resident")
        .build()
        .unwrap();
    assert_eq!(
        f32dw.max_patch_per_image,
        hw * dw.cols,
        "patches high-water != grouped-conv fallback"
    );
    assert!(f32dw.footprint(1).patch_elems > 0);
}

#[test]
fn grouped_and_strided_fixed_cases_bit_exact_batch8() {
    // fixed heavy cases on top of the property grid: stride-2 no-pad
    // (topo 0 shapes) and the depthwise chain, batch 8, both thread
    // counts
    for topo in [0usize, 1] {
        for seed in [3u64, 17] {
            let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
            let (manifest, weights, x) = build_model(&mut g, topo, 8);
            for threads in [1usize, 8] {
                let cfg = ParallelConfig { threads, tile_cols: 16, min_rows_per_task: 2, ..ParallelConfig::default() };
                let mut imp = executor_with(&manifest, &weights, cfg, &[]);
                let mut exp = executor_with(&manifest, &weights, cfg, &["implicit"]);
                let imp_out = imp.infer(&x).unwrap().clone();
                let exp_out = exp.infer(&x).unwrap().clone();
                let ref_out = imp.reference_infer(&x).unwrap();
                assert_eq!(imp_out.data, ref_out.data, "topo {topo} seed {seed} t{threads}");
                assert_eq!(imp_out.data, exp_out.data, "topo {topo} seed {seed} t{threads}");
                assert_codes_match(&imp, &exp, 8).unwrap();
            }
        }
    }
}
