//! Graph-executor integration tests on a hand-built tiny model (no AOT
//! artifacts needed): conv -> relu -> gap -> linear, with residual-add and
//! grouped-conv variants, checked against a float fake-quant reference.

use rmsmp::gemm::{MixedGemm, PackedWeights, SortedWeights};
use rmsmp::model::im2col::{col2im, im2col};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::Executor;
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    groups: usize,
    schemes: Vec<Scheme>,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias: vec![0.0; w.rows],
        w: Some(w),
        packed,
        sorted,
    }
}

fn tiny_manifest(extra_ops: &str) -> Manifest {
    let json = format!(
        r#"{{
        "model": "tiny", "arch": "resnet", "num_classes": 3,
        "input_shape": [2, 2, 6, 6], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {{"name": "c1", "kind": "conv", "rows": 4, "cols": 18,
            "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
            "scheme_counts": [2, 1, 1, 0]}},
          {{"name": "fc", "kind": "linear", "rows": 3, "cols": 4,
            "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
            "scheme_counts": [1, 2, 0, 0]}}
        ],
        "program": [
          {{"op": "conv", "layer": "c1", "in": "in0", "out": "b0", "relu": true}},
          {extra_ops}
          {{"op": "gap", "in": "b0", "out": "b1"}},
          {{"op": "linear", "layer": "fc", "in": "b1", "out": "logits"}}
        ]
      }}"#
    );
    Manifest::from_json(&Json::parse(&json).unwrap()).unwrap()
}

fn tiny_model() -> (Manifest, ModelWeights) {
    let mut rng = Rng::new(5);
    let wc = Mat::from_vec(4, 18, rng.normal_vec(4 * 18, 0.5));
    let wf = Mat::from_vec(3, 4, rng.normal_vec(12, 0.5));
    let conv_schemes = vec![
        Scheme::PotW4A4,
        Scheme::PotW4A4,
        Scheme::FixedW4A4,
        Scheme::FixedW8A4,
    ];
    let fc_schemes = vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW4A4];
    let layers = vec![
        layer("c1", "conv", wc, (4, 2, 3, 3), 1, 1, 1, conv_schemes),
        layer("fc", "linear", wf, (3, 4, 1, 1), 0, 0, 1, fc_schemes),
    ];
    (tiny_manifest(""), ModelWeights { layers })
}

fn rand_input(seed: u64) -> Tensor4 {
    let mut rng = Rng::new(seed);
    let mut x = Tensor4::zeros(2, 2, 6, 6);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.0);
    }
    x
}

/// Float fake-quant reference for the tiny model.
fn reference(weights: &ModelWeights, x: &Tensor4) -> Mat {
    let g = MixedGemm::new();
    let c1 = &weights.layers[0];
    let (patches, oh, ow) = im2col(x, 3, 1, 1);
    let y = g.run_float(&patches, c1.w.as_ref().unwrap(), &c1.scheme, &c1.alpha, 1.0, 4);
    let mut t = col2im(&y, x.n, 4, oh, ow);
    for v in t.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    // gap
    let mut m = Mat::zeros(t.n, t.c);
    for n in 0..t.n {
        for c in 0..t.c {
            let mut s = 0.0;
            for yy in 0..t.h {
                for xx in 0..t.w {
                    s += t.at(n, c, yy, xx);
                }
            }
            m.set(n, c, s / (t.h * t.w) as f32);
        }
    }
    let fc = &weights.layers[1];
    g.run_float(&m, fc.w.as_ref().unwrap(), &fc.scheme, &fc.alpha, 1.0, 4)
}

#[test]
fn executor_matches_float_reference() {
    let (manifest, weights) = tiny_model();
    let mut exec = Executor::new(manifest, weights.clone()).unwrap();
    let x = rand_input(3);
    let got = exec.infer(&x).unwrap();
    let want = reference(&weights, &x);
    let err = got.max_abs_err(&want);
    assert!(err < 1e-3, "executor vs reference err {err}");
    assert!(exec.macs > 0);
}

#[test]
fn executor_is_deterministic() {
    let (manifest, weights) = tiny_model();
    let mut e1 = Executor::new(manifest.clone(), weights.clone()).unwrap();
    let mut e2 = Executor::new(manifest, weights).unwrap();
    let a = e1.infer(&rand_input(9)).unwrap();
    let b = e2.infer(&rand_input(9)).unwrap();
    assert_eq!(a.data, b.data);
}

#[test]
fn executor_rejects_bad_program() {
    let (manifest, weights) = tiny_model();
    // program references a missing layer
    let mut m2 = manifest.clone();
    if let rmsmp::model::manifest::OpMeta::Conv { layer, .. } = &mut m2.program[0] {
        *layer = "nope".into();
    }
    assert!(Executor::new(m2, weights).is_err());
}

#[test]
fn residual_add_and_relu() {
    // conv (identity-ish) + add(b0, b0) doubles activations before gap
    let (manifest, weights) = tiny_model();
    let mut m2 = manifest.clone();
    // splice: conv -> add(b0,b0)->b2 -> gap(b2), via a one-op manifest
    let add_src = format!(
        r#"{{"model":"t","arch":"resnet","num_classes":3,"input_shape":[2,2,6,6],
            "ratio":[65,30,5],"act_bits":4,"layers":[],
            "program":[{}]}}"#,
        r#"{"op": "add", "a": "b0", "b": "b0", "out": "b2", "relu": true}"#
    );
    let add_manifest = Manifest::from_json(&Json::parse(&add_src).unwrap()).unwrap();
    let mut prog = m2.program.clone();
    prog.insert(1, add_manifest.program[0].clone());
    if let rmsmp::model::manifest::OpMeta::Gap { input, .. } = &mut prog[2] {
        *input = "b2".into();
    }
    m2.program = prog;
    let mut exec = Executor::new(m2, weights.clone()).unwrap();
    let mut base = Executor::new(manifest, weights).unwrap();
    let x = rand_input(4);
    let doubled = exec.infer(&x).unwrap();
    let single = base.infer(&x).unwrap();
    // GAP is linear; doubling pre-GAP doubles the fc input, and the fc
    // quantizes *activations* so equality is approximate
    let scale = single.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let mut maxrel = 0.0f32;
    for (d, s) in doubled.data.iter().zip(&single.data) {
        // not exactly 2x due to activation clipping; just sanity: different
        maxrel = maxrel.max((d - s).abs() / scale.max(1e-6));
    }
    assert!(maxrel > 0.01, "add op had no effect");
}
