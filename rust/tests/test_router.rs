//! Multi-model router over two in-memory variants (no artifacts needed).

use rmsmp::coordinator::{Router, ServerConfig};
use rmsmp::gemm::{PackedWeights, SortedWeights};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

fn tiny(seed: u64, schemes: Vec<Scheme>) -> (Manifest, ModelWeights) {
    let manifest = Manifest::from_json(
        &Json::parse(
            r#"{
        "model": "tiny", "arch": "resnet", "num_classes": 3,
        "input_shape": [1, 2, 4, 4], "ratio": [65, 30, 5], "act_bits": 4,
        "layers": [
          {"name": "fc", "kind": "linear", "rows": 3, "cols": 2,
           "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
           "scheme_counts": [1, 1, 1, 0]}
        ],
        "program": [
          {"op": "gap", "in": "in0", "out": "b0"},
          {"op": "linear", "layer": "fc", "in": "b0", "out": "logits"}
        ]
      }"#,
        )
        .unwrap(),
    )
    .unwrap();
    // graph: gap reduces (1,2,4,4) -> (1,2); fc is 3x2.
    let mut rng = Rng::new(seed);
    let w = Mat::from_vec(3, 2, rng.normal_vec(6, 0.5));
    let alpha: Vec<f32> = (0..3).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    let weights = ModelWeights {
        layers: vec![LayerWeights {
            name: "fc".into(),
            kind: "linear".into(),
            rows: 3,
            cols: 2,
            out_ch: 3,
            in_ch: 2,
            kh: 1,
            kw: 1,
            stride: 0,
            pad: 0,
            groups: 1,
            a_alpha: 1.0,
            scheme: schemes,
            alpha,
            bias: vec![0.0; 3],
            w: Some(w),
            packed,
            sorted,
        }],
    };
    (manifest, weights)
}

fn router() -> Router {
    let (m1, w1) = tiny(1, vec![Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4]);
    let (m2, w2) = tiny(2, vec![Scheme::FixedW4A4; 3]);
    let cfg = ServerConfig::default();
    Router::start(vec![
        ("rmsmp".to_string(), m1, w1, cfg.clone()),
        ("fixed".to_string(), m2, w2, cfg),
    ])
    .unwrap()
}

#[test]
fn routes_by_name_and_default() {
    let r = router();
    assert_eq!(r.names(), vec!["fixed", "rmsmp"]);
    let img = vec![0.5f32; 32];
    let a = r.infer(Some("rmsmp"), img.clone()).unwrap();
    let b = r.infer(Some("fixed"), img.clone()).unwrap();
    let d = r.infer(None, img).unwrap(); // default = first registered = rmsmp
    assert_eq!(a.logits.len(), 3);
    assert_ne!(a.logits, b.logits, "different weights must differ");
    assert_eq!(a.logits, d.logits, "default routes to first variant");
    r.shutdown();
}

#[test]
fn unknown_model_is_an_error() {
    let r = router();
    assert!(r.infer(Some("nope"), vec![0.0; 32]).is_err());
    r.shutdown();
}

#[test]
fn per_variant_metrics() {
    let r = router();
    for _ in 0..3 {
        r.infer(Some("fixed"), vec![0.1; 32]).unwrap();
    }
    let s = r.summary();
    assert!(s.contains("[fixed]"), "{s}");
    assert!(s.contains("responses=3"), "{s}");
    let v = r.variant("rmsmp").unwrap();
    assert_eq!(
        v.server.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
        0
    );
    r.shutdown();
}
