//! Load-time autotuner invariants: tuning is an **optimization, never a
//! semantic**. A plan compiled with tuned blocking knobs must produce
//! logits bit-identical to the fixed-default plan (`.no_tune()`), the
//! tuned knobs must come from the advertised candidate sets, an APoT
//! layer must pin the tile width (its f32-accumulating baseline core is
//! only deterministic for a fixed tile), and repeated builds in one
//! process must agree (the per-process cache). All assertions here are
//! robust to `RMSMP_NO_TUNE=1` in the environment — under the escape
//! hatch the "tuned" plan degenerates to the defaults, which satisfy
//! every membership and equality check below.

use std::sync::Arc;

use rmsmp::gemm::{
    PackedWeights, ParallelConfig, SortedWeights, TuneSource, DEFAULT_MIN_ROWS_PER_TASK,
    DEFAULT_PANEL_BYTES, DEFAULT_TILE_COLS,
};
use rmsmp::model::manifest::Manifest;
use rmsmp::model::weights::{LayerWeights, ModelWeights};
use rmsmp::model::{Executor, Plan};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::{self, Mat, Scheme};
use rmsmp::util::json::Json;
use rmsmp::util::rng::Rng;

fn layer(
    name: &str,
    kind: &str,
    w: Mat,
    conv: (usize, usize, usize, usize),
    stride: usize,
    pad: usize,
    schemes: Vec<Scheme>,
    bias: Vec<f32>,
) -> LayerWeights {
    let alpha: Vec<f32> = (0..w.rows).map(|r| quant::default_alpha(w.row(r))).collect();
    let packed = PackedWeights::quantize(&w, &schemes, &alpha);
    let sorted = SortedWeights::from_packed(&packed);
    LayerWeights {
        name: name.into(),
        kind: kind.into(),
        rows: w.rows,
        cols: w.cols,
        out_ch: conv.0,
        in_ch: conv.1,
        kh: conv.2,
        kw: conv.3,
        stride,
        pad,
        groups: 1,
        a_alpha: 1.0,
        scheme: schemes,
        alpha,
        bias,
        w: Some(w),
        packed,
        sorted,
    }
}

/// conv(3x3 s1 p1, relu) -> gap -> fc. With `apot` false every row uses
/// an integer-accumulating scheme, so logits are tile-independent and
/// the tuned-vs-default comparison below is exact by construction.
fn model(apot: bool) -> (Manifest, ModelWeights, Tensor4) {
    let (n, c_in, hw, c1, classes) = (2usize, 3usize, 6usize, 8usize, 4usize);
    let cc = c_in * 9;
    let mut rng = Rng::new(if apot { 11 } else { 10 });
    let pool: [Scheme; 3] = [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];
    let mut schemes: Vec<Scheme> = (0..c1).map(|r| pool[r % 3]).collect();
    if apot {
        schemes[0] = Scheme::ApotW4A4;
    }
    let w1 = Mat::from_vec(c1, cc, rng.normal_vec(c1 * cc, 0.5));
    let b1: Vec<f32> = (0..c1).map(|_| rng.normal() * 0.1).collect();
    let layers = vec![
        layer("c1", "conv", w1, (c1, c_in, 3, 3), 1, 1, schemes, b1),
        layer(
            "fc",
            "linear",
            Mat::from_vec(classes, c1, rng.normal_vec(classes * c1, 0.5)),
            (classes, c1, 1, 1),
            0,
            0,
            (0..classes).map(|r| pool[r % 3]).collect(),
            (0..classes).map(|_| rng.normal() * 0.1).collect(),
        ),
    ];
    let json = format!(
        r#"{{"model":"tune","arch":"resnet","num_classes":{classes},
            "input_shape":[{n},{c_in},{hw},{hw}],"ratio":[65,30,5],"act_bits":4,
            "layers":[
              {{"name":"c1","kind":"conv","rows":{c1},"cols":{cc},"stride":1,"pad":1,
               "groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}},
              {{"name":"fc","kind":"linear","rows":{classes},"cols":{c1},"stride":0,"pad":0,
               "groups":1,"a_alpha":1.0,"scheme_counts":[0,0,0,0]}}],
            "program":[
              {{"op":"conv","layer":"c1","in":"in0","out":"b0","relu":true}},
              {{"op":"gap","in":"b0","out":"g0"}},
              {{"op":"linear","layer":"fc","in":"g0","out":"logits"}}]}}"#
    );
    let manifest = Manifest::from_json(&Json::parse(&json).unwrap()).unwrap();
    let mut x = Tensor4::zeros(n, c_in, hw, hw);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.2);
    }
    (manifest, ModelWeights { layers }, x)
}

fn logits(manifest: &Manifest, weights: &ModelWeights, plan: Plan, x: &Tensor4) -> Vec<f32> {
    let mut exec = Executor::from_shared(
        Arc::new(manifest.clone()),
        Arc::new(weights.clone()),
        Arc::new(plan),
        ParallelConfig::sequential(),
        None,
    )
    .unwrap();
    exec.infer(x).unwrap().data.clone()
}

#[test]
fn no_tune_builder_compiles_with_the_fixed_defaults() {
    let (manifest, weights, _) = model(false);
    let plan = Plan::builder(&manifest, &weights).capacity(2).no_tune().build().unwrap();
    assert_eq!(plan.tuned.source, TuneSource::Defaults);
    assert_eq!(plan.cfg.tile_cols, DEFAULT_TILE_COLS);
    assert_eq!(plan.cfg.min_rows_per_task, DEFAULT_MIN_ROWS_PER_TASK);
    assert_eq!(plan.tuned.panel_bytes, DEFAULT_PANEL_BYTES);
    // deterministic twin of RMSMP_NO_TUNE=1: two builds agree exactly
    let again = Plan::builder(&manifest, &weights).capacity(2).no_tune().build().unwrap();
    assert_eq!(plan.tuned, again.tuned);
    assert_eq!(plan.cfg.tile_cols, again.cfg.tile_cols);
}

#[test]
fn tuned_and_default_plans_produce_bit_identical_logits() {
    // Integer accumulation is tile-independent, panel width and chunk
    // granularity only reshape the schedule — so whatever the tuner
    // picked, the logits must not move by even one ulp.
    let (manifest, weights, x) = model(false);
    let tuned = Plan::builder(&manifest, &weights).capacity(2).build().unwrap();
    let fixed =
        Plan::builder(&manifest, &weights).capacity(2).no_tune().build().unwrap();
    let got = logits(&manifest, &weights, tuned, &x);
    let want = logits(&manifest, &weights, fixed, &x);
    assert_eq!(got, want, "autotuned plan changed the logits");
}

#[test]
fn tuned_knobs_are_members_of_the_candidate_sets() {
    let (manifest, weights, _) = model(false);
    let plan = Plan::builder(&manifest, &weights).capacity(2).build().unwrap();
    assert!(
        [64, 128, 256, 512].contains(&plan.cfg.tile_cols),
        "tile_cols {} not a tuner candidate",
        plan.cfg.tile_cols
    );
    assert!(
        [4, 8, 16].contains(&plan.cfg.min_rows_per_task),
        "min_rows_per_task {} not a tuner candidate",
        plan.cfg.min_rows_per_task
    );
    assert!(
        [16 * 1024, 32 * 1024, 64 * 1024].contains(&plan.tuned.panel_bytes),
        "panel_bytes {} not a tuner candidate",
        plan.tuned.panel_bytes
    );
    assert!(
        [4, 6, 8].contains(&plan.cfg.micro_rows),
        "micro_rows {} not a tuner candidate",
        plan.cfg.micro_rows
    );
    assert_eq!(plan.layer_tuned.len(), 2, "one tuned entry per weights layer");
    for t in &plan.layer_tuned {
        assert!(
            [4, 6, 8].contains(&t.micro_rows),
            "layer micro_rows {} not a tuner candidate",
            t.micro_rows
        );
        assert!(
            t.tile_cols == 0 || t.tile_cols >= 48,
            "layer tile_cols {} below any candidate",
            t.tile_cols
        );
    }
}

#[test]
fn repeated_tuned_builds_agree_via_the_process_cache() {
    let (manifest, weights, x) = model(false);
    let a = Plan::builder(&manifest, &weights).capacity(2).build().unwrap();
    let b = Plan::builder(&manifest, &weights).capacity(2).build().unwrap();
    assert_eq!(a.tuned, b.tuned, "same model, same process, different tuning");
    let la = logits(&manifest, &weights, a, &x);
    let lb = logits(&manifest, &weights, b, &x);
    assert_eq!(la, lb);
}

#[test]
fn apot_rows_pin_the_tile_width() {
    // The APoT baseline core accumulates in f32, so its output depends
    // on the tile split; the builder must keep the configured tile when
    // any row uses it — tuned and default plans then stay bit-identical
    // even for APoT models.
    let (manifest, weights, x) = model(true);
    let plan = Plan::builder(&manifest, &weights).capacity(2).build().unwrap();
    assert_eq!(plan.cfg.tile_cols, DEFAULT_TILE_COLS, "APoT model's tile moved");
    let fixed =
        Plan::builder(&manifest, &weights).capacity(2).no_tune().build().unwrap();
    let got = logits(&manifest, &weights, plan, &x);
    let want = logits(&manifest, &weights, fixed, &x);
    assert_eq!(got, want, "tuning changed an APoT model's logits");
}

#[test]
fn describe_reports_the_resolved_kernel_parameters() {
    let (manifest, weights, _) = model(false);
    let plan = Plan::builder(&manifest, &weights).capacity(2).build().unwrap();
    let desc = plan.describe(&weights, 1);
    assert!(desc.contains("kernels: isa"), "describe missing kernel line:\n{desc}");
    assert!(
        desc.contains(plan.tuned.source.name()),
        "describe missing tuning source:\n{desc}"
    );
    assert!(
        desc.contains(&format!("tile cols {}", plan.cfg.tile_cols)),
        "describe missing tile cols:\n{desc}"
    );
    // the per-layer knob table with its cache-provenance header
    assert!(desc.contains("layer knobs ("), "describe missing layer knobs:\n{desc}");
    for lw in &weights.layers {
        assert!(
            desc.contains(lw.name.as_str()),
            "describe missing layer {}:\n{desc}",
            lw.name
        );
    }
}
