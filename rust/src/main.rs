//! `rmsmp` — the L3 coordinator binary.
//!
//! Subcommands:
//!   info       artifact + model summary (layers, schemes, sizes)
//!   plan       print the compiled execution plan (slots, ops, footprint)
//!   infer      run integer inference on synthetic images, report logits
//!   parity     integer executor vs recorded JAX logits
//!   serve      dynamic-batching serving loop: synthetic Poisson workload,
//!              or a real HTTP/1.1 front-end with `--http ADDR`; add
//!              `--models a.rmsa,b.rmsa` for multi-model resident serving
//!   pack       convert manifest.json + weights.bin into one mmap-ready
//!              `.rmsa` artifact (see `rmsmp::model::artifact`)
//!   simulate   FPGA resource/cycle simulation for a quantization config
//!   assign     re-assign schemes under a new ratio and report the split
//!
//! Execution flags shared by infer/parity/serve: `--threads N` (0 = one
//! per core, 1 = sequential) and `--tile COLS` size the parallel mixed
//! GEMM; see the library docs for the execution model.
//!
//! Table/figure regeneration lives in the `table` binary (`cargo run
//! --release --bin table -- <n>`).

use std::path::{Path, PathBuf};

use rmsmp::bail;
use rmsmp::coordinator::batcher::BatchPolicy;
use rmsmp::coordinator::{HttpConfig, HttpServer, OpenLoopGen, Router, Server, ServerConfig};
use rmsmp::fpga::{simulate, Board, CoreCosts, Design, QuantConfig};
use rmsmp::model::{Manifest, ModelWeights};
use rmsmp::quant::tensor::Tensor4;
use rmsmp::quant::Ratio;
use rmsmp::runtime::{artifacts_dir, Runtime};
use rmsmp::util::cli::{help, Args, FlagSpec};
use rmsmp::util::error::{Context, Result};
use rmsmp::util::rng::Rng;
use rmsmp::{err, ParallelConfig};

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec {
            name: "artifacts",
            help: "artifacts directory",
            default: Some("artifacts"),
            takes_value: true,
        },
        FlagSpec {
            name: "ratio",
            help: "PoT4:Fixed4:Fixed8 ratio",
            default: Some("65:30:5"),
            takes_value: true,
        },
        FlagSpec {
            name: "board",
            help: "FPGA board (XC7Z020|XC7Z045)",
            default: Some("XC7Z045"),
            takes_value: true,
        },
        FlagSpec {
            name: "batch",
            help: "inference batch size",
            default: Some("4"),
            takes_value: true,
        },
        FlagSpec {
            name: "threads",
            help: "GEMM worker threads (0 = one per core, 1 = sequential)",
            default: Some("0"),
            takes_value: true,
        },
        FlagSpec {
            name: "tile",
            help: "GEMM column tile size (0 = untiled)",
            default: Some("256"),
            takes_value: true,
        },
        FlagSpec {
            name: "requests",
            help: "serve: number of requests",
            default: Some("64"),
            takes_value: true,
        },
        FlagSpec {
            name: "rate",
            help: "serve: arrival rate (req/s)",
            default: Some("50"),
            takes_value: true,
        },
        FlagSpec {
            name: "workers",
            help: "serve: worker threads",
            default: Some("1"),
            takes_value: true,
        },
        FlagSpec {
            name: "max-batch",
            help: "serve: dynamic batch cap",
            default: Some("8"),
            takes_value: true,
        },
        FlagSpec {
            name: "max-wait-ms",
            help: "serve: batch deadline",
            default: Some("2"),
            takes_value: true,
        },
        FlagSpec {
            name: "http",
            help: "serve: HTTP/1.1 bind address (e.g. 127.0.0.1:8080); \
                   omit for the synthetic open-loop run",
            default: None,
            takes_value: true,
        },
        FlagSpec {
            name: "http-threads",
            help: "serve: connection-handler threads (0 = 4x cores)",
            default: Some("0"),
            takes_value: true,
        },
        FlagSpec {
            name: "models",
            help: "serve: comma-separated .rmsa artifacts to serve side by \
                   side (requires --http; routes on the request's model field)",
            default: None,
            takes_value: true,
        },
        FlagSpec {
            name: "out",
            help: "pack: output .rmsa path (default: <artifacts>/model.rmsa)",
            default: None,
            takes_value: true,
        },
        FlagSpec {
            name: "no-tune",
            help: "plan: skip the load-time autotuner (fixed default blocking)",
            default: None,
            takes_value: false,
        },
        FlagSpec {
            name: "tune-cache",
            help: "plan: persist/reuse autotune winners at PATH \
                   (default: $RMSMP_TUNE_CACHE)",
            default: None,
            takes_value: true,
        },
        FlagSpec {
            name: "first-last-8bit",
            help: "simulate: 8-bit first/last layers",
            default: None,
            takes_value: false,
        },
        FlagSpec {
            name: "apot",
            help: "simulate: APoT nonlinear core (MSQ)",
            default: None,
            takes_value: false,
        },
        FlagSpec {
            name: "imagenet",
            help: "simulate: paper's ResNet-18/224 layer table",
            default: None,
            takes_value: false,
        },
        FlagSpec { name: "seed", help: "PRNG seed", default: Some("0"), takes_value: true },
        FlagSpec { name: "help", help: "show help", default: None, takes_value: false },
    ]
}

fn parallel_cfg(args: &Args) -> Result<ParallelConfig> {
    Ok(ParallelConfig {
        threads: args.get_usize("threads", 0)?,
        tile_cols: args.get_usize("tile", 256)?,
        ..ParallelConfig::default()
    })
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &flag_specs())?;
    if args.has("help") || args.positional.is_empty() {
        print!(
            "{}",
            help(
                "rmsmp",
                "row-wise mixed-scheme multi-precision quantized inference",
                &flag_specs()
            )
        );
        println!("\nSubcommands: info | plan | infer | parity | serve | pack | simulate | assign");
        return Ok(());
    }
    let artifacts = PathBuf::from(args.get_or("artifacts", artifacts_dir().to_str().unwrap()));
    match args.positional[0].as_str() {
        "info" => cmd_info(&artifacts),
        "plan" => cmd_plan(&artifacts, &args),
        "infer" => cmd_infer(&artifacts, &args),
        "parity" => cmd_parity(&artifacts, &args),
        "serve" => cmd_serve(&artifacts, &args),
        "pack" => cmd_pack(&artifacts, &args),
        "simulate" => cmd_simulate(&args),
        "assign" => cmd_assign(&artifacts, &args),
        other => bail!("unknown subcommand {other:?} (see --help)"),
    }
}

fn load_artifacts(dir: &Path) -> Result<(Manifest, ModelWeights)> {
    let manifest = Manifest::load(&dir.join("manifest.json"))
        .context("loading manifest (run `make artifacts` first)")?;
    let weights = ModelWeights::load(&dir.join("weights.bin"))?;
    Ok((manifest, weights))
}

fn cmd_info(dir: &Path) -> Result<()> {
    let (m, w) = load_artifacts(dir)?;
    println!(
        "model {} ({}) classes={} input={:?} ratio={}",
        m.model, m.arch, m.num_classes, m.input_shape, m.ratio
    );
    println!(
        "{:<16} {:>6} {:>7} {:>8}  scheme counts [PoT4,F4,F8,APoT]",
        "layer", "rows", "cols", "kind"
    );
    for l in &m.layers {
        println!(
            "{:<16} {:>6} {:>7} {:>8}  {:?}",
            l.name, l.rows, l.cols, l.kind, l.scheme_counts
        );
    }
    println!(
        "float {} KiB -> quantized {} KiB ({:.2}x compression)",
        w.float_bytes() / 1024,
        w.quantized_bytes() / 1024,
        w.float_bytes() as f64 / w.quantized_bytes() as f64
    );
    Ok(())
}

fn cmd_plan(dir: &Path, args: &Args) -> Result<()> {
    use rmsmp::model::Plan;

    let (m, w) = load_artifacts(dir)?;
    let cfg = parallel_cfg(args)?;
    let capacity = args.get_usize("batch", m.input_shape.first().copied().unwrap_or(1))?;
    let mut b = Plan::builder(&m, &w).capacity(capacity).config(&cfg);
    if args.has("no-tune") {
        b = b.no_tune();
    }
    let cache = args.get_or("tune-cache", "");
    if !cache.is_empty() {
        b = b.tune_cache(cache);
    }
    let plan = b.build()?;
    print!("{}", plan.describe(&w, cfg.lanes()));
    Ok(())
}

fn cmd_infer(dir: &Path, args: &Args) -> Result<()> {
    let (m, w) = load_artifacts(dir)?;
    let batch = args.get_usize("batch", 4)?;
    let (c, h, wd) = (m.input_shape[1], m.input_shape[2], m.input_shape[3]);
    let rt = Runtime::new(parallel_cfg(args)?);
    let mut exec = rt.executor(m, w)?;
    let mut rng = Rng::new(args.get_usize("seed", 0)? as u64);
    let mut x = Tensor4::zeros(batch, c, h, wd);
    for v in x.data.iter_mut() {
        *v = rng.uniform(0.0, 1.0);
    }
    let t0 = std::time::Instant::now();
    let logits = exec.infer(&x)?.clone();
    let dt = t0.elapsed();
    println!(
        "integer inference: batch={batch} threads={} in {:.1}ms ({:.2}ms/img, {} MMACs)",
        rt.threads(),
        dt.as_secs_f64() * 1e3,
        dt.as_secs_f64() * 1e3 / batch as f64,
        exec.macs / 1_000_000
    );
    for b in 0..batch.min(4) {
        let row = logits.row(b);
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("  img{b}: argmax={argmax} logits[..4]={:?}", &row[..row.len().min(4)]);
    }
    Ok(())
}

fn cmd_parity(dir: &Path, args: &Args) -> Result<()> {
    use rmsmp::util::json::Json;

    let (m, w) = load_artifacts(dir)?;
    let parity = Json::load(&dir.join("parity.json"))?;
    let input = parity.get("input")?.as_f32_vec()?;
    let shape = parity.get("input_shape")?.as_usize_vec()?;
    let want = parity.get("logits")?.as_f32_vec()?;

    // integer executor vs recorded JAX logits (the HLO-artifact leg runs
    // on the Python side now that the build carries no PJRT backend)
    let rt = Runtime::new(parallel_cfg(args)?);
    let mut exec = rt.executor(m, w)?;
    let mut x = Tensor4::zeros(shape[0], shape[1], shape[2], shape[3]);
    x.data.copy_from_slice(&input);
    let got = exec.infer(&x)?;
    let max_err = got
        .data
        .iter()
        .zip(&want)
        .fold(0.0f32, |e, (a, b)| e.max((a - b).abs()));
    let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
    println!("integer-vs-jax: max |err| = {max_err:.5} (rel {:.4})", max_err / scale);
    println!("(hlo-vs-jax parity runs in Python: `python -m compile.aot --check`)");
    rmsmp::ensure!(max_err / scale < 0.05, "integer parity failure");
    println!("parity OK");
    Ok(())
}

fn cmd_serve(dir: &Path, args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 64)?;
    let rate = args.get_f64("rate", 50.0)?;
    let cfg = ServerConfig {
        workers: args.get_usize("workers", 1)?,
        policy: BatchPolicy {
            max_batch: args.get_usize("max-batch", 8)?,
            max_wait: std::time::Duration::from_millis(args.get_usize("max-wait-ms", 2)? as u64),
            queue_cap: 1024,
        },
        parallel: parallel_cfg(args)?,
    };
    let http_addr = args.get_or("http", "");

    // --models a.rmsa,b.rmsa: multi-model resident serving. Each `.rmsa`
    // is mmap-loaded (zero-copy weight planes share the page cache), the
    // variants share one GEMM thread pool via the Router, and requests
    // route on their `model` field (unknown model -> 404).
    let models_arg = args.get_or("models", "");
    if !models_arg.is_empty() {
        rmsmp::ensure!(!http_addr.is_empty(), "--models requires --http ADDR");
        let mut models = Vec::new();
        for path in models_arg.split(',').filter(|s| !s.is_empty()) {
            let (m, w) = rmsmp::model::artifact::load(Path::new(path))
                .with_context(|| format!("loading artifact {path}"))?;
            println!("resident model {:?} from {path} ({} layers)", m.model, m.layers.len());
            models.push((m.model.clone(), m, w, cfg.clone()));
        }
        let router = Router::start(models)?;
        let http = HttpServer::start_router(
            router,
            HttpConfig {
                addr: http_addr,
                conn_threads: args.get_usize("http-threads", 0)?,
                ..HttpConfig::default()
            },
        )?;
        println!("serving HTTP on http://{}", http.addr());
        println!("  POST /v1/infer {{\"model\": \"name\", \"input\": [...]}}");
        println!("  GET  /metrics | /healthz");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(30));
            println!("{}", http.summary());
        }
    }

    let (m, w) = load_artifacts(dir)?;
    let image_len = m.input_shape[1] * m.input_shape[2] * m.input_shape[3];
    let server = Server::start(m, w, cfg)?;

    // --http ADDR: real-socket front-end instead of the synthetic
    // open-loop trace; runs until the process is killed
    if !http_addr.is_empty() {
        let http = HttpServer::start(
            server,
            HttpConfig {
                addr: http_addr,
                conn_threads: args.get_usize("http-threads", 0)?,
                ..HttpConfig::default()
            },
        )?;
        println!("serving HTTP on http://{}", http.addr());
        println!("  POST /v1/infer {{\"input\": [...], \"deadline_ms\": 50}}");
        println!("  GET  /metrics | /healthz");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(30));
            println!("{}", http.summary());
        }
    }

    let mut gen = OpenLoopGen::new(args.get_usize("seed", 0)? as u64, rate, image_len);
    let trace = gen.trace(n);

    println!("serving {n} requests at {rate} req/s (open loop)...");
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n);
    for ev in &trace {
        let target = std::time::Duration::from_secs_f64(ev.at_s);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        match server.submit(ev.image.clone()) {
            Ok(rx) => receivers.push(rx),
            Err(e) => println!("  rejected: {e:?}"),
        }
    }
    for rx in receivers {
        let _ = rx.recv();
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("done in {wall:.2}s -> {:.1} req/s", n as f64 / wall);
    println!("{}", server.metrics.summary());
    server.shutdown();
    Ok(())
}

fn cmd_pack(dir: &Path, args: &Args) -> Result<()> {
    let manifest_path = dir.join("manifest.json");
    let manifest_json = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {manifest_path:?} (run `make artifacts` first)"))?;
    let weights = ModelWeights::load(&dir.join("weights.bin"))?;
    let out = match args.get_or("out", "") {
        s if s.is_empty() => dir.join("model.rmsa"),
        s => PathBuf::from(s),
    };
    rmsmp::model::artifact::pack_to_file(&manifest_json, &weights, &out)?;
    let size = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!("packed {} layers -> {out:?} ({} KiB)", weights.layers.len(), size / 1024);
    println!("serve it with: rmsmp serve --http 127.0.0.1:8080 --models {}", out.display());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let board = Board::by_name(&args.get_or("board", "XC7Z045"))
        .ok_or_else(|| err!("unknown board"))?;
    let ratio = Ratio::parse(&args.get_or("ratio", "65:30:5"))?;
    let qc = QuantConfig {
        ratio,
        first_last_8bit: args.has("first-last-8bit"),
        apot: args.has("apot"),
    };
    let design = Design::allocate(board, qc, CoreCosts::default());
    let layers = rmsmp::fpga::sim::resnet18_imagenet_layers();
    let r = simulate(&design, &layers);
    println!(
        "board {} ratio {} first/last-8bit={} apot={}",
        board.name, ratio, qc.first_last_8bit, qc.apot
    );
    println!(
        "  PEs: pot={:.0} fixed4={:.0} fixed8={:.0}",
        design.pot_pes, design.fixed4_pes, design.fixed8_pes
    );
    println!(
        "  LUT {:.0}%  DSP {:.0}%  throughput {:.1} GOP/s  latency {:.1} ms",
        100.0 * r.lut_util,
        100.0 * r.dsp_util,
        r.gops,
        r.latency_ms
    );
    Ok(())
}

fn cmd_assign(dir: &Path, args: &Args) -> Result<()> {
    use rmsmp::assign::{assign_layer, equivalent_bits, Sensitivity};
    use rmsmp::quant::Scheme;

    let (_, w) = load_artifacts(dir)?;
    let ratio = Ratio::parse(&args.get_or("ratio", "65:30:5"))?;
    println!("re-assigning under ratio {ratio} (weight-norm sensitivity):");
    let mut total_bits = 0.0;
    let mut total_rows = 0usize;
    for l in &w.layers {
        let lw = l
            .w
            .as_ref()
            .ok_or_else(|| err!("layer {}: no float weights (artifact load path)", l.name))?;
        let s = assign_layer(lw, ratio, Sensitivity::WeightNorm, Scheme::PotW4A4);
        let pot = s.iter().filter(|&&x| x == Scheme::PotW4A4).count();
        let f4 = s.iter().filter(|&&x| x == Scheme::FixedW4A4).count();
        let f8 = s.iter().filter(|&&x| x == Scheme::FixedW8A4).count();
        println!("  {:<16} rows={:<4} -> PoT4={pot} F4={f4} F8={f8}", l.name, l.rows);
        total_bits += equivalent_bits(&s, l.cols) * l.rows as f64;
        total_rows += l.rows;
    }
    println!("equivalent weight precision: {:.3} bits", total_bits / total_rows as f64);
    Ok(())
}
