//! Native execution runtime: the parallel substrate shared by everything
//! that runs inference.
//!
//! Owns the resolved [`ParallelConfig`] and (when it resolves to more
//! than one thread) the process-wide [`ThreadPool`] that the parallel
//! mixed GEMM fans row chunks out onto. Executors built here run the
//! compiled-plan path: [`Runtime::executor`] compiles the model's plan
//! and preallocates its workspace, [`Runtime::executor_shared`] reuses
//! an already-compiled plan across workers. One pool serves every model
//! instance instead of each spawning its own threads.
//!
//! Historical note: this module used to wrap PJRT via the external `xla`
//! crate to execute AOT HLO artifacts. The build is offline and
//! zero-dependency, so the float-reference parity against the HLO
//! artifacts now lives on the Python side (`python -m compile.aot`);
//! `rmsmp parity` checks the integer executor against the recorded JAX
//! logits directly.

use std::path::PathBuf;
use std::sync::Arc;

use crate::gemm::ParallelConfig;
use crate::model::{Executor, Manifest, ModelWeights};
use crate::util::error::Result;
use crate::util::pool::ThreadPool;

/// Process-wide execution context: config + shared thread pool.
pub struct Runtime {
    cfg: ParallelConfig,
    pool: Option<Arc<ThreadPool>>,
}

impl Runtime {
    /// Build a runtime; spawns a pool when `cfg` resolves to >1 thread.
    pub fn new(cfg: ParallelConfig) -> Runtime {
        let threads = cfg.resolved_threads();
        let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        Runtime { cfg, pool }
    }

    /// Single-threaded runtime (the seed's behaviour).
    pub fn sequential() -> Runtime {
        Runtime::new(ParallelConfig::sequential())
    }

    pub fn config(&self) -> ParallelConfig {
        self.cfg
    }

    /// Worker threads backing the GEMM (1 = sequential).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads())
    }

    /// Handle to the shared pool, if any.
    pub fn pool(&self) -> Option<Arc<ThreadPool>> {
        self.pool.clone()
    }

    /// Build an integer executor wired to this runtime's pool + config:
    /// compiles the manifest's program into a [`crate::model::Plan`] and
    /// preallocates the executor's [`crate::model::Workspace`], so the
    /// returned executor runs the compiled plan-based path.
    pub fn executor(&self, manifest: Manifest, weights: ModelWeights) -> Result<Executor> {
        Executor::with_parallel(manifest, weights, self.cfg, self.pool())
    }

    /// Plan-based executor over already-shared model state (see
    /// [`Executor::from_shared`]): the multi-worker entry point — one
    /// weights/plan allocation, one private workspace per executor.
    pub fn executor_shared(
        &self,
        manifest: std::sync::Arc<Manifest>,
        weights: std::sync::Arc<ModelWeights>,
        plan: std::sync::Arc<crate::model::Plan>,
    ) -> Result<Executor> {
        Executor::from_shared(manifest, weights, plan, self.cfg, self.pool())
    }
}

/// Locate the artifacts directory: $RMSMP_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RMSMP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_runtime_has_no_pool() {
        let rt = Runtime::sequential();
        assert_eq!(rt.threads(), 1);
        assert!(rt.pool().is_none());
    }

    #[test]
    fn explicit_thread_count_spawns_pool() {
        let rt = Runtime::new(ParallelConfig { threads: 3, ..ParallelConfig::default() });
        assert_eq!(rt.threads(), 3);
        assert!(rt.pool().is_some());
        // shared handles point at the same pool
        let a = rt.pool().unwrap();
        let b = rt.pool().unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn auto_threads_resolve_to_at_least_one() {
        let cfg = ParallelConfig::default();
        assert!(cfg.resolved_threads() >= 1);
    }
}
