//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client (the float reference path next to the integer executor).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`, with an
//! executable cache keyed by artifact path. HLO *text* is the interchange
//! format (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids — see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

/// The PJRT CPU runtime with a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, usize>>,
    executables: Mutex<Vec<std::sync::Arc<Executable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            executables: Mutex::new(Vec::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO text artifact (cached by path).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<Executable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(&i) = cache.get(path) {
                return Ok(std::sync::Arc::clone(&self.executables.lock().unwrap()[i]));
            }
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let arc = std::sync::Arc::new(Executable { exe, path: path.to_path_buf() });
        let mut exes = self.executables.lock().unwrap();
        exes.push(std::sync::Arc::clone(&arc));
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exes.len() - 1);
        Ok(arc)
    }
}

impl Executable {
    /// Execute with f32 inputs of the given shapes; returns the flat f32
    /// outputs of the (single-tuple) result.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims).context("reshaping input literal")?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // jax lowering uses return_tuple=True -> 1-tuple
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        out.to_vec::<f32>().context("reading f32 output")
    }

    /// Execute with mixed f32/i32 inputs (the standalone GEMM artifact
    /// takes an i32 scheme vector).
    pub fn run_mixed(&self, inputs: &[ArtifactInput<'_>]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = match inp {
                ArtifactInput::F32(data, shape) => {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                ArtifactInput::I32(data, shape) => {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        out.to_vec::<f32>().context("reading f32 output")
    }
}

/// Typed input for [`Executable::run_mixed`].
pub enum ArtifactInput<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// Locate the artifacts directory: $RMSMP_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RMSMP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
