//! `table` — regenerate the paper's tables/figures.
//!
//! Usage: `cargo run --release --bin table -- <2|3|4|6|fig3|all>`
//!
//! * Tables 2-4 (SOTA comparisons): the cited methods' rows are the
//!   papers' published numbers (constants, as in the paper itself); our
//!   rows are measured on the substituted workloads and read from
//!   `results/table1.json` when present (run
//!   `python -m compile.experiments table1` first), with the accuracy
//!   *delta vs our baseline* shown so the shape is comparable.
//! * Table 6 (FPGA): every row is simulated by `rmsmp::fpga` next to the
//!   paper's measured value.
//! * fig3 renders `results/fig3.json` as text series.
//!
//! Accuracy shape note: absolute top-1 values are not comparable across
//! the substituted datasets; deltas and orderings are.

use std::path::Path;

use rmsmp::bail;
use rmsmp::fpga::{simulate, Board, CoreCosts, Design, QuantConfig};
use rmsmp::quant::Ratio;
use rmsmp::util::error::Result;
use rmsmp::util::json::Json;

fn main() -> Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".to_string());
    match which.as_str() {
        "2" => table_sota(2),
        "3" => table_sota(3),
        "4" => table_sota(4),
        "6" => table6(),
        "fig3" => fig3()?,
        "all" => {
            table_sota(2);
            table_sota(3);
            table_sota(4);
            table6();
            fig3()?;
        }
        other => bail!("unknown table {other:?} (want 2|3|4|6|fig3|all)"),
    }
    Ok(())
}

/// Published rows of Tables 2-4: (method, approach, bits, top1, top5).
type SotaRow = (&'static str, &'static str, &'static str, f64, f64);

fn cited(table: usize) -> (&'static str, Vec<SotaRow>) {
    match table {
        2 => (
            "ResNet-18 on ImageNet",
            vec![
                ("Baseline", "-", "W32A32", 70.25, 89.48),
                ("Dorefa", "Linear", "W4A4", 68.10, 88.10),
                ("PACT", "Linear", "W4A4", 69.20, 89.00),
                ("DSQ", "Linear", "W4A4", 69.56, f64::NAN),
                ("QIL", "Linear", "W4A4", 70.10, f64::NAN),
                ("uL2Q", "Linear", "W4A4", 65.92, 86.72),
                ("APoT", "Non-Lin.", "W4A4", 70.70, 89.60),
                ("LQ-Nets", "Non-Lin.", "W4A4", 69.30, 88.80),
                ("DNAS", "MP-Lin.", "Mixed", 70.64, f64::NAN),
                ("MPDNN", "MP-Lin.", "Mixed", 70.08, f64::NAN),
                ("MSQ", "MS", "W4A4", 70.27, 89.42),
                ("RMSMP (paper)", "MP-MS", "W4A4*", 70.73, 89.62),
            ],
        ),
        3 => (
            "ResNet-50 on ImageNet",
            vec![
                ("Baseline", "-", "W32A32", 76.51, 93.09),
                ("Dorefa", "Linear", "W4A4", 71.40, 88.10),
                ("PACT", "Linear", "W4A4", 76.50, 93.30),
                ("APoT", "Non-Lin.", "W4A4", 76.60, 93.10),
                ("LQ-Nets", "Non-Lin.", "W4A4", 75.40, 92.40),
                ("HAQ", "MP-Lin.", "Mixed", 76.15, 92.89),
                ("MSQ", "MS", "W4A4", 76.22, 92.86),
                ("RMSMP (paper)", "MP-MS", "W4A4*", 76.62, 93.36),
            ],
        ),
        4 => (
            "MobileNet-V2 on ImageNet",
            vec![
                ("Baseline", "-", "W32A32", 71.88, 90.29),
                ("PACT", "Linear", "W4A4", 61.40, f64::NAN),
                ("DSQ", "Non-Lin.", "W4A4", 64.80, f64::NAN),
                ("HAQ", "MP-Lin.", "Mixed", 67.01, 87.46),
                ("MSQ", "MS", "W4A4", 68.99, 88.04),
                ("RMSMP (paper)", "MP-MS", "W4A4*", 69.02, 89.07),
            ],
        ),
        _ => unreachable!(),
    }
}

fn measured_rows(model: &str) -> Option<(f64, f64)> {
    // (baseline acc, rmsmp acc) from results/table1.json for this model
    let j = Json::load(Path::new("results/table1.json")).ok()?;
    let obj = j.as_obj().ok()?;
    let (_, row) = obj.iter().find(|(k, _)| k.starts_with(model))?;
    let base = row.get("Baseline (W32A32)").ok()?.as_f64().ok()?;
    let rmsmp = row.get("RMSMP (65:30:5)").ok()?.as_f64().ok()?;
    Some((base * 100.0, rmsmp * 100.0))
}

fn table_sota(n: usize) {
    let (title, rows) = cited(n);
    println!("\n=== Table {n} — {title} (equivalent 4-bit) ===");
    println!(
        "{:<16} {:<9} {:<8} {:>7} {:>7}",
        "method", "approach", "bits", "top-1", "top-5"
    );
    for (m, a, b, t1, t5) in &rows {
        let t5s = if t5.is_nan() {
            "    N/A".to_string()
        } else {
            format!("{t5:>7.2}")
        };
        println!("{m:<16} {a:<9} {b:<8} {t1:>7.2} {t5s}");
    }
    let model = match n {
        2 => "resnet18",
        3 => "resnet50",
        _ => "mobilenetv2",
    };
    match measured_rows(model) {
        Some((base, rmsmp)) => {
            println!("--- measured on substituted workload (results/table1.json) ---");
            println!(
                "{:<16} {:<9} {:<8} {:>7.2}   (delta vs our baseline: {:+.2})",
                "RMSMP (ours)",
                "MP-MS",
                "W4A4*",
                rmsmp,
                rmsmp - base
            );
            let paper_delta = rows.last().unwrap().3 - rows[0].3;
            println!("paper delta vs baseline: {paper_delta:+.2} (shape check: ~0 or positive)");
        }
        None => {
            println!("(run `python -m compile.experiments table1 --models {model}` for this row)")
        }
    }
}

/// One Table 6 row: config + the paper's measured numbers for comparison.
struct T6Row {
    label: &'static str,
    board: Board,
    ratio: (u32, u32, u32),
    first_last_8bit: bool,
    apot: bool,
    paper: (f64, f64, f64, f64), // LUT%, DSP%, GOP/s, ms
}

#[allow(clippy::fn_params_excessive_bools)]
fn t6(
    label: &'static str,
    board: Board,
    ratio: (u32, u32, u32),
    first_last_8bit: bool,
    apot: bool,
    paper: (f64, f64, f64, f64),
) -> T6Row {
    T6Row { label, board, ratio, first_last_8bit, apot, paper }
}

fn table6() {
    let z20 = Board::XC7Z020;
    let z45 = Board::XC7Z045;
    let rows = [
        t6("(1) Fixed, 8b f/l", z20, (0, 100, 0), true, false, (26.0, 100.0, 29.6, 122.6)),
        t6("(2) Fixed", z20, (0, 100, 0), false, false, (23.0, 100.0, 36.5, 99.3)),
        t6("(3) PoT, 8b f/l", z20, (100, 0, 0), true, false, (41.0, 100.0, 62.4, 58.1)),
        t6("(4) PoT", z20, (100, 0, 0), false, false, (43.0, 12.0, 72.2, 50.2)),
        t6("(5) PoT+Fixed, 8b f/l", z20, (50, 50, 0), true, false, (50.0, 100.0, 50.3, 72.0)),
        t6("(6) PoT+Fixed", z20, (50, 50, 0), false, false, (46.0, 100.0, 75.8, 47.8)),
        t6("(7) 60:40, 8b f/l", z20, (60, 40, 0), true, false, (52.0, 100.0, 57.0, 63.6)),
        t6("MSQ-1 (APoT 60:40)", z20, (60, 40, 0), false, true, (53.0, 100.0, 77.0, 47.1)),
        t6("RMSMP-1 (60:35:5)", z20, (60, 35, 5), false, false, (57.0, 100.0, 89.0, 40.7)),
        t6("(1) Fixed, 8b f/l", z45, (0, 100, 0), true, false, (21.0, 100.0, 115.6, 31.4)),
        t6("(2) Fixed", z45, (0, 100, 0), false, false, (19.0, 100.0, 142.7, 25.4)),
        t6("(3) PoT, 8b f/l", z45, (100, 0, 0), true, false, (40.0, 100.0, 290.5, 12.5)),
        t6("(4) PoT", z45, (100, 0, 0), false, false, (43.0, 3.0, 352.6, 10.3)),
        t6("(5) PoT+Fixed, 8b f/l", z45, (50, 50, 0), true, false, (48.0, 100.0, 196.8, 18.4)),
        t6("(6) PoT+Fixed", z45, (50, 50, 0), false, false, (45.0, 100.0, 296.3, 12.2)),
        t6("(8) 67:33, 8b f/l", z45, (67, 33, 0), true, false, (63.0, 100.0, 245.8, 14.8)),
        t6("MSQ-2 (APoT 67:33)", z45, (67, 33, 0), false, true, (66.0, 100.0, 359.2, 10.1)),
        t6("RMSMP-2 (65:30:5)", z45, (65, 30, 5), false, false, (67.0, 100.0, 421.1, 8.6)),
    ];
    let layers = rmsmp::fpga::sim::resnet18_imagenet_layers();
    println!("\n=== Table 6 — FPGA implementations, ResNet-18/ImageNet (sim vs paper) ===");
    println!("{:<22} {:<9} | {:^29} | {:^29}", "", "", "simulated", "paper (measured)");
    println!(
        "{:<22} {:<9} | {:>5} {:>5} {:>9} {:>7} | {:>5} {:>5} {:>9} {:>7}",
        "config", "board", "LUT%", "DSP%", "GOP/s", "ms", "LUT%", "DSP%", "GOP/s", "ms"
    );
    let mut fixed_ms = (0.0f64, 0.0f64);
    let mut rmsmp_ms = (0.0f64, 0.0f64);
    for r in &rows {
        let d = Design::allocate(
            r.board,
            QuantConfig {
                ratio: Ratio::new(r.ratio.0, r.ratio.1, r.ratio.2),
                first_last_8bit: r.first_last_8bit,
                apot: r.apot,
            },
            CoreCosts::default(),
        );
        let s = simulate(&d, &layers);
        println!(
            "{:<22} {:<9} | {:>4.0}% {:>4.0}% {:>9.1} {:>7.1} | {:>4.0}% {:>4.0}% {:>9.1} {:>7.1}",
            r.label,
            r.board.name,
            100.0 * s.lut_util,
            100.0 * s.dsp_util,
            s.gops,
            s.latency_ms,
            r.paper.0,
            r.paper.1,
            r.paper.2,
            r.paper.3
        );
        if r.label.starts_with("(1)") {
            if r.board == Board::XC7Z020 {
                fixed_ms.0 = s.latency_ms
            } else {
                fixed_ms.1 = s.latency_ms
            }
        }
        if r.label.starts_with("RMSMP") {
            if r.board == Board::XC7Z020 {
                rmsmp_ms.0 = s.latency_ms
            } else {
                rmsmp_ms.1 = s.latency_ms
            }
        }
    }
    println!(
        "\nspeedup RMSMP vs (1) Fixed:  XC7Z020 {:.2}x (paper 3.01x) | XC7Z045 {:.2}x (paper 3.65x)",
        fixed_ms.0 / rmsmp_ms.0,
        fixed_ms.1 / rmsmp_ms.1
    );
}

fn fig3() -> Result<()> {
    let path = Path::new("results/fig3.json");
    if !path.exists() {
        println!("\n=== Figure 3 ===");
        println!("(run `python -m compile.experiments fig3` first — results/fig3.json missing)");
        return Ok(());
    }
    let j = Json::load(path)?;
    let ratios = j.get("ratios")?.as_f32_vec()?;
    println!("\n=== Figure 3 — accuracy vs PoT-W4A4 ratio ===");
    for (name, series) in j.get("series")?.as_obj()? {
        let accs = series.as_f32_vec()?;
        print!("{name:<38}");
        for (r, a) in ratios.iter().zip(&accs) {
            print!(" {:>3.0}%:{:>5.3}", r, a);
        }
        println!();
    }
    println!("(series semantics + QAT-vs-PTQ caveat: see results/fig3.md and EXPERIMENTS.md)");
    Ok(())
}
