//! Dynamic batcher: coalesce single-image requests into executor batches.
//!
//! Policy: dispatch when `max_batch` requests are waiting, or when the
//! oldest waiting request has been queued for `max_wait` — the classic
//! latency/throughput knob. The queue applies backpressure at
//! `queue_cap` (submissions fail fast instead of growing unboundedly).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One enqueued request: flat NCHW image + response channel.
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
    pub respond: std::sync::mpsc::Sender<Response>,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
}

/// A dispatched batch.
pub struct Batch<T> {
    pub requests: Vec<Pending<T>>,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// Batch-level vs row-level parallelism for one dispatched batch.
///
/// Each worker drives its batch through one executor, so the batch
/// dimension only fills the machine *across* concurrently-running
/// workers — a wide batch on a single worker still wants the pool's
/// threads back inside the GEMM. Row-level dispatch is skipped only when
/// the workers alone can saturate the pool AND the batch is wide enough
/// that per-task overhead would not be repaid. The server consults this
/// per dispatched batch.
pub fn row_parallel_for_batch(batch_size: usize, workers: usize, threads: usize) -> bool {
    threads > 1 && (workers < threads || batch_size < threads)
}

struct State<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

/// Thread-safe batching queue.
pub struct Batcher<T> {
    policy: BatchPolicy,
    state: Mutex<State<T>>,
    cv: Condvar,
}

/// Submission failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — the backpressure signal.
    Full,
    /// Batcher shut down.
    Closed,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            policy,
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (non-blocking; `Full` = backpressure).
    pub fn submit(&self, req: Pending<T>) -> Result<(), SubmitError> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(SubmitError::Closed);
        }
        if s.queue.len() >= self.policy.queue_cap {
            return Err(SubmitError::Full);
        }
        s.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready (or `None` after close + drain).
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.queue.is_empty() {
                let oldest = s.queue.front().unwrap().enqueued;
                let full = s.queue.len() >= self.policy.max_batch;
                let expired = oldest.elapsed() >= self.policy.max_wait;
                if full || expired || s.closed {
                    let n = s.queue.len().min(self.policy.max_batch);
                    let requests = s.queue.drain(..n).collect();
                    return Some(Batch { requests });
                }
                // wait the remaining deadline of the oldest request
                let remaining = self.policy.max_wait.saturating_sub(oldest.elapsed());
                let (ns, _) = self.cv.wait_timeout(s, remaining).unwrap();
                s = ns;
            } else if s.closed {
                return None;
            } else {
                s = self.cv.wait(s).unwrap();
            }
        }
    }

    /// Close: wake all workers; queued requests still drain.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn req(id: u64) -> (Pending<u32>, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending { id, payload: id as u32, enqueued: Instant::now(), respond: tx },
            rx,
        )
    }

    #[test]
    fn row_parallel_decision() {
        // sequential executor: never row-parallel
        assert!(!row_parallel_for_batch(1, 1, 1));
        assert!(!row_parallel_for_batch(8, 4, 1));
        // a lone worker always wants the threads inside the GEMM,
        // regardless of batch width (the batch runs sequentially in it)
        assert!(row_parallel_for_batch(1, 1, 4));
        assert!(row_parallel_for_batch(16, 1, 4));
        // under-subscribed workers: still row-parallel
        assert!(row_parallel_for_batch(8, 2, 4));
        // workers saturate the pool and the batch is wide: stay sequential
        assert!(!row_parallel_for_batch(8, 4, 4));
        assert!(!row_parallel_for_batch(16, 8, 4));
        // workers saturate the pool but the batch is narrow: the batch
        // drains fast and frees the worker, so row-level still pays
        assert!(row_parallel_for_batch(2, 4, 4));
    }

    #[test]
    fn dispatches_full_batch_immediately() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            queue_cap: 10,
        });
        for i in 0..3 {
            b.submit(req(i).0).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn dispatches_partial_batch_on_deadline() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 10,
        });
        b.submit(req(1).0).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn backpressure_at_cap() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
            queue_cap: 2,
        });
        b.submit(req(1).0).unwrap();
        b.submit(req(2).0).unwrap();
        assert_eq!(b.submit(req(3).0), Err(SubmitError::Full));
    }

    #[test]
    fn close_drains_then_none() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
            queue_cap: 10,
        }));
        b.submit(req(1).0).unwrap();
        b.close();
        assert_eq!(b.next_batch().unwrap().requests.len(), 1);
        assert!(b.next_batch().is_none());
        assert_eq!(b.submit(req(2).0), Err(SubmitError::Closed));
    }

    #[test]
    fn concurrent_producers_consumers() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 1000,
        }));
        let n = 200;
        let prod = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n {
                    loop {
                        match b.submit(req(i).0) {
                            Ok(()) => break,
                            Err(SubmitError::Full) => std::thread::yield_now(),
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                }
                b.close();
            })
        };
        let mut got = 0;
        while let Some(batch) = b.next_batch() {
            got += batch.requests.len();
        }
        prod.join().unwrap();
        assert_eq!(got, n as usize);
    }
}
