//! Dynamic batcher: coalesce single-image requests into executor batches.
//!
//! Policy: dispatch when `max_batch` requests are waiting, or when the
//! oldest waiting request has been queued for `max_wait` — the classic
//! latency/throughput knob. The queue applies backpressure at
//! `queue_cap` (submissions fail fast instead of growing unboundedly).
//!
//! Requests may carry an absolute **deadline**: a request whose deadline
//! has passed while it sat in the queue is *shed at dispatch time* —
//! removed before the batch is formed, returned in [`Batch::expired`] so
//! the caller can answer it immediately — instead of wasting GEMM cycles
//! on logits nobody is waiting for.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One enqueued request: flat NCHW image + response channel.
pub struct Pending<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
    /// Absolute completion deadline; `None` = never shed.
    pub deadline: Option<Instant>,
    pub respond: std::sync::mpsc::Sender<Response>,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
    /// The request was load-shed (deadline expired in queue): `logits`
    /// is empty and no inference ran for it.
    pub shed: bool,
}

/// A dispatched batch.
pub struct Batch<T> {
    pub requests: Vec<Pending<T>>,
    /// Requests whose deadline expired while queued — shed before the
    /// GEMM; the worker answers these without running inference.
    pub expired: Vec<Pending<T>>,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

/// Batch-level vs row-level parallelism for one dispatched batch.
///
/// Each worker drives its batch through one executor, so the batch
/// dimension only fills the machine *across* concurrently-running
/// workers — a wide batch on a single worker still wants the pool's
/// threads back inside the GEMM. Row-level dispatch is skipped only when
/// the workers alone can saturate the pool AND the batch is wide enough
/// that per-task overhead would not be repaid. The server consults this
/// per dispatched batch.
pub fn row_parallel_for_batch(batch_size: usize, workers: usize, threads: usize) -> bool {
    threads > 1 && (workers < threads || batch_size < threads)
}

struct State<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

/// Thread-safe batching queue.
pub struct Batcher<T> {
    policy: BatchPolicy,
    state: Mutex<State<T>>,
    cv: Condvar,
}

/// Submission failure modes — granular so a front-end can map each to
/// the right wire status: `Full` is transient (retry after backoff,
/// HTTP 429), `Closed` is terminal for this server (503), `Invalid` and
/// `UnknownModel` are caller errors (400 / 404) that no retry fixes.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — the backpressure signal. Retryable.
    Full,
    /// Batcher shut down. Not retryable against this instance.
    Closed,
    /// Request rejected by validation (wrong shape, bad payload).
    Invalid(String),
    /// No model variant by that name is resident.
    UnknownModel(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server shutting down"),
            SubmitError::Invalid(m) => write!(f, "invalid request: {m}"),
            SubmitError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
        }
    }
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher {
            policy,
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (non-blocking; `Full` = backpressure).
    pub fn submit(&self, req: Pending<T>) -> Result<(), SubmitError> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(SubmitError::Closed);
        }
        if s.queue.len() >= self.policy.queue_cap {
            return Err(SubmitError::Full);
        }
        s.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Block until a batch is ready (or `None` after close + drain).
    ///
    /// Every wake-up first sweeps deadline-expired requests out of the
    /// queue into [`Batch::expired`] — shedding happens *before* batch
    /// formation, so an expired request never occupies a GEMM row.
    pub fn next_batch(&self) -> Option<Batch<T>> {
        let mut s = self.state.lock().unwrap();
        let mut shed = Vec::new();
        loop {
            let now = Instant::now();
            let mut i = 0;
            while i < s.queue.len() {
                let expired = s.queue[i].deadline.is_some_and(|d| d <= now);
                if expired {
                    shed.push(s.queue.remove(i).unwrap());
                } else {
                    i += 1;
                }
            }
            if !s.queue.is_empty() {
                let oldest = s.queue.front().unwrap().enqueued;
                let full = s.queue.len() >= self.policy.max_batch;
                let waited_out = now.duration_since(oldest) >= self.policy.max_wait;
                if full || waited_out || s.closed {
                    let n = s.queue.len().min(self.policy.max_batch);
                    let requests = s.queue.drain(..n).collect();
                    return Some(Batch { requests, expired: shed });
                }
                // wake at the oldest request's dispatch time or the
                // earliest per-request deadline, whichever comes first
                let mut wake = oldest + self.policy.max_wait;
                for p in &s.queue {
                    if let Some(d) = p.deadline {
                        if d < wake {
                            wake = d;
                        }
                    }
                }
                let (ns, _) = self
                    .cv
                    .wait_timeout(s, wake.saturating_duration_since(now))
                    .unwrap();
                s = ns;
            } else if !shed.is_empty() {
                // nothing runnable, but expired requests need answering
                return Some(Batch { requests: Vec::new(), expired: shed });
            } else if s.closed {
                return None;
            } else {
                s = self.cv.wait(s).unwrap();
            }
        }
    }

    /// Close: wake all workers; queued requests still drain.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn req(id: u64) -> (Pending<u32>, mpsc::Receiver<Response>) {
        req_deadline(id, None)
    }

    fn req_deadline(id: u64, deadline: Option<Instant>) -> (Pending<u32>, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                id,
                payload: id as u32,
                enqueued: Instant::now(),
                deadline,
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn row_parallel_decision() {
        // sequential executor: never row-parallel
        assert!(!row_parallel_for_batch(1, 1, 1));
        assert!(!row_parallel_for_batch(8, 4, 1));
        // a lone worker always wants the threads inside the GEMM,
        // regardless of batch width (the batch runs sequentially in it)
        assert!(row_parallel_for_batch(1, 1, 4));
        assert!(row_parallel_for_batch(16, 1, 4));
        // under-subscribed workers: still row-parallel
        assert!(row_parallel_for_batch(8, 2, 4));
        // workers saturate the pool and the batch is wide: stay sequential
        assert!(!row_parallel_for_batch(8, 4, 4));
        assert!(!row_parallel_for_batch(16, 8, 4));
        // workers saturate the pool but the batch is narrow: the batch
        // drains fast and frees the worker, so row-level still pays
        assert!(row_parallel_for_batch(2, 4, 4));
    }

    #[test]
    fn dispatches_full_batch_immediately() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
            queue_cap: 10,
        });
        for i in 0..3 {
            b.submit(req(i).0).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn dispatches_partial_batch_on_deadline() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 10,
        });
        b.submit(req(1).0).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn expired_requests_are_shed_before_dispatch() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_cap: 10,
        });
        // one already-expired request, one live one
        b.submit(req_deadline(1, Some(Instant::now())).0).unwrap();
        b.submit(req(2).0).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.expired.len(), 1, "expired request shed");
        assert_eq!(batch.expired[0].id, 1);
        assert_eq!(batch.requests.len(), 1, "live request dispatched");
        assert_eq!(batch.requests[0].id, 2);
    }

    #[test]
    fn all_expired_yields_empty_batch_with_shed() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
            queue_cap: 10,
        });
        b.submit(req_deadline(1, Some(Instant::now())).0).unwrap();
        b.submit(req_deadline(2, Some(Instant::now())).0).unwrap();
        let batch = b.next_batch().unwrap();
        assert!(batch.requests.is_empty());
        assert_eq!(batch.expired.len(), 2);
    }

    #[test]
    fn near_deadline_wakes_before_max_wait() {
        // deadline (20ms) far sooner than max_wait (10s): next_batch must
        // wake on the deadline and shed, not sit out the full max_wait
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
            queue_cap: 10,
        });
        b.submit(req_deadline(1, Some(Instant::now() + Duration::from_millis(20))).0)
            .unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "woke on deadline");
        assert_eq!(batch.expired.len(), 1);
        assert!(batch.requests.is_empty());
    }

    #[test]
    fn submit_error_display_is_granular() {
        assert!(SubmitError::Full.to_string().contains("backpressure"));
        assert!(SubmitError::Closed.to_string().contains("shutting down"));
        assert!(SubmitError::Invalid("len 3".into()).to_string().contains("len 3"));
        assert!(SubmitError::UnknownModel("m".into()).to_string().contains("m"));
    }

    #[test]
    fn backpressure_at_cap() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
            queue_cap: 2,
        });
        b.submit(req(1).0).unwrap();
        b.submit(req(2).0).unwrap();
        assert_eq!(b.submit(req(3).0), Err(SubmitError::Full));
    }

    #[test]
    fn close_drains_then_none() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
            queue_cap: 10,
        }));
        b.submit(req(1).0).unwrap();
        b.close();
        assert_eq!(b.next_batch().unwrap().requests.len(), 1);
        assert!(b.next_batch().is_none());
        assert_eq!(b.submit(req(2).0), Err(SubmitError::Closed));
    }

    #[test]
    fn concurrent_producers_consumers() {
        let b: Arc<Batcher<u32>> = Arc::new(Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 1000,
        }));
        let n = 200;
        let prod = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n {
                    loop {
                        match b.submit(req(i).0) {
                            Ok(()) => break,
                            Err(SubmitError::Full) => std::thread::yield_now(),
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                }
                b.close();
            })
        };
        let mut got = 0;
        while let Some(batch) = b.next_batch() {
            got += batch.requests.len();
        }
        prod.join().unwrap();
        assert_eq!(got, n as usize);
    }
}
