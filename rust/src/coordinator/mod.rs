//! Serving coordinator: router → dynamic batcher → worker pool → metrics.
//!
//! The L3 request path (Python never appears here): clients submit single
//! images; the [`batcher`] coalesces them under a max-batch / max-wait
//! policy (the standard dynamic-batching tradeoff); [`server`] workers run
//! the integer [`crate::model::Executor`] layer by layer and complete the
//! per-request responses; [`metrics`] tracks queue depth, batch sizes, and
//! latency percentiles. [`workload`] generates Poisson open-loop traffic
//! for the serving benchmarks.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod workload;

pub use batcher::{Batch, BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use router::Router;
pub use server::{Server, ServerConfig};
pub use workload::{OpenLoopGen, TraceEvent};
