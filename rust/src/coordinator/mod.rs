//! Serving coordinator: HTTP front-end → router → dynamic batcher →
//! worker pool → metrics.
//!
//! The L3 request path (Python never appears here): [`http`] accepts
//! real sockets and lazy-parses request JSON; the [`batcher`] coalesces
//! concurrent requests under a max-batch / max-wait policy (the
//! standard dynamic-batching tradeoff) and sheds deadline-expired ones
//! before the GEMM; [`server`] workers run the integer
//! [`crate::model::Executor`] layer by layer and complete the
//! per-request responses; [`metrics`] tracks queue depth, batch sizes,
//! latency percentiles, and the per-stage timers (also rendered in
//! Prometheus text format for `GET /metrics`). [`conn`] holds the
//! HTTP/1.1 wire plumbing plus a tiny test/bench client; [`workload`]
//! generates Poisson open-loop traffic for the serving benchmarks.

pub mod batcher;
pub mod conn;
pub mod http;
pub mod metrics;
pub mod router;
pub mod server;
pub mod workload;

pub use batcher::{Batch, BatchPolicy, Batcher, SubmitError};
pub use conn::SimpleClient;
pub use http::{HttpConfig, HttpServer};
pub use metrics::Metrics;
pub use router::Router;
pub use server::{Server, ServerConfig};
pub use workload::{OpenLoopGen, TraceEvent};
