//! The serving loop: batcher → per-worker integer executors → responses.
//!
//! The model is loaded and compiled **once**: one `Arc<ModelWeights>`,
//! one `Arc<Manifest>`, and one compiled `Arc<Plan>` (sized for the
//! batcher's `max_batch`) are shared by every worker, so an N-worker
//! server holds ~1x the weights — not N clones. Each worker owns only
//! its private mutable state: an [`Executor`] whose preallocated
//! [`crate::model::Workspace`] is reused across batches, so the
//! steady-state request path allocates no inference buffers (see the
//! library docs for the exact zero-allocation guarantee per execution
//! mode).
//!
//! All workers' executors share one [`ThreadPool`] sized by
//! [`ServerConfig::parallel`]; per batch, the worker asks
//! [`super::batcher::row_parallel_for_batch`] whether to spend those
//! threads inside the GEMM or leave them to the other concurrently
//! running workers, so the machine is filled either way.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::ensure;
use crate::err;
use crate::gemm::{ParallelConfig, RowPartition};
use crate::model::{Executor, Manifest, ModelWeights, Plan};
use crate::quant::tensor::Tensor4;
use crate::util::error::Result;
use crate::util::pool::ThreadPool;

use super::batcher::{
    row_parallel_for_batch, Batch, BatchPolicy, Batcher, Pending, Response, SubmitError,
};
use super::metrics::Metrics;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Execution config for the shared GEMM pool. Defaults to sequential
    /// (no pool); `ParallelConfig::default()` enables one thread per core.
    pub parallel: ParallelConfig,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            workers: 1,
            policy: BatchPolicy::default(),
            parallel: ParallelConfig::sequential(),
        }
    }
}

/// A running server instance.
pub struct Server {
    batcher: Arc<Batcher<Vec<f32>>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    input_chw: (usize, usize, usize),
    num_classes: usize,
    model: String,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Admission check: every layer's row partition must cover all rows with
/// class fractions summing to 1 (all four classes counted — APoT rows
/// used to be dropped from the fractions and broke this invariant).
fn admit(weights: &ModelWeights) -> Result<()> {
    for l in &weights.layers {
        let part = RowPartition::from_schemes(&l.scheme);
        ensure!(
            part.total() == l.rows,
            "layer {}: partition covers {} of {} rows",
            l.name,
            part.total(),
            l.rows
        );
        let sum: f64 = part.fractions().iter().sum();
        ensure!(
            l.rows == 0 || (sum - 1.0).abs() < 1e-9,
            "layer {}: scheme fractions sum to {sum}, want 1",
            l.name
        );
    }
    Ok(())
}

impl Server {
    /// Spawn workers over the manifest + weights: compile the plan once,
    /// share weights/manifest/plan via `Arc`, give each worker a private
    /// preallocated workspace.
    pub fn start(manifest: Manifest, weights: ModelWeights, cfg: ServerConfig) -> Result<Server> {
        Server::start_with_pool(manifest, weights, cfg, None)
    }

    /// [`Server::start`] with an externally owned GEMM thread pool. The
    /// multi-model [`super::Router`] passes one shared pool to every
    /// resident model so N models contend for the machine's cores
    /// through one scheduler instead of N oversubscribed ones. `None`
    /// keeps the single-model behavior: the server builds its own pool
    /// when `cfg.parallel` resolves to more than one thread.
    pub fn start_with_pool(
        manifest: Manifest,
        weights: ModelWeights,
        cfg: ServerConfig,
        shared_pool: Option<Arc<ThreadPool>>,
    ) -> Result<Server> {
        let batcher = Arc::new(Batcher::new(cfg.policy));
        let metrics = Arc::new(Metrics::new());
        let shape = &manifest.input_shape;
        ensure!(shape.len() == 4, "manifest input_shape must be NCHW");
        let input_chw = (shape[1], shape[2], shape[3]);
        let num_classes = manifest.num_classes;
        let model = manifest.model.clone();
        admit(&weights)?;

        // compile once; size workspaces for the largest batch the
        // batcher will ever hand a worker
        let plan = Arc::new(
            Plan::builder(&manifest, &weights)
                .capacity(cfg.policy.max_batch.max(1))
                .config(&cfg.parallel)
                .build()?,
        );
        let manifest = Arc::new(manifest);
        let weights = Arc::new(weights);

        let threads = cfg.parallel.resolved_threads();
        let pool = match shared_pool {
            Some(p) => Some(p),
            None => (threads > 1).then(|| Arc::new(ThreadPool::new(threads))),
        };

        let mut workers = Vec::new();
        let n_workers = cfg.workers.max(1);
        for wi in 0..n_workers {
            let b = Arc::clone(&batcher);
            let m = Arc::clone(&metrics);
            let mut exec = Executor::from_shared(
                Arc::clone(&manifest),
                Arc::clone(&weights),
                Arc::clone(&plan),
                cfg.parallel,
                pool.clone(),
            )?;
            let chw = input_chw;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rmsmp-serve-{wi}"))
                    .spawn(move || worker_loop(&b, &m, &mut exec, chw, (n_workers, threads)))
                    .expect("spawn server worker"),
            );
        }
        Ok(Server {
            batcher,
            metrics,
            next_id: AtomicU64::new(0),
            input_chw,
            num_classes,
            model,
            workers,
        })
    }

    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.input_chw;
        c * h * w
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The manifest's model name (what the HTTP front-end routes on).
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Submit one image (flat CHW floats); returns a receiver for the
    /// response. `Err` = validation failure, backpressure, or shutdown.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_with_deadline(image, None)
    }

    /// Submit with an optional completion deadline: if the request is
    /// still queued when the deadline passes, the batcher sheds it
    /// before the GEMM and the receiver gets a [`Response`] with
    /// `shed = true` instead of logits.
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        if image.len() != self.input_len() {
            return Err(SubmitError::Invalid(format!(
                "input length {} != expected {}",
                image.len(),
                self.input_len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let res = self.batcher.submit(Pending {
            id,
            payload: image,
            enqueued: Instant::now(),
            deadline: deadline.map(|d| Instant::now() + d),
            respond: tx,
        });
        if res.is_err() {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        }
        res.map(|()| rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, image: Vec<f32>) -> Result<Response> {
        let rx = self
            .submit(image)
            .map_err(|e| err!("submit failed: {e:?}"))?;
        Ok(rx.recv()?)
    }

    /// Graceful shutdown: drain the queue, join workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }
}

/// Pack a batch of flat CHW images into one reused NCHW tensor. The
/// tensor grows to the batch high-water once; at steady state `resize`
/// stays within capacity and the copy overwrites in place, so the
/// worker's pack step allocates nothing (pinned by `test_alloc.rs`
/// alongside the executor's zero-allocation window).
pub fn pack_batch<'a, I>(x: &mut Tensor4, (c, h, w): (usize, usize, usize), n: usize, images: I)
where
    I: Iterator<Item = &'a [f32]>,
{
    x.n = n;
    x.c = c;
    x.h = h;
    x.w = w;
    x.data.resize(n * c * h * w, 0.0);
    for (i, img) in images.enumerate() {
        let off = i * c * h * w;
        x.data[off..off + c * h * w].copy_from_slice(img);
    }
}

fn worker_loop(
    batcher: &Batcher<Vec<f32>>,
    metrics: &Metrics,
    exec: &mut Executor,
    (c, h, w): (usize, usize, usize),
    (workers, threads): (usize, usize),
) {
    // the packing tensor is reused across batches (grows to the batch
    // high-water once, then the request path stays allocation-free
    // through the executor's workspace)
    let mut x = Tensor4::zeros(0, c, h, w);
    while let Some(Batch { requests, expired }) = batcher.next_batch() {
        // deadline-shed requests: answer without running the GEMM
        for r in expired {
            metrics.record_shed();
            let queue_ms = r.enqueued.elapsed().as_secs_f64() * 1e3;
            let _ = r.respond.send(Response {
                id: r.id,
                logits: Vec::new(),
                queue_ms,
                total_ms: queue_ms,
                batch_size: 0,
                shed: true,
            });
        }
        if requests.is_empty() {
            continue;
        }
        let n = requests.len();
        metrics.record_batch(n);
        // batch-level vs row-level parallelism (see row_parallel_for_batch)
        exec.set_row_parallel(row_parallel_for_batch(n, workers, threads));
        let t0 = Instant::now();
        pack_batch(&mut x, (c, h, w), n, requests.iter().map(|r| r.payload.as_slice()));
        match exec.infer(&x) {
            Ok(logits) => {
                let infer_ms = t0.elapsed().as_secs_f64() * 1e3;
                for (i, r) in requests.into_iter().enumerate() {
                    let queue_ms = t0.duration_since(r.enqueued).as_secs_f64() * 1e3;
                    let total_ms = queue_ms + infer_ms;
                    metrics.record_response(total_ms, queue_ms);
                    let _ = r.respond.send(Response {
                        id: r.id,
                        logits: logits.row(i).to_vec(),
                        queue_ms,
                        total_ms,
                        batch_size: n,
                        shed: false,
                    });
                }
            }
            Err(e) => {
                // fail the whole batch: drop senders (clients see RecvError)
                eprintln!("[server] batch failed: {e}");
            }
        }
        // drain the executor's per-stage breakdown into the shared
        // metrics so the stats line shows where batch time goes
        metrics.record_stages(&exec.take_stage_times());
    }
}
