//! Multi-model router: front door over several [`Server`] instances.
//!
//! The paper ships different quantization configurations per board
//! (RMSMP-1 at 60:35:5, RMSMP-2 at 65:30:5); a deployment serves several
//! such variants side by side. The router owns one server per variant,
//! routes by model name, exposes aggregate metrics, and implements a
//! default-variant fallback — the vLLM-router-shaped front of the stack.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;

use crate::ensure;
use crate::err;
use crate::util::error::Result;
use crate::util::pool::ThreadPool;

use super::batcher::{Response, SubmitError};
use super::server::{Server, ServerConfig};
use crate::model::{Manifest, ModelWeights};

/// A named model variant under one router.
pub struct Variant {
    pub name: String,
    pub server: Server,
}

/// Routes requests to model variants by name.
pub struct Router {
    variants: BTreeMap<String, Variant>,
    default: String,
}

impl Router {
    /// Build from (name, manifest, weights, config) tuples; the first
    /// entry becomes the default variant.
    ///
    /// All variants share **one** GEMM thread pool, sized by the widest
    /// variant's `parallel` config: N resident models contend for the
    /// machine's cores through a single scheduler instead of stacking N
    /// pools (N× oversubscription under concurrent traffic). Each
    /// variant still resolves its own row-parallel policy per batch.
    pub fn start(models: Vec<(String, Manifest, ModelWeights, ServerConfig)>) -> Result<Router> {
        ensure!(!models.is_empty(), "router needs at least one variant");
        let default = models[0].0.clone();
        let threads = models
            .iter()
            .map(|(_, _, _, cfg)| cfg.parallel.resolved_threads())
            .max()
            .unwrap_or(1);
        let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        let mut variants = BTreeMap::new();
        for (name, manifest, weights, cfg) in models {
            // sequential variants keep running with no pool at all
            let vpool = if cfg.parallel.resolved_threads() > 1 { pool.clone() } else { None };
            let server = Server::start_with_pool(manifest, weights, cfg, vpool)?;
            variants.insert(name.clone(), Variant { name, server });
        }
        Ok(Router { variants, default })
    }

    fn variant_for(&self, model: Option<&str>) -> Result<&Variant, SubmitError> {
        let name = model.unwrap_or(&self.default);
        self.variants
            .get(name)
            .ok_or_else(|| SubmitError::UnknownModel(name.to_string()))
    }

    /// Route a request; `model = None` selects the default variant. The
    /// typed error keeps the HTTP front-end's status mapping exact:
    /// unknown model → 404, queue full → 429, shutdown → 503.
    pub fn submit(
        &self,
        model: Option<&str>,
        image: Vec<f32>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_with_deadline(model, image, None)
    }

    /// Route with an optional deadline (see
    /// [`Server::submit_with_deadline`]).
    pub fn submit_with_deadline(
        &self,
        model: Option<&str>,
        image: Vec<f32>,
        deadline: Option<std::time::Duration>,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.variant_for(model)?.server.submit_with_deadline(image, deadline)
    }

    /// Expected flat input length for a variant (`None` = default).
    pub fn input_len(&self, model: Option<&str>) -> Result<usize, SubmitError> {
        Ok(self.variant_for(model)?.server.input_len())
    }

    /// Blocking convenience.
    pub fn infer(&self, model: Option<&str>, image: Vec<f32>) -> Result<Response> {
        let rx = self
            .submit(model, image)
            .map_err(|e| err!("submit failed: {e}"))?;
        Ok(rx.recv()?)
    }

    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    /// Aggregate metrics summary across variants.
    pub fn summary(&self) -> String {
        self.variants
            .iter()
            .map(|(n, v)| format!("[{n}] {}", v.server.metrics.summary()))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Prometheus text exposition across all variants, one `model` label
    /// per variant (what `GET /metrics` serves).
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (n, v) in &self.variants {
            v.server.metrics.prometheus_into(n, &mut out);
        }
        out
    }

    pub fn shutdown(self) {
        for (_, v) in self.variants {
            v.server.shutdown();
        }
    }
}
