//! Zero-dependency HTTP/1.1 serving front-end over `std::net`.
//!
//! The request path is: accept loop → handler thread (keep-alive) →
//! lazy JSON field scan ([`crate::util::json::lazy_f32_array`] — no
//! tree is built for the hot fields) → [`Batcher`] admission
//! (queue-depth backpressure + per-request deadline) → shared compiled
//! plan → response. Handlers block inside `rx.recv()` while the batcher
//! coalesces concurrent requests into one GEMM batch, so throughput
//! under concurrency comes from batching, not from per-request model
//! state.
//!
//! Status mapping is exact so clients can implement retry policy:
//! queue full → 429 + `Retry-After`, shutting down → 503 +
//! `Retry-After`, validation failure → 400, unknown model → 404,
//! deadline shed → 504, oversized body → 413, missing length → 411.
//! `GET /metrics` renders the counters, latency quantiles, and the
//! per-stage executor timers in Prometheus text exposition format;
//! `GET /healthz` answers `ok`.
//!
//! Shutdown raises a stop flag, self-connects to unblock the acceptor,
//! joins every handler (their 100 ms read timeout bounds the wait), and
//! finally drains the inference workers.

use std::io::{self, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::error::Result;
use crate::util::json;

use super::batcher::{Response, SubmitError};
use super::conn::{read_request, write_response, ReadError, Request};
use super::router::Router;
use super::server::Server;

/// Front-end configuration.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`HttpServer::addr`]).
    pub addr: String,
    /// Connection-handler threads; 0 = 4x cores with a floor of 8.
    /// Handlers spend most of their life blocked on batched inference,
    /// so oversubscribing well past the core count is what lets the
    /// batcher see concurrent requests at all.
    pub conn_threads: usize,
    /// Request bodies above this are refused with 413 without reading.
    pub max_body_bytes: usize,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            conn_threads: 0,
            max_body_bytes: 8 << 20,
        }
    }
}

/// What the front-end serves: one model or a multi-model router.
enum Backend {
    Single(Server),
    Multi(Router),
}

impl Backend {
    fn submit(
        &self,
        model: Option<&str>,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> std::result::Result<Receiver<Response>, SubmitError> {
        match self {
            Backend::Single(s) => {
                if let Some(m) = model {
                    if m != s.model() {
                        return Err(SubmitError::UnknownModel(m.to_string()));
                    }
                }
                s.submit_with_deadline(image, deadline)
            }
            Backend::Multi(r) => r.submit_with_deadline(model, image, deadline),
        }
    }

    fn input_len(&self, model: Option<&str>) -> std::result::Result<usize, SubmitError> {
        match self {
            Backend::Single(s) => Ok(s.input_len()),
            Backend::Multi(r) => r.input_len(model),
        }
    }

    fn prometheus(&self) -> String {
        match self {
            Backend::Single(s) => {
                let mut out = String::new();
                s.metrics.prometheus_into(s.model(), &mut out);
                out
            }
            Backend::Multi(r) => r.prometheus(),
        }
    }

    fn summary(&self) -> String {
        match self {
            Backend::Single(s) => s.metrics.summary(),
            Backend::Multi(r) => r.summary(),
        }
    }

    fn shutdown(self) {
        match self {
            Backend::Single(s) => s.shutdown(),
            Backend::Multi(r) => r.shutdown(),
        }
    }
}

/// A running HTTP front-end.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
    backend: Option<Arc<Backend>>,
}

impl HttpServer {
    /// Serve one model.
    pub fn start(server: Server, cfg: HttpConfig) -> Result<HttpServer> {
        HttpServer::start_backend(Backend::Single(server), cfg)
    }

    /// Serve a multi-model [`Router`]; requests route on their `model`
    /// field, absent field = default variant.
    pub fn start_router(router: Router, cfg: HttpConfig) -> Result<HttpServer> {
        HttpServer::start_backend(Backend::Multi(router), cfg)
    }

    fn start_backend(backend: Backend, cfg: HttpConfig) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let backend = Arc::new(backend);

        let n = if cfg.conn_threads > 0 {
            cfg.conn_threads
        } else {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            (4 * cores).max(8)
        };

        // acceptor pushes connections into one queue; each handler pops
        // exactly one, drops the lock, then serves it to completion
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handlers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let b = Arc::clone(&backend);
            let stop = Arc::clone(&stop);
            let max_body = cfg.max_body_bytes;
            handlers.push(
                std::thread::Builder::new()
                    .name(format!("rmsmp-http-{i}"))
                    .spawn(move || loop {
                        let stream = rx.lock().unwrap().recv();
                        match stream {
                            Ok(s) => handle_connection(s, &b, &stop, max_body),
                            Err(_) => return, // acceptor dropped the sender
                        }
                    })
                    .expect("spawn http handler"),
            );
        }

        let stop_a = Arc::clone(&stop);
        let acceptor = std::thread::Builder::new()
            .name("rmsmp-http-accept".to_string())
            .spawn(move || {
                for s in listener.incoming() {
                    if stop_a.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(s) = s {
                        if tx.send(s).is_err() {
                            break;
                        }
                    }
                }
                // tx drops here, ending every idle handler's recv()
            })
            .expect("spawn http acceptor");

        Ok(HttpServer { addr, stop, acceptor: Some(acceptor), handlers, backend: Some(backend) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Human-readable metrics line(s), one per model.
    pub fn summary(&self) -> String {
        self.backend.as_ref().map(|b| b.summary()).unwrap_or_default()
    }

    /// Graceful shutdown: stop accepting, join handlers, drain workers.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the acceptor's blocking accept with a self-connection
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
        if let Some(b) = self.backend.take() {
            if let Ok(b) = Arc::try_unwrap(b) {
                b.shutdown();
            }
        }
    }
}

/// HTTP status + optional `Retry-After` seconds for a submit failure.
/// Queue-full is the retryable case; shutdown tells clients to back off
/// longer; validation and routing failures are the client's fault.
fn status_for(e: &SubmitError) -> (u16, Option<u32>) {
    match e {
        SubmitError::Full => (429, Some(1)),
        SubmitError::Closed => (503, Some(5)),
        SubmitError::Invalid(_) => (400, None),
        SubmitError::UnknownModel(_) => (404, None),
    }
}

fn json_quote(s: &str) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn respond_error<W: Write>(
    w: &mut W,
    scratch: &mut String,
    status: u16,
    msg: &str,
    keep: bool,
    retry_after: Option<u32>,
) -> io::Result<()> {
    let body = format!("{{\"error\":{}}}\n", json_quote(msg));
    let retry = retry_after.map(|secs| secs.to_string());
    let mut extra: Vec<(&str, &str)> = Vec::new();
    if let Some(r) = retry.as_deref() {
        extra.push(("Retry-After", r));
    }
    write_response(w, scratch, status, "application/json", &extra, &body, keep)
}

fn write_infer_response<W: Write>(
    w: &mut W,
    scratch: &mut String,
    resp: &Response,
    keep: bool,
) -> io::Result<()> {
    use std::fmt::Write as _;
    let mut body = String::with_capacity(resp.logits.len() * 12 + 64);
    body.push_str("{\"logits\":[");
    for (i, v) in resp.logits.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        // f32 Display is the shortest roundtrip representation: a client
        // parsing as f64 and narrowing back to f32 recovers the exact bits
        let _ = write!(body, "{v}");
    }
    let _ = write!(
        body,
        "],\"batch_size\":{},\"queue_ms\":{:.3},\"total_ms\":{:.3}}}\n",
        resp.batch_size, resp.queue_ms, resp.total_ms
    );
    write_response(w, scratch, 200, "application/json", &[], &body, keep)
}

fn infer_route<W: Write>(
    req: &Request,
    keep: bool,
    backend: &Backend,
    w: &mut W,
    scratch: &mut String,
    input: &mut Vec<f32>,
) -> io::Result<()> {
    if req.content_length.is_none() {
        return respond_error(w, scratch, 411, "Content-Length required", keep, None);
    }
    let model = match json::lazy_str(&req.body, "model") {
        Ok(m) => m,
        Err(e) => return respond_error(w, scratch, 400, &format!("bad JSON: {e}"), keep, None),
    };
    let deadline = match json::lazy_f64(&req.body, "deadline_ms") {
        // non-finite deadlines (overflowing exponents parse to inf) are
        // treated as already expired rather than panicking from_secs_f64
        Ok(d) => d.map(|ms| {
            let secs = ms / 1e3;
            Duration::from_secs_f64(if secs.is_finite() { secs.max(0.0) } else { 0.0 })
        }),
        Err(e) => return respond_error(w, scratch, 400, &format!("bad JSON: {e}"), keep, None),
    };
    // size the input buffer up front so the element parse appends into
    // reserved capacity instead of growing mid-scan
    if let Ok(n) = backend.input_len(model.as_deref()) {
        input.clear();
        input.reserve(n);
    }
    match json::lazy_f32_array(&req.body, "input", input) {
        Ok(true) => {}
        Ok(false) => {
            return respond_error(w, scratch, 400, "missing \"input\" array", keep, None)
        }
        Err(e) => return respond_error(w, scratch, 400, &format!("bad JSON: {e}"), keep, None),
    }
    let rx = match backend.submit(model.as_deref(), std::mem::take(input), deadline) {
        Ok(rx) => rx,
        Err(e) => {
            let (status, retry) = status_for(&e);
            return respond_error(w, scratch, status, &e.to_string(), keep, retry);
        }
    };
    match rx.recv() {
        Ok(resp) if resp.shed => respond_error(
            w,
            scratch,
            504,
            "deadline expired before dispatch; request shed",
            keep,
            None,
        ),
        Ok(resp) => write_infer_response(w, scratch, &resp, keep),
        Err(_) => respond_error(w, scratch, 500, "inference batch failed", keep, None),
    }
}

fn serve_one<W: Write>(
    req: &Request,
    keep: bool,
    backend: &Backend,
    w: &mut W,
    scratch: &mut String,
    input: &mut Vec<f32>,
) -> io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/infer") | ("POST", "/infer") => {
            infer_route(req, keep, backend, w, scratch, input)
        }
        ("GET", "/metrics") => {
            let body = backend.prometheus();
            write_response(w, scratch, 200, "text/plain; version=0.0.4", &[], &body, keep)
        }
        ("GET", "/healthz") => write_response(w, scratch, 200, "text/plain", &[], "ok\n", keep),
        (_, "/v1/infer") | (_, "/infer") | (_, "/metrics") | (_, "/healthz") => {
            respond_error(w, scratch, 405, "method not allowed", keep, None)
        }
        _ => respond_error(w, scratch, 404, "unknown route", keep, None),
    }
}

fn handle_connection(stream: TcpStream, backend: &Backend, stop: &AtomicBool, max_body: usize) {
    let _ = stream.set_nodelay(true);
    // short read timeout: idle keep-alive connections poll the stop flag
    // (read_request reassembles requests split across timeouts)
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = BufReader::new(&stream);
    let mut writer = &stream;
    let mut scratch = String::new();
    let mut input: Vec<f32> = Vec::new();
    loop {
        let req = match read_request(&mut reader, &mut writer, stop, max_body) {
            Ok(req) => req,
            Err(ReadError::Closed) | Err(ReadError::Stopped) | Err(ReadError::Io(_)) => return,
            Err(ReadError::Bad(status, msg)) => {
                let _ = respond_error(&mut writer, &mut scratch, status, msg, false, None);
                return;
            }
        };
        let keep = req.keep_alive && !stop.load(Ordering::Relaxed);
        if serve_one(&req, keep, backend, &mut writer, &mut scratch, &mut input).is_err() {
            return;
        }
        if !keep {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping_is_granular() {
        assert_eq!(status_for(&SubmitError::Full), (429, Some(1)));
        assert_eq!(status_for(&SubmitError::Closed), (503, Some(5)));
        assert_eq!(status_for(&SubmitError::Invalid("len".to_string())), (400, None));
        assert_eq!(status_for(&SubmitError::UnknownModel("x".to_string())), (404, None));
    }

    #[test]
    fn json_quote_escapes() {
        assert_eq!(json_quote("plain"), "\"plain\"");
        assert_eq!(json_quote("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(json_quote("\u{1}"), "\"\\u0001\"");
    }
}
