//! Serving workload generation: open-loop Poisson arrivals over synthetic
//! images (the serving-benchmark harness's traffic source).

use crate::util::rng::Rng;

/// One scheduled request in an open-loop trace.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    /// Flat CHW image payload.
    pub image: Vec<f32>,
}

/// Open-loop generator: Poisson arrivals at `rate_rps`, synthetic images.
pub struct OpenLoopGen {
    rng: Rng,
    rate_rps: f64,
    image_len: usize,
    clock_s: f64,
}

impl OpenLoopGen {
    pub fn new(seed: u64, rate_rps: f64, image_len: usize) -> OpenLoopGen {
        OpenLoopGen { rng: Rng::new(seed), rate_rps, image_len, clock_s: 0.0 }
    }

    /// Generate the next arrival.
    pub fn next_event(&mut self) -> TraceEvent {
        self.clock_s += self.rng.exponential(self.rate_rps);
        let image = (0..self.image_len)
            .map(|_| self.rng.uniform(0.0, 1.0))
            .collect();
        TraceEvent { at_s: self.clock_s, image }
    }

    /// Generate a complete trace of `n` requests.
    pub fn trace(&mut self, n: usize) -> Vec<TraceEvent> {
        (0..n).map(|_| self.next_event()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_monotone_and_rate_matches() {
        let mut g = OpenLoopGen::new(1, 100.0, 4);
        let tr = g.trace(2000);
        for w in tr.windows(2) {
            assert!(w[1].at_s > w[0].at_s);
        }
        let span = tr.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn images_have_requested_len_and_range() {
        let mut g = OpenLoopGen::new(2, 10.0, 12);
        let e = g.next_event();
        assert_eq!(e.image.len(), 12);
        assert!(e.image.iter().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = OpenLoopGen::new(7, 50.0, 3).trace(10);
        let b = OpenLoopGen::new(7, 50.0, 3).trace(10);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.image, y.image);
        }
    }
}
