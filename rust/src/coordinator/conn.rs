//! HTTP/1.1 connection plumbing shared by the serving front-end
//! ([`super::http`]), the socket tests, and `bench_serve`: a blocking
//! request reader that tolerates read timeouts (handlers poll a stop
//! flag between reads without dropping half-read requests), a response
//! writer that builds the head in a reused scratch buffer, and a tiny
//! blocking client for tests and benchmarks.
//!
//! Only the slice of HTTP/1.1 the serving path needs is implemented:
//! `Content-Length` bodies (chunked transfer encoding is rejected with
//! 400), keep-alive, and `Expect: 100-continue`. Every protocol
//! violation maps to a 4xx answer followed by a close — a malformed
//! peer can never wedge a handler thread.

use std::io::{self, BufRead, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};

/// Cap on a single head line (request line or header).
const MAX_LINE_BYTES: usize = 8 * 1024;
/// Cap on header count per request.
const MAX_HEADERS: usize = 64;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Whether the connection should stay open after the response.
    pub keep_alive: bool,
    /// `Content-Length` as sent; `None` means the header was absent
    /// (POST routes answer 411 in that case).
    pub content_length: Option<usize>,
    pub body: Vec<u8>,
}

/// Why [`read_request`] stopped without producing a request.
#[derive(Debug)]
pub enum ReadError {
    /// Clean EOF between requests — the normal end of a keep-alive
    /// connection.
    Closed,
    /// The server's stop flag was raised while this handler was idle.
    Stopped,
    /// Transport failure mid-request.
    Io(io::Error),
    /// Protocol violation: answer with this status + message, then close.
    Bad(u16, &'static str),
}

fn interrupted(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
    )
}

/// Read one `\n`-terminated line into `line` (cleared first). Retries
/// read-timeout errors while polling `stop`; `read_until` appends, so a
/// line split across timeouts is reassembled rather than dropped.
fn read_line_bytes<R: BufRead>(
    r: &mut R,
    stop: &AtomicBool,
    line: &mut Vec<u8>,
) -> Result<(), ReadError> {
    line.clear();
    loop {
        match (&mut *r).take(MAX_LINE_BYTES as u64).read_until(b'\n', line) {
            Ok(0) => {
                // EOF, or the take-limit ran out with no newline in sight
                if line.len() >= MAX_LINE_BYTES {
                    return Err(ReadError::Bad(431, "header line too long"));
                }
                return Err(ReadError::Closed);
            }
            Ok(_) => {
                if line.last() == Some(&b'\n') {
                    return Ok(());
                }
                if line.len() >= MAX_LINE_BYTES {
                    return Err(ReadError::Bad(431, "header line too long"));
                }
                // partial line (timeout window or take boundary): keep going
            }
            Err(e) if interrupted(e.kind()) => {
                if stop.load(Ordering::Relaxed) {
                    return Err(ReadError::Stopped);
                }
            }
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
}

fn trim_ascii(mut b: &[u8]) -> &[u8] {
    while let [f, rest @ ..] = b {
        if f.is_ascii_whitespace() {
            b = rest;
        } else {
            break;
        }
    }
    while let [rest @ .., l] = b {
        if l.is_ascii_whitespace() {
            b = rest;
        } else {
            break;
        }
    }
    b
}

/// Read one request from `r`, writing the interim `100 Continue` to `w`
/// when the client asks for it. Bodies larger than `max_body` are
/// refused with 413 *without* being read.
pub fn read_request<R: BufRead, W: Write>(
    r: &mut R,
    w: &mut W,
    stop: &AtomicBool,
    max_body: usize,
) -> Result<Request, ReadError> {
    let mut line = Vec::with_capacity(256);
    read_line_bytes(r, stop, &mut line)?;
    let text =
        std::str::from_utf8(&line).map_err(|_| ReadError::Bad(400, "non-UTF-8 request line"))?;
    let mut parts = text.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(400, "malformed request line"));
    }

    // keep-alive is the HTTP/1.1 default; 1.0 must opt in
    let mut keep_alive = version != "HTTP/1.0";
    let mut content_length: Option<usize> = None;
    let mut expect_continue = false;
    let mut n_headers = 0;
    loop {
        if n_headers > MAX_HEADERS {
            return Err(ReadError::Bad(431, "too many headers"));
        }
        n_headers += 1;
        read_line_bytes(r, stop, &mut line).map_err(|e| match e {
            // EOF inside the head is a broken request, not a clean close
            ReadError::Closed => {
                ReadError::Io(io::Error::new(io::ErrorKind::UnexpectedEof, "eof in headers"))
            }
            other => other,
        })?;
        let header = trim_ascii(&line);
        if header.is_empty() {
            break;
        }
        let Some(colon) = header.iter().position(|&b| b == b':') else {
            return Err(ReadError::Bad(400, "malformed header"));
        };
        let name = &header[..colon];
        let value = trim_ascii(&header[colon + 1..]);
        if name.eq_ignore_ascii_case(b"content-length") {
            match std::str::from_utf8(value).ok().and_then(|s| s.parse::<usize>().ok()) {
                Some(n) => content_length = Some(n),
                None => return Err(ReadError::Bad(400, "bad Content-Length")),
            }
        } else if name.eq_ignore_ascii_case(b"connection") {
            if value.eq_ignore_ascii_case(b"close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case(b"keep-alive") {
                keep_alive = true;
            }
        } else if name.eq_ignore_ascii_case(b"expect") {
            expect_continue = value.eq_ignore_ascii_case(b"100-continue");
        } else if name.eq_ignore_ascii_case(b"transfer-encoding") {
            return Err(ReadError::Bad(400, "chunked transfer encoding unsupported"));
        }
    }

    let body = match content_length {
        None | Some(0) => Vec::new(),
        Some(n) if n > max_body => return Err(ReadError::Bad(413, "body too large")),
        Some(n) => {
            if expect_continue {
                w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").map_err(ReadError::Io)?;
                w.flush().map_err(ReadError::Io)?;
            }
            // manual read loop (not read_exact): a timeout mid-body must
            // resume at the current offset, not abandon the request
            let mut body = vec![0u8; n];
            let mut got = 0;
            while got < n {
                match r.read(&mut body[got..]) {
                    Ok(0) => {
                        return Err(ReadError::Io(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            "eof in body",
                        )))
                    }
                    Ok(k) => got += k,
                    Err(e) if interrupted(e.kind()) => {
                        if stop.load(Ordering::Relaxed) {
                            return Err(ReadError::Stopped);
                        }
                    }
                    Err(e) => return Err(ReadError::Io(e)),
                }
            }
            body
        }
    };
    Ok(Request { method, path, keep_alive, content_length, body })
}

pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one full response. The head + body are assembled in `scratch`
/// (reused across requests, so steady-state responses only write into
/// existing capacity) and flushed in a single syscall-friendly write.
pub fn write_response<W: Write>(
    w: &mut W,
    scratch: &mut String,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
    keep_alive: bool,
) -> io::Result<()> {
    use std::fmt::Write as _;
    scratch.clear();
    let _ = write!(scratch, "HTTP/1.1 {status} {}\r\n", reason_phrase(status));
    let _ = write!(scratch, "Content-Type: {content_type}\r\n");
    let _ = write!(scratch, "Content-Length: {}\r\n", body.len());
    for (k, v) in extra_headers {
        let _ = write!(scratch, "{k}: {v}\r\n");
    }
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let _ = write!(scratch, "Connection: {conn}\r\n\r\n");
    scratch.push_str(body);
    w.write_all(scratch.as_bytes())?;
    w.flush()
}

/// Minimal blocking HTTP/1.1 client over one keep-alive connection —
/// what the socket tests, `bench_serve`, and the example's curl-style
/// self-query speak.
pub struct SimpleClient {
    stream: TcpStream,
    reader: io::BufReader<TcpStream>,
}

/// A response as seen by [`SimpleClient`].
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl ClientResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

impl SimpleClient {
    pub fn connect(addr: &str) -> io::Result<SimpleClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = io::BufReader::new(stream.try_clone()?);
        Ok(SimpleClient { stream, reader })
    }

    /// Send one request and block for its response. The connection is
    /// keep-alive, so sequential `request` calls reuse the socket.
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> io::Result<ClientResponse> {
        use std::fmt::Write as _;
        let mut head = String::with_capacity(128);
        let _ = write!(head, "{method} {path} HTTP/1.1\r\nHost: rmsmp\r\n");
        if method == "POST" || !body.is_empty() {
            let _ = write!(
                head,
                "Content-Type: application/json\r\nContent-Length: {}\r\n",
                body.len()
            );
        }
        head.push_str("\r\n");
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()?;
        self.read_response()
    }

    /// Write raw bytes verbatim (malformed-request tests), then read one
    /// response.
    pub fn send_raw(&mut self, raw: &[u8]) -> io::Result<ClientResponse> {
        self.stream.write_all(raw)?;
        self.stream.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> io::Result<ClientResponse> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "server closed"));
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad status line {line:?}"))
            })?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof in response headers",
                ));
            }
            let t = line.trim();
            if t.is_empty() {
                break;
            }
            if let Some((k, v)) = t.split_once(':') {
                let (k, v) = (k.trim().to_string(), v.trim().to_string());
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v.parse().unwrap_or(0);
                }
                headers.push((k, v));
            }
        }
        if status == 100 {
            // interim response: the real one follows
            return self.read_response();
        }
        let mut body = vec![0u8; content_length];
        self.reader.read_exact(&mut body)?;
        Ok(ClientResponse {
            status,
            headers,
            body: String::from_utf8_lossy(&body).into_owned(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8], max_body: usize) -> Result<Request, ReadError> {
        let stop = AtomicBool::new(false);
        let mut r = io::BufReader::new(Cursor::new(raw.to_vec()));
        let mut sink = Vec::new();
        read_request(&mut r, &mut sink, &stop, max_body)
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = parse(raw, 1024).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.content_length, Some(4));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn connection_close_and_http10_default() {
        let raw = b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        assert!(!parse(raw, 1024).unwrap().keep_alive);
        let raw = b"GET /metrics HTTP/1.0\r\n\r\n";
        assert!(!parse(raw, 1024).unwrap().keep_alive);
    }

    #[test]
    fn oversized_body_is_413_without_reading_it() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        match parse(raw, 16) {
            Err(ReadError::Bad(413, _)) => {}
            other => panic!("want 413, got {other:?}"),
        }
    }

    #[test]
    fn chunked_and_garbage_are_400() {
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        match parse(raw, 1024) {
            Err(ReadError::Bad(400, _)) => {}
            other => panic!("want 400, got {other:?}"),
        }
        match parse(b"this is not http\r\n\r\n", 1024) {
            Err(ReadError::Bad(400, _)) => {}
            other => panic!("want 400, got {other:?}"),
        }
    }

    #[test]
    fn clean_eof_is_closed() {
        match parse(b"", 1024) {
            Err(ReadError::Closed) => {}
            other => panic!("want Closed, got {other:?}"),
        }
    }

    #[test]
    fn expect_continue_gets_interim_response() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 2\r\nExpect: 100-continue\r\n\r\nok";
        let stop = AtomicBool::new(false);
        let mut r = io::BufReader::new(Cursor::new(raw.to_vec()));
        let mut sink = Vec::new();
        let req = read_request(&mut r, &mut sink, &stop, 1024).unwrap();
        assert_eq!(req.body, b"ok");
        assert!(sink.starts_with(b"HTTP/1.1 100 Continue"));
    }

    #[test]
    fn response_writer_formats_head() {
        let mut out = Vec::new();
        let mut scratch = String::new();
        write_response(&mut out, &mut scratch, 429, "application/json", &[("Retry-After", "1")], "{}", true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n\r\n{}"), "{text}");
    }
}
