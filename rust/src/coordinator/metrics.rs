//! Serving metrics: counters + latency reservoirs, shared across
//! workers, plus the per-stage inference-time breakdown (quantize /
//! im2col / gemm / epilogue) the workers drain from their executors
//! after every batch — the stats line that shows where batch time goes
//! (and, on the integer-resident pipeline, that the quantize and
//! epilogue stages have collapsed into the fused GEMM).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::model::StageTimes;
use crate::util::stats::{Reservoir, Welford};

/// Aggregated serving metrics (thread-safe).
#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests shed in-queue because their deadline expired.
    pub shed: AtomicU64,
    pub batches: AtomicU64,
    latency_ms: Mutex<Reservoir>,
    queue_ms: Mutex<Reservoir>,
    batch_size: Mutex<Welford>,
    /// Cumulative executor stage time across all workers, nanoseconds.
    stage_quantize_ns: AtomicU64,
    stage_im2col_ns: AtomicU64,
    stage_gemm_ns: AtomicU64,
    stage_epilogue_ns: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            latency_ms: Mutex::new(Reservoir::new(4096)),
            queue_ms: Mutex::new(Reservoir::new(4096)),
            batch_size: Mutex::new(Welford::new()),
            stage_quantize_ns: AtomicU64::new(0),
            stage_im2col_ns: AtomicU64::new(0),
            stage_gemm_ns: AtomicU64::new(0),
            stage_epilogue_ns: AtomicU64::new(0),
        }
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size.lock().unwrap().push(size as f64);
    }

    /// Count one deadline-expired request shed before the GEMM.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one executor's drained per-stage timings into the totals
    /// (workers call this with [`crate::model::Executor::take_stage_times`]
    /// after each batch).
    pub fn record_stages(&self, st: &StageTimes) {
        self.stage_quantize_ns.fetch_add(st.quantize_ns, Ordering::Relaxed);
        self.stage_im2col_ns.fetch_add(st.im2col_ns, Ordering::Relaxed);
        self.stage_gemm_ns.fetch_add(st.gemm_ns, Ordering::Relaxed);
        self.stage_epilogue_ns.fetch_add(st.epilogue_ns, Ordering::Relaxed);
    }

    /// Cumulative stage breakdown across all workers.
    pub fn stage_totals(&self) -> StageTimes {
        StageTimes {
            quantize_ns: self.stage_quantize_ns.load(Ordering::Relaxed),
            im2col_ns: self.stage_im2col_ns.load(Ordering::Relaxed),
            gemm_ns: self.stage_gemm_ns.load(Ordering::Relaxed),
            epilogue_ns: self.stage_epilogue_ns.load(Ordering::Relaxed),
        }
    }

    pub fn record_response(&self, total_ms: f64, queue_ms: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency_ms.lock().unwrap().push(total_ms);
        self.queue_ms.lock().unwrap().push(queue_ms);
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency_ms.lock().unwrap().percentile(p)
    }

    pub fn queue_percentile(&self, p: f64) -> f64 {
        self.queue_ms.lock().unwrap().percentile(p)
    }

    pub fn mean_batch_size(&self) -> f64 {
        self.batch_size.lock().unwrap().mean()
    }

    pub fn summary(&self) -> String {
        let st = self.stage_totals();
        let ms = |ns: u64| ns as f64 / 1e6;
        format!(
            "requests={} responses={} rejected={} shed={} batches={} mean_batch={:.2} \
             p50={:.2}ms p95={:.2}ms p99={:.2}ms queue_p95={:.2}ms \
             stages[quantize={:.2}ms im2col={:.2}ms gemm={:.2}ms epilogue={:.2}ms]",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency_percentile(50.0),
            self.latency_percentile(95.0),
            self.latency_percentile(99.0),
            self.queue_percentile(95.0),
            ms(st.quantize_ns),
            ms(st.im2col_ns),
            ms(st.gemm_ns),
            ms(st.epilogue_ns),
        )
    }

    /// Render this model's metrics in Prometheus text exposition format,
    /// appended to `out` with a `model` label on every sample — counters,
    /// latency/queue quantiles, mean batch size, and the per-stage
    /// executor time breakdown (quantize / im2col / gemm / epilogue).
    pub fn prometheus_into(&self, model: &str, out: &mut String) {
        use std::fmt::Write as _;

        let counters: [(&str, &str, u64); 5] = [
            ("rmsmp_requests_total", "Requests submitted", self.requests.load(Ordering::Relaxed)),
            ("rmsmp_responses_total", "Responses completed", self.responses.load(Ordering::Relaxed)),
            (
                "rmsmp_rejected_total",
                "Requests rejected by admission control or backpressure",
                self.rejected.load(Ordering::Relaxed),
            ),
            (
                "rmsmp_shed_total",
                "Requests shed in-queue on deadline expiry",
                self.shed.load(Ordering::Relaxed),
            ),
            ("rmsmp_batches_total", "Batches dispatched", self.batches.load(Ordering::Relaxed)),
        ];
        for (name, help, v) in counters {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name}{{model=\"{model}\"}} {v}");
        }

        let _ = writeln!(out, "# HELP rmsmp_batch_size_mean Mean dispatched batch size");
        let _ = writeln!(out, "# TYPE rmsmp_batch_size_mean gauge");
        let _ = writeln!(out, "rmsmp_batch_size_mean{{model=\"{model}\"}} {}", self.mean_batch_size());

        for (name, help, res) in [
            ("rmsmp_latency_ms", "End-to-end request latency", &self.latency_ms),
            ("rmsmp_queue_ms", "Time spent queued before dispatch", &self.queue_ms),
        ] {
            let _ = writeln!(out, "# HELP {name} {help} (milliseconds)");
            let _ = writeln!(out, "# TYPE {name} summary");
            let r = res.lock().unwrap();
            for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
                let _ = writeln!(
                    out,
                    "{name}{{model=\"{model}\",quantile=\"{q}\"}} {}",
                    r.percentile(p)
                );
            }
        }

        let st = self.stage_totals();
        let _ = writeln!(
            out,
            "# HELP rmsmp_stage_seconds_total Cumulative executor time per inference stage"
        );
        let _ = writeln!(out, "# TYPE rmsmp_stage_seconds_total counter");
        for (stage, ns) in [
            ("quantize", st.quantize_ns),
            ("im2col", st.im2col_ns),
            ("gemm", st.gemm_ns),
            ("epilogue", st.epilogue_ns),
        ] {
            let _ = writeln!(
                out,
                "rmsmp_stage_seconds_total{{model=\"{model}\",stage=\"{stage}\"}} {}",
                ns as f64 / 1e9
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_batch(2);
        m.record_batch(4);
        m.record_response(10.0, 1.0);
        m.record_response(20.0, 2.0);
        m.record_response(30.0, 3.0);
        assert_eq!(m.responses.load(Ordering::Relaxed), 3);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-9);
        assert!((m.latency_percentile(50.0) - 20.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("responses=3"), "{s}");
    }

    #[test]
    fn prometheus_text_exposition() {
        let m = Metrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.record_shed();
        m.record_batch(4);
        m.record_response(12.0, 3.0);
        m.record_stages(&StageTimes {
            quantize_ns: 1_000_000,
            im2col_ns: 0,
            gemm_ns: 500_000_000,
            epilogue_ns: 0,
        });
        let mut out = String::new();
        m.prometheus_into("resnet18", &mut out);
        assert!(out.contains("rmsmp_requests_total{model=\"resnet18\"} 2"), "{out}");
        assert!(out.contains("rmsmp_shed_total{model=\"resnet18\"} 1"), "{out}");
        assert!(
            out.contains("rmsmp_latency_ms{model=\"resnet18\",quantile=\"0.5\"} 12"),
            "{out}"
        );
        assert!(
            out.contains("rmsmp_stage_seconds_total{model=\"resnet18\",stage=\"gemm\"} 0.5"),
            "{out}"
        );
        assert!(out.contains("# TYPE rmsmp_requests_total counter"), "{out}");
    }

    #[test]
    fn accumulates_stage_breakdown() {
        let m = Metrics::new();
        m.record_stages(&StageTimes {
            quantize_ns: 1_000_000,
            im2col_ns: 2_000_000,
            gemm_ns: 30_000_000,
            epilogue_ns: 500_000,
        });
        m.record_stages(&StageTimes { gemm_ns: 10_000_000, ..StageTimes::default() });
        let st = m.stage_totals();
        assert_eq!(st.quantize_ns, 1_000_000);
        assert_eq!(st.im2col_ns, 2_000_000);
        assert_eq!(st.gemm_ns, 40_000_000);
        assert_eq!(st.epilogue_ns, 500_000);
        assert_eq!(st.total_ns(), 43_500_000);
        let s = m.summary();
        assert!(s.contains("gemm=40.00ms"), "{s}");
    }
}
