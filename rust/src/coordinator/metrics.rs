//! Serving metrics: counters + latency reservoirs, shared across workers.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::{Reservoir, Welford};

/// Aggregated serving metrics (thread-safe).
#[derive(Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    latency_ms: Mutex<Reservoir>,
    queue_ms: Mutex<Reservoir>,
    batch_size: Mutex<Welford>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            latency_ms: Mutex::new(Reservoir::new(4096)),
            queue_ms: Mutex::new(Reservoir::new(4096)),
            batch_size: Mutex::new(Welford::new()),
        }
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size.lock().unwrap().push(size as f64);
    }

    pub fn record_response(&self, total_ms: f64, queue_ms: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency_ms.lock().unwrap().push(total_ms);
        self.queue_ms.lock().unwrap().push(queue_ms);
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency_ms.lock().unwrap().percentile(p)
    }

    pub fn queue_percentile(&self, p: f64) -> f64 {
        self.queue_ms.lock().unwrap().percentile(p)
    }

    pub fn mean_batch_size(&self) -> f64 {
        self.batch_size.lock().unwrap().mean()
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} rejected={} batches={} mean_batch={:.2} \
             p50={:.2}ms p95={:.2}ms p99={:.2}ms queue_p95={:.2}ms",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency_percentile(50.0),
            self.latency_percentile(95.0),
            self.latency_percentile(99.0),
            self.queue_percentile(95.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_batch(2);
        m.record_batch(4);
        m.record_response(10.0, 1.0);
        m.record_response(20.0, 2.0);
        m.record_response(30.0, 3.0);
        assert_eq!(m.responses.load(Ordering::Relaxed), 3);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-9);
        assert!((m.latency_percentile(50.0) - 20.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("responses=3"), "{s}");
    }
}
