//! Integer GEMM cores — the software twins of the paper's heterogeneous
//! FPGA GEMM cores (§3.1, §4.1):
//!
//! * [`GemmFixed4`] / [`GemmFixed8`] — DSP-style multiply-accumulate over
//!   integer codes (i8 x u4 -> i32).
//! * [`GemmPoT4`] — LUT-style shift-add: each weight is (sign, shift), so
//!   a MAC is `acc += sign * (a << shift_adjust)`.
//! * [`mixed`] — the row-partitioned mixed GEMM: rows are grouped by
//!   scheme class and dispatched to their core, exactly like the FPGA
//!   routes filter classes to PE arrays. One entry point
//!   ([`MixedGemm::dispatch`] over a [`GemmCall`] descriptor) covers the
//!   explicit/implicit × f32/quantized kernel matrix; dispatch is
//!   multi-threaded and cache-blocked (see [`ParallelConfig`]),
//!   bit-exact vs the sequential path.
//! * [`depthwise`] — the grouped/depthwise conv driver: per-group
//!   implicit-GEMM dispatches over per-group task schedules, no
//!   materialized patch buffer.
//! * [`sorted`] — the class-sorted kernel layout ([`SortedWeights`]):
//!   rows permuted once at load so each class is one contiguous block,
//!   with the permutation kept for output scatter.
//! * [`panels`] — implicit-GEMM column-tile panel packing
//!   ([`ColTileSource`]): conv activations stream into per-lane
//!   cache-resident panels (gathered from NCHW codes or quantized from
//!   f32 on the fly) instead of a materialized im2col buffer.
//! * [`simd`] — runtime-dispatched micro-kernels ([`dot_block`], a
//!   tuned 4/6/8-row block height up to [`MAX_MICRO_ROWS`] rows) on a
//!   five-tier ISA ladder: AVX-512 VNNI, AVX2, SSE4.1, NEON
//!   dot-product, scalar. Every tier and height is bit-exact;
//!   `RMSMP_ISA=<tier>` forces a tier (clamped to the hardware) and
//!   `RMSMP_NO_SIMD=1` is a deprecated alias for `RMSMP_ISA=scalar`.
//! * [`autotune`] — the load-time microbenchmark the plan compiler runs
//!   per distinct layer signature to pick `micro_rows` / `tile_cols` /
//!   `min_rows_per_task` / panel bytes for *this* machine's registers
//!   and cache hierarchy ([`TunedParams`]); `RMSMP_NO_TUNE=1` keeps the
//!   fixed defaults and `RMSMP_TUNE_CACHE=path` persists winners across
//!   processes.
//!
//! All cores operate on *quantized codes* plus per-row scales, and their
//! float results are bit-identical to fake-quant matmuls over the same
//! data (see the gemm-consistency property tests), which is the property
//! that makes "simulated quantized inference" equal to "integer hardware
//! inference".

pub mod autotune;
pub mod cores;
pub(crate) mod depthwise;
pub mod mixed;
pub mod nibble;
pub mod packed;
pub mod panels;
pub mod simd;
pub mod sorted;

pub use autotune::{
    LayerSig, TuneShape, TuneSource, TuneStats, TunedParams, DEFAULT_PANEL_BYTES,
};
pub use cores::{requant_block, requant_row, GemmCore, GemmFixed4, GemmFixed8, GemmPoT4, Requant};
pub use mixed::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, OutLayout, ParallelConfig,
    QuantEpilogue, RowPartition, TaskChunk, DEFAULT_MICRO_ROWS, DEFAULT_MIN_ROWS_PER_TASK,
    DEFAULT_TILE_COLS,
};
pub use nibble::NibblePacked;
pub use packed::{ActsView, PackedActs, PackedWeights};
pub use panels::{pack_patch_rows, pack_quant_patch_rows, ColTileSource, PatchGeometry};
pub use simd::{
    dot_block, Isa, KernelIsa, ISA_LADDER, MAX_MICRO_ROWS, MICRO_ROWS, MICRO_ROWS_CANDIDATES,
};
pub use sorted::SortedWeights;
