//! Implicit-GEMM panel packing: the conv activation operand, one
//! column tile at a time.
//!
//! The explicit im2col path materializes the full `(N·OH·OW, C·k·k)`
//! patch matrix in DRAM before the mixed GEMM reads a single code — the
//! largest buffer in the workspace, written once and then re-streamed
//! from memory by every 4-row micro-kernel block. This module is the
//! software analogue of the FPGA's streaming datapath: a
//! [`ColTileSource`] describes where a conv's activation matrix comes
//! from (an NCHW code slot, an f32 feature map, or an already row-major
//! code buffer), and the GEMM dispatch asks it to *pack one
//! `panel_positions`-wide panel at a time* into a small per-lane scratch
//! buffer. The panel — a handful of output positions × the full patch
//! width, in u8 codes — fits in L1/L2 and is swept by **every** row
//! class and micro-kernel block of the layer while it is hot, so the
//! giant col buffer never exists.
//!
//! Three sources, one contract (the packed panel holds exactly the rows
//! the explicit path would have built, code for code):
//!
//! * [`ColTileSource::Codes`] — gather patch rows straight from a u8
//!   NCHW code slot (the integer-resident path). Padding packs the
//!   literal code 0 == the code of 0.0 (the activation quantizer is
//!   unsigned and zero-point-free).
//! * [`ColTileSource::F32`] — gather from an f32 NCHW slot and quantize
//!   **on the fly**, fusing the `PackedActs` pass into the gather (one
//!   multiply by the precomputed `n/alpha` reciprocal per element, clamp
//!   bounds hoisted out of the loop).
//! * [`ColTileSource::Packed`] — the 1×1 stride-1 pad-0 fast path: when
//!   the plan proves a code slot is only ever consumed by unit convs, the
//!   producer stores it NHWC (row-major positions × channels), and the
//!   "panel" is a plain subslice of the slot — no gather, no copy.
//!
//! The per-tile packer ([`pack_patch_rows`]) is also the kernel behind
//! the explicit `model::im2col` fronts (they pack the full row range in
//! one call), so the reference path and the implicit path share one
//! gather loop and stay bit-exact by construction.

use super::packed::{code_map, ActsView};

/// Output spatial size of one dimension for a (k, stride, pad) conv.
pub fn out_dim(in_dim: usize, k: usize, stride: usize, pad: usize) -> usize {
    (in_dim + 2 * pad - k) / stride + 1
}

/// The compiled gather geometry of one conv's activation operand: maps a
/// patch-matrix cell (GEMM row = output position, GEMM col = channel ×
/// kernel offset) to its NCHW source element. Carried per conv op by the
/// plan; `n` is the runtime batch, so instances are built per call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatchGeometry {
    /// Source NCHW dims.
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Channel range `c0..c0 + nc` (grouped conv packs one group).
    pub c0: usize,
    pub nc: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Output spatial dims (derived from h/w/k/stride/pad).
    pub oh: usize,
    pub ow: usize,
}

impl PatchGeometry {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        c0: usize,
        nc: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> PatchGeometry {
        PatchGeometry {
            n,
            c,
            h,
            w,
            c0,
            nc,
            k,
            stride,
            pad,
            oh: out_dim(h, k, stride, pad),
            ow: out_dim(w, k, stride, pad),
        }
    }

    /// GEMM batch rows (output positions across the batch).
    #[inline]
    pub fn batch(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// GEMM inner dim (patch width).
    #[inline]
    pub fn cols(&self) -> usize {
        self.nc * self.k * self.k
    }
}

/// Gather patch rows `b0..b0 + nb` of the im2col matrix from an NCHW
/// slice into `out` (`nb * g.cols()` elements, every one written;
/// padding positions get `zero`). This is the one copy of the gather
/// loop: the explicit `im2col_*` fronts call it over the full row range,
/// the implicit-GEMM dispatch per column tile — so both paths move the
/// same element to the same cell by construction.
pub fn pack_patch_rows<T: Copy>(
    data: &[T],
    zero: T,
    g: &PatchGeometry,
    b0: usize,
    nb: usize,
    out: &mut [T],
) {
    pack_rows_map(data, zero, g, b0, nb, out, |v| v)
}

/// [`pack_patch_rows`] fused with activation quantization: gather f32
/// values and write the consumer's u8 codes directly, skipping the f32
/// patch staging entirely. The reciprocal `n/alpha` and the clamp
/// ceiling are hoisted out of the gather loop; the per-element map is
/// [`code_map`], the same expression `PackedActs::quantize` applies, so
/// the packed codes are bit-identical to gather-then-quantize (padding's
/// 0.0 maps to code 0 for any positive alpha).
pub fn pack_quant_patch_rows(
    data: &[f32],
    g: &PatchGeometry,
    b0: usize,
    nb: usize,
    alpha: f32,
    bits: u32,
    out: &mut [u8],
) {
    let top = ((1u32 << bits) - 1) as f32;
    let inv = top / alpha;
    pack_rows_map(data, 0u8, g, b0, nb, out, move |v| code_map(v, inv, top))
}

/// The generic gather behind both packers: per-element map `f` applied
/// on the way through (identity for the plain copy, the hoisted
/// quantizer for the fused one).
fn pack_rows_map<S: Copy, D: Copy>(
    data: &[S],
    zero: D,
    g: &PatchGeometry,
    b0: usize,
    nb: usize,
    out: &mut [D],
    f: impl Fn(S) -> D,
) {
    assert_eq!(data.len(), g.n * g.c * g.h * g.w, "NCHW shape/data mismatch");
    assert!(g.c0 + g.nc <= g.c, "channel range out of bounds");
    assert!(b0 + nb <= g.batch(), "patch row range out of bounds");
    let cols = g.cols();
    assert_eq!(out.len(), nb * cols, "panel size mismatch");
    let hw = g.oh * g.ow;
    for i in 0..nb {
        let b = b0 + i;
        let img = b / hw;
        let rem = b % hw;
        let oy = rem / g.ow;
        let ox = rem % g.ow;
        let dst = &mut out[i * cols..(i + 1) * cols];
        if g.k == 1 && g.pad == 0 {
            // unit-kernel gather: one in-bounds element per channel
            // (oy*stride <= h-1 because oh = (h-1)/stride + 1), so the
            // padding checks vanish and the row is a strided channel walk
            let base = (img * g.c + g.c0) * g.h * g.w + (oy * g.stride) * g.w + ox * g.stride;
            for (dc, d) in dst.iter_mut().enumerate() {
                *d = f(data[base + dc * g.h * g.w]);
            }
        } else {
            let mut ci = 0;
            for dc in 0..g.nc {
                let plane = (img * g.c + g.c0 + dc) * g.h * g.w;
                for ky in 0..g.k {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for kx in 0..g.k {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        dst[ci] = if iy >= 0
                            && (iy as usize) < g.h
                            && ix >= 0
                            && (ix as usize) < g.w
                        {
                            f(data[plane + iy as usize * g.w + ix as usize])
                        } else {
                            zero
                        };
                        ci += 1;
                    }
                }
            }
        }
    }
}

/// Where a GEMM's activation operand comes from (see module docs). The
/// dispatch never sees a whole activation matrix — it asks the source
/// for one column tile at a time via [`ColTileSource::view`].
pub enum ColTileSource<'a> {
    /// Already row-major u8 activation codes (positions × cols): a code
    /// slot the plan retargeted to NHWC for the unit-conv fast path.
    /// Panels are subslices — no gather, no copy.
    Packed {
        codes: &'a [u8],
        rows: usize,
        cols: usize,
        alpha: f32,
        bits: u32,
    },
    /// Implicit im2col over a u8 NCHW code slot (integer-resident input).
    Codes {
        data: &'a [u8],
        geo: PatchGeometry,
        alpha: f32,
        bits: u32,
    },
    /// Implicit im2col over an f32 NCHW slot with on-the-fly
    /// quantization (the network input and other f32-resident edges).
    F32 {
        data: &'a [f32],
        geo: PatchGeometry,
        alpha: f32,
        bits: u32,
    },
}

impl<'a> ColTileSource<'a> {
    /// GEMM batch rows (output positions) this source produces.
    pub fn batch(&self) -> usize {
        match self {
            ColTileSource::Packed { rows, .. } => *rows,
            ColTileSource::Codes { geo, .. } | ColTileSource::F32 { geo, .. } => geo.batch(),
        }
    }

    /// GEMM inner dim (patch width).
    pub fn cols(&self) -> usize {
        match self {
            ColTileSource::Packed { cols, .. } => *cols,
            ColTileSource::Codes { geo, .. } | ColTileSource::F32 { geo, .. } => geo.cols(),
        }
    }

    /// The consumer's activation clip scale / width the codes carry.
    pub fn alpha(&self) -> f32 {
        match self {
            ColTileSource::Packed { alpha, .. }
            | ColTileSource::Codes { alpha, .. }
            | ColTileSource::F32 { alpha, .. } => *alpha,
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            ColTileSource::Packed { bits, .. }
            | ColTileSource::Codes { bits, .. }
            | ColTileSource::F32 { bits, .. } => *bits,
        }
    }

    /// Pack positions `b0..b0 + nb` into `scratch` (resized in place,
    /// allocation-free within its reserved capacity) and return the
    /// panel as a kernel-ready [`ActsView`]. The `Packed` source returns
    /// a subslice of its backing slot and never touches `scratch`.
    pub fn view<'p>(&'p self, b0: usize, nb: usize, scratch: &'p mut Vec<u8>) -> ActsView<'p> {
        let cols = self.cols();
        let codes: &[u8] = match self {
            ColTileSource::Packed { codes, rows, .. } => {
                assert!(b0 + nb <= *rows, "panel range out of bounds");
                &codes[b0 * cols..(b0 + nb) * cols]
            }
            ColTileSource::Codes { data, geo, .. } => {
                scratch.resize(nb * cols, 0);
                pack_patch_rows(data, 0u8, geo, b0, nb, scratch);
                scratch
            }
            ColTileSource::F32 { data, geo, alpha, bits } => {
                scratch.resize(nb * cols, 0);
                pack_quant_patch_rows(data, geo, b0, nb, *alpha, *bits, scratch);
                scratch
            }
        };
        ActsView { codes, rows: nb, cols, alpha: self.alpha(), bits: self.bits() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::packed::PackedActs;
    use crate::quant::Mat;
    use crate::util::rng::Rng;

    fn rand_nchw(n: usize, c: usize, h: usize, w: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * c * h * w).map(|_| rng.uniform(-0.2, 1.3)).collect()
    }

    #[test]
    fn tiled_packing_equals_full_gather() {
        // packing any tile decomposition must reproduce the full-range
        // gather row for row
        let (n, c, h, w) = (2usize, 3usize, 7usize, 6usize);
        let data = rand_nchw(n, c, h, w, 5);
        let cases = [(3, 1, 1, 0, 3), (3, 2, 0, 1, 2), (1, 1, 0, 0, 3), (1, 2, 0, 0, 3)];
        for (k, s, p, c0, nc) in cases {
            let g = PatchGeometry::new(n, c, h, w, c0, nc, k, s, p);
            let mut full = vec![0.0f32; g.batch() * g.cols()];
            pack_patch_rows(&data, 0.0, &g, 0, g.batch(), &mut full);
            for tile in [1usize, 3, 5, g.batch()] {
                let mut b0 = 0;
                while b0 < g.batch() {
                    let nb = tile.min(g.batch() - b0);
                    let mut panel = vec![f32::NAN; nb * g.cols()];
                    pack_patch_rows(&data, 0.0, &g, b0, nb, &mut panel);
                    assert_eq!(
                        &panel[..],
                        &full[b0 * g.cols()..(b0 + nb) * g.cols()],
                        "k{k} s{s} p{p} tile {tile} b0 {b0}"
                    );
                    b0 += nb;
                }
            }
        }
    }

    #[test]
    fn fused_quant_pack_equals_gather_then_quantize() {
        let (n, c, h, w) = (1usize, 2usize, 5usize, 5usize);
        let data = rand_nchw(n, c, h, w, 9);
        let (alpha, bits) = (0.9f32, 4u32);
        let g = PatchGeometry::new(n, c, h, w, 0, c, 3, 1, 1);
        let mut fpatch = vec![0.0f32; g.batch() * g.cols()];
        pack_patch_rows(&data, 0.0, &g, 0, g.batch(), &mut fpatch);
        let want = PackedActs::quantize(
            &Mat::from_vec(g.batch(), g.cols(), fpatch),
            alpha,
            bits,
        );
        let mut got = vec![0xffu8; g.batch() * g.cols()];
        pack_quant_patch_rows(&data, &g, 0, g.batch(), alpha, bits, &mut got);
        assert_eq!(got, want.codes);
    }

    #[test]
    fn packed_source_views_are_aliases() {
        let codes: Vec<u8> = (0..24).map(|i| i as u8).collect();
        let src =
            ColTileSource::Packed { codes: &codes, rows: 6, cols: 4, alpha: 1.0, bits: 4 };
        let mut scratch = Vec::new();
        let v = src.view(2, 3, &mut scratch);
        assert_eq!(v.rows, 3);
        assert_eq!(v.codes, &codes[8..20]);
        // the alias never stages through the scratch buffer
        assert_eq!(scratch.capacity(), 0);
    }

    #[test]
    fn code_source_matches_f32_source_cell_for_cell() {
        // quantize-then-pack must equal pack-then-quantize: the code
        // gather moves codes exactly where the fused f32 gather writes
        // the quantized value (padding's code 0 == code of 0.0)
        let (n, c, h, w) = (2usize, 2usize, 4usize, 5usize);
        let data = rand_nchw(n, c, h, w, 13);
        let (alpha, bits) = (1.1f32, 4u32);
        let top = ((1u32 << bits) - 1) as f32;
        let inv = top / alpha;
        let codes: Vec<u8> = data.iter().map(|&v| code_map(v, inv, top)).collect();
        let g = PatchGeometry::new(n, c, h, w, 0, c, 3, 2, 1);
        let csrc = ColTileSource::Codes { data: &codes, geo: g, alpha, bits };
        let fsrc = ColTileSource::F32 { data: &data, geo: g, alpha, bits };
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        let batch = g.batch();
        for b0 in 0..batch {
            let a = csrc.view(b0, 1, &mut s1);
            let b = fsrc.view(b0, 1, &mut s2);
            assert_eq!(a.codes, b.codes, "row {b0}");
        }
    }

    #[test]
    fn unit_geometry_preserves_dims() {
        let g = PatchGeometry::new(1, 4, 6, 6, 0, 4, 1, 1, 0);
        assert_eq!((g.oh, g.ow), (6, 6));
        assert_eq!(g.cols(), 4);
        assert_eq!(g.batch(), 36);
    }
}
