//! Load-time autotuner: microbench the machine, bake the winners into
//! the compiled plan.
//!
//! The kernel layer's blocking knobs — the column-tile width
//! (`tile_cols`), the parallel chunk granularity (`min_rows_per_task`),
//! and the implicit-GEMM panel budget (bytes per streamed column-tile
//! panel) — encode assumptions about cache sizes and core counts that
//! hold on the dev box and nowhere else in a heterogeneous fleet. RMSMP's
//! premise is hardware-informed quantization; this module applies the
//! same discipline one level down: at plan-compile time
//! ([`crate::model::PlanBuilder::build`]), [`tune`] runs the real
//! [`MixedGemm::dispatch`] path over a synthetic workload shaped like the
//! model's largest layer (same 65:30:5 scheme mix as the benches, same
//! class-sorted layout, same chunk schedules) for a small candidate grid,
//! and returns the fastest [`TunedParams`].
//!
//! Contracts that keep tuning safe:
//!
//! * **Bit-exactness is never at stake.** The integer cores are
//!   tile-size-independent (i32 accumulation is associative) and panel
//!   width / chunk schedule never change per-cell arithmetic, so a tuned
//!   plan produces logits bit-identical to the default plan. The one
//!   exception — the f32-accumulating APoT baseline core is only
//!   deterministic for a *fixed* `tile_cols` — is handled by the caller
//!   pinning the tile (`pin_tile`) whenever the model carries APoT rows.
//! * **Explicit knobs win.** A [`ParallelConfig`] field that differs from
//!   its documented default ([`DEFAULT_TILE_COLS`] /
//!   [`DEFAULT_MIN_ROWS_PER_TASK`]) is a caller decision; [`TunedParams::
//!   apply_to`] leaves it alone and tuning only fills the knobs still at
//!   their defaults.
//! * **A winner must beat the default decisively.** Candidates replace
//!   the default only on a >2% improvement in the microbench, so noise
//!   cannot regress the shipped defaults — the tuned plan is >= the
//!   fixed-default plan by construction (up to microbench noise on real
//!   workloads).
//! * **Deterministic escape hatch.** `RMSMP_NO_TUNE=1` (checked by the
//!   plan builder via [`no_tune_requested`]) skips the microbench and
//!   keeps today's fixed defaults — reproducible tests and benchable
//!   ablations.
//!
//! Results are cached per process (keyed by workload shape, thread
//! count, and the pinned/explicit knobs), so a server compiling many
//! plans pays for the microbench once.

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::mixed::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, ParallelConfig,
    DEFAULT_MIN_ROWS_PER_TASK, DEFAULT_TILE_COLS,
};
use super::packed::{PackedActs, PackedWeights};
use super::sorted::SortedWeights;
use crate::quant::{Mat, Scheme};
use crate::util::rng::Rng;

/// The untuned implicit-GEMM panel budget: bytes of activation codes per
/// streamed column-tile panel (the pre-autotuner compile-time constant).
pub const DEFAULT_PANEL_BYTES: usize = 32 * 1024;

/// Candidate `tile_cols` widths (the default stays in the grid so it is
/// always measured as the baseline).
const TILE_CANDIDATES: [usize; 4] = [64, 128, DEFAULT_TILE_COLS, 512];
/// Candidate parallel chunk granularities.
const CHUNK_CANDIDATES: [usize; 3] = [4, DEFAULT_MIN_ROWS_PER_TASK, 16];
/// Candidate panel budgets.
const PANEL_CANDIDATES: [usize; 3] = [16 * 1024, DEFAULT_PANEL_BYTES, 64 * 1024];

/// A candidate must beat the incumbent by this factor to replace it —
/// the noise guard that keeps tuning monotone vs the defaults.
const IMPROVEMENT: f64 = 0.98;

/// Microbench workload shape — the model's largest GEMM layer, clamped
/// to keep the load-time cost bounded.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TuneShape {
    /// Weight rows (output channels) of the synthetic layer.
    pub rows: usize,
    /// Columns (reduction depth) of the synthetic layer.
    pub cols: usize,
    /// Activation rows per dispatch (batch, or panel positions).
    pub batch: usize,
}

impl TuneShape {
    /// Shape for a model whose largest layer is `rows x cols` with up to
    /// `batch` activation rows in flight, clamped so one microbench
    /// dispatch stays in the low-millisecond range.
    pub fn for_layer(rows: usize, cols: usize, batch: usize) -> TuneShape {
        TuneShape {
            rows: rows.clamp(16, 64),
            cols: cols.clamp(32, 1024),
            batch: batch.clamp(8, 64),
        }
    }
}

/// Where a plan's blocking parameters came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneSource {
    /// Chosen by the load-time microbench.
    Tuned,
    /// The fixed compile-time defaults (`RMSMP_NO_TUNE`, or a builder
    /// that opted out).
    Defaults,
}

impl TuneSource {
    /// Short label for plan descriptions and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            TuneSource::Tuned => "tuned",
            TuneSource::Defaults => "defaults",
        }
    }
}

/// The blocking parameters a compiled plan bakes in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TunedParams {
    /// Column-tile width for the packed inner loops.
    pub tile_cols: usize,
    /// Parallel chunk granularity (rows per task).
    pub min_rows_per_task: usize,
    /// Implicit-GEMM panel budget in bytes (positions per panel =
    /// `panel_bytes / layer cols`, clamped as before).
    pub panel_bytes: usize,
    /// Whether these came from the microbench or the fixed defaults.
    pub source: TuneSource,
}

impl TunedParams {
    /// The untuned parameters for `cfg` (the `RMSMP_NO_TUNE` path):
    /// whatever the config says, plus the fixed panel budget.
    pub fn defaults(cfg: &ParallelConfig) -> TunedParams {
        TunedParams {
            tile_cols: cfg.tile_cols,
            min_rows_per_task: cfg.min_rows_per_task,
            panel_bytes: DEFAULT_PANEL_BYTES,
            source: TuneSource::Defaults,
        }
    }

    /// Merge into `cfg` under the explicit-wins contract: a knob still at
    /// its documented default takes the tuned value, anything else was an
    /// explicit caller choice and is kept.
    pub fn apply_to(&self, cfg: ParallelConfig) -> ParallelConfig {
        ParallelConfig {
            threads: cfg.threads,
            tile_cols: if cfg.tile_cols == DEFAULT_TILE_COLS {
                self.tile_cols
            } else {
                cfg.tile_cols
            },
            min_rows_per_task: if cfg.min_rows_per_task == DEFAULT_MIN_ROWS_PER_TASK {
                self.min_rows_per_task
            } else {
                cfg.min_rows_per_task
            },
        }
    }
}

/// Whether `RMSMP_NO_TUNE` asks for the deterministic fixed defaults
/// (any non-empty value other than `"0"`).
pub fn no_tune_requested() -> bool {
    std::env::var("RMSMP_NO_TUNE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

type CacheKey = (TuneShape, usize, bool, usize, usize);
static CACHE: OnceLock<Mutex<Vec<(CacheKey, TunedParams)>>> = OnceLock::new();

/// Microbench the candidate grids for `shape` and return the winners.
/// `cfg` supplies the baseline knobs (and the thread count: chunk
/// granularity is only tuned when the config resolves to >1 thread);
/// `pin_tile` keeps `tile_cols` at the configured value (required when
/// the model carries f32-accumulating APoT rows, whose results are only
/// deterministic for a fixed tile). Results are cached per process.
///
/// This runs at plan-compile (load) time, so its allocations do not
/// disturb the zero-steady-state-allocation property of inference.
pub fn tune(shape: TuneShape, cfg: &ParallelConfig, pin_tile: bool) -> TunedParams {
    let threads = if cfg.threads == 1 { 1 } else { cfg.resolved_threads() };
    let key = (shape, threads, pin_tile, cfg.tile_cols, cfg.min_rows_per_task);
    let cache = CACHE.get_or_init(|| Mutex::new(Vec::new()));
    if let Ok(hits) = cache.lock() {
        if let Some((_, p)) = hits.iter().find(|(k, _)| *k == key) {
            return *p;
        }
    }
    let params = tune_uncached(shape, cfg, threads, pin_tile);
    if let Ok(mut hits) = cache.lock() {
        hits.push((key, params));
    }
    params
}

/// One synthetic workload: a 65:30:5 Fixed-4 / PoT-4 / Fixed-8 row mix
/// (the repo's canonical scheme ratio) in the class-sorted layout, plus
/// 4-bit activations with `batch` rows.
struct Workload {
    acts: PackedActs,
    sorted: SortedWeights,
    rows: usize,
}

impl Workload {
    fn build(rows: usize, cols: usize, batch: usize) -> Workload {
        let mut rng = Rng::new(0x7a11e7);
        let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect();
        let x = Mat::from_vec(batch, cols, xd);
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.4));
        let alpha: Vec<f32> =
            (0..rows).map(|r| crate::quant::default_alpha(w.row(r))).collect();
        let schemes: Vec<Scheme> = (0..rows)
            .map(|r| {
                if r * 20 < rows * 13 {
                    Scheme::FixedW4A4
                } else if r * 20 < rows * 19 {
                    Scheme::PotW4A4
                } else {
                    Scheme::FixedW8A4
                }
            })
            .collect();
        let packed = PackedWeights::quantize(&w, &schemes, &alpha);
        let sorted = SortedWeights::from_packed(&packed);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        Workload { acts, sorted, rows }
    }

    /// Best-of-`iters` wall time of one full dispatch (after one
    /// warmup), in nanoseconds.
    fn time(
        &self,
        gemm: &MixedGemm,
        min_rows: usize,
        parallel: bool,
        scratch: &mut GemmScratch,
        out: &mut Mat,
    ) -> u64 {
        let chunks = chunk_tasks(self.sorted.partition(), min_rows);
        let mut best = u64::MAX;
        for it in 0..4 {
            let t = Instant::now();
            gemm.dispatch(
                GemmCall {
                    acts: GemmActs::Packed(&self.acts),
                    weights: &self.sorted,
                    chunks: &chunks,
                    parallel,
                    fill: true,
                    out: GemmOut::F32(out),
                },
                scratch,
            );
            let ns = t.elapsed().as_nanos() as u64;
            if it > 0 {
                best = best.min(ns);
            }
        }
        best
    }
}

/// Sequential engine with one knob overridden.
fn engine(tile_cols: usize) -> MixedGemm {
    MixedGemm::with_config(ParallelConfig {
        threads: 1,
        tile_cols,
        min_rows_per_task: DEFAULT_MIN_ROWS_PER_TASK,
    })
}

fn tune_uncached(
    shape: TuneShape,
    cfg: &ParallelConfig,
    threads: usize,
    pin_tile: bool,
) -> TunedParams {
    let wl = Workload::build(shape.rows, shape.cols, shape.batch);
    let mut scratch = GemmScratch::new(1);
    let mut out = Mat::zeros(shape.batch, wl.rows);

    // tile_cols: sequential sweep, incumbent = the configured value
    let mut tile_cols = cfg.tile_cols;
    if !pin_tile {
        let mut best =
            wl.time(&engine(tile_cols), cfg.min_rows_per_task, false, &mut scratch, &mut out);
        for cand in TILE_CANDIDATES {
            if cand == cfg.tile_cols {
                continue;
            }
            let ns = wl.time(&engine(cand), cfg.min_rows_per_task, false, &mut scratch, &mut out);
            if (ns as f64) < best as f64 * IMPROVEMENT {
                best = ns;
                tile_cols = cand;
            }
        }
    }

    // panel budget: the implicit-GEMM path processes `panel_bytes / cols`
    // positions per dispatch; proxy each candidate with a packed GEMM at
    // that batch height and compare per-element cost (cache-resident
    // panels win, spilled ones lose, tiny ones waste amortization).
    let mut panel_bytes = DEFAULT_PANEL_BYTES;
    {
        let tile_engine = engine(tile_cols);
        let positions = |pb: usize| (pb / shape.cols.max(1)).clamp(8, 256);
        let per_elem = |pb: usize, scratch: &mut GemmScratch| {
            let p = positions(pb);
            let pwl = Workload::build(shape.rows, shape.cols, p);
            let mut pout = Mat::zeros(p, pwl.rows);
            let ns = pwl.time(&tile_engine, cfg.min_rows_per_task, false, scratch, &mut pout);
            ns as f64 / (p * shape.rows * shape.cols) as f64
        };
        let mut best = per_elem(DEFAULT_PANEL_BYTES, &mut scratch);
        for cand in PANEL_CANDIDATES {
            if cand == DEFAULT_PANEL_BYTES || positions(cand) == positions(DEFAULT_PANEL_BYTES) {
                continue;
            }
            let c = per_elem(cand, &mut scratch);
            if c < best * IMPROVEMENT {
                best = c;
                panel_bytes = cand;
            }
        }
    }

    // chunk granularity: only meaningful with a pool; sweep real parallel
    // dispatches so scheduling overhead vs balance is actually measured
    let mut min_rows = cfg.min_rows_per_task;
    if threads > 1 {
        let par = MixedGemm::with_config(ParallelConfig {
            threads,
            tile_cols,
            min_rows_per_task: cfg.min_rows_per_task,
        });
        let mut pscratch = GemmScratch::new(par.lanes());
        let mut best = wl.time(&par, min_rows, true, &mut pscratch, &mut out);
        for cand in CHUNK_CANDIDATES {
            if cand == cfg.min_rows_per_task {
                continue;
            }
            let ns = wl.time(&par, cand, true, &mut pscratch, &mut out);
            if (ns as f64) < best as f64 * IMPROVEMENT {
                best = ns;
                min_rows = cand;
            }
        }
    }

    TunedParams { tile_cols, min_rows_per_task: min_rows, panel_bytes, source: TuneSource::Tuned }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reflect_config_and_are_marked() {
        let cfg = ParallelConfig { threads: 1, tile_cols: 33, min_rows_per_task: 5 };
        let p = TunedParams::defaults(&cfg);
        assert_eq!(p.tile_cols, 33);
        assert_eq!(p.min_rows_per_task, 5);
        assert_eq!(p.panel_bytes, DEFAULT_PANEL_BYTES);
        assert_eq!(p.source, TuneSource::Defaults);
        assert_eq!(p.source.name(), "defaults");
    }

    #[test]
    fn apply_to_lets_explicit_knobs_win() {
        let tuned = TunedParams {
            tile_cols: 128,
            min_rows_per_task: 16,
            panel_bytes: 64 * 1024,
            source: TuneSource::Tuned,
        };
        // defaults are replaced by the tuned values
        let base = ParallelConfig { threads: 3, ..ParallelConfig::default() };
        let merged = tuned.apply_to(base);
        assert_eq!(merged.threads, 3);
        assert_eq!(merged.tile_cols, 128);
        assert_eq!(merged.min_rows_per_task, 16);
        // explicit values survive
        let explicit = ParallelConfig { threads: 1, tile_cols: 48, min_rows_per_task: 2 };
        let kept = tuned.apply_to(explicit);
        assert_eq!(kept.tile_cols, 48);
        assert_eq!(kept.min_rows_per_task, 2);
    }

    #[test]
    fn shape_is_clamped_to_the_microbench_budget() {
        let s = TuneShape::for_layer(4096, 100_000, 9999);
        assert_eq!(s, TuneShape { rows: 64, cols: 1024, batch: 64 });
        let t = TuneShape::for_layer(1, 1, 1);
        assert_eq!(t, TuneShape { rows: 16, cols: 32, batch: 8 });
    }

    #[test]
    fn tune_picks_candidates_and_caches() {
        let cfg = ParallelConfig::sequential();
        let shape = TuneShape::for_layer(16, 48, 8);
        let a = tune(shape, &cfg, false);
        assert_eq!(a.source, TuneSource::Tuned);
        assert!(
            TILE_CANDIDATES.contains(&a.tile_cols) || a.tile_cols == cfg.tile_cols,
            "tile {}",
            a.tile_cols
        );
        assert!(PANEL_CANDIDATES.contains(&a.panel_bytes));
        // sequential config never tunes the chunk granularity
        assert_eq!(a.min_rows_per_task, cfg.min_rows_per_task);
        // second call is a cache hit with an identical answer
        let b = tune(shape, &cfg, false);
        assert_eq!(a, b);
    }

    #[test]
    fn pinned_tile_is_never_changed() {
        let cfg = ParallelConfig::sequential();
        let shape = TuneShape::for_layer(16, 40, 8);
        let p = tune(shape, &cfg, true);
        assert_eq!(p.tile_cols, cfg.tile_cols);
        assert_eq!(p.source, TuneSource::Tuned);
    }
}
