//! Load-time autotuner: microbench the machine per layer, bake the
//! winners into the compiled plan, and persist them across processes.
//!
//! The kernel layer's blocking knobs — the micro-kernel row-block
//! height (`micro_rows`), the column-tile width (`tile_cols`), the
//! parallel chunk granularity (`min_rows_per_task`), and the
//! implicit-GEMM panel budget (bytes per streamed column-tile panel) —
//! encode assumptions about register files, cache sizes, and core
//! counts that hold on the dev box and nowhere else in a heterogeneous
//! fleet. RMSMP's premise is hardware-informed quantization; this
//! module applies the same discipline one level down: at plan-compile
//! time ([`crate::model::PlanBuilder::build`]), [`tune_layer`] runs the
//! real [`MixedGemm::dispatch`] path over a synthetic workload shaped
//! like **each distinct layer** of the model — same row/col/batch
//! shape (clamped to a microbench budget), same scheme mix, same
//! class-sorted layout, same chunk schedules — for a small candidate
//! grid, and returns the fastest [`TunedParams`] per layer signature.
//!
//! Contracts that keep tuning safe:
//!
//! * **Bit-exactness is never at stake.** The integer cores are
//!   blocking-independent (i32 accumulation per cell is associative and
//!   independent of how rows are grouped into `micro_rows` blocks), and
//!   panel width / chunk schedule never change per-cell arithmetic, so
//!   a tuned plan produces logits bit-identical to the default plan.
//!   The one exception — the f32-accumulating APoT baseline core is
//!   only deterministic for a *fixed* `tile_cols` — is handled by the
//!   caller pinning the tile (`pin_tile`) whenever the model carries
//!   APoT rows. (`micro_rows` is safe even for APoT: its core sweeps
//!   row-at-a-time inside the block, so per-row accumulation order
//!   depends only on `tile_cols`.)
//! * **Explicit knobs win.** A [`ParallelConfig`] field that differs
//!   from its documented default ([`DEFAULT_TILE_COLS`] /
//!   [`DEFAULT_MIN_ROWS_PER_TASK`] / [`DEFAULT_MICRO_ROWS`]) is a
//!   caller decision; [`TunedParams::apply_to`] leaves it alone and
//!   tuning only fills the knobs still at their defaults.
//! * **A winner must beat the default decisively.** Candidates replace
//!   the default only on a >2% improvement in the microbench, so noise
//!   cannot regress the shipped defaults — the tuned plan is >= the
//!   fixed-default plan by construction (up to microbench noise on real
//!   workloads).
//! * **Deterministic escape hatch.** `RMSMP_NO_TUNE=1` (checked by the
//!   plan builder via [`no_tune_requested`]) skips the microbench and
//!   keeps today's fixed defaults — reproducible tests and benchable
//!   ablations.
//!
//! # Result caching
//!
//! Results are cached at two levels:
//!
//! * **Per process** (keyed by layer signature, thread count, and the
//!   pinned/explicit knobs), so a server compiling many plans pays for
//!   each distinct layer's microbench once.
//! * **On disk**, when the caller passes a cache path (the plan builder
//!   forwards `RMSMP_TUNE_CACHE=path` or its `--tune-cache` flag): a
//!   small versioned text file keyed by kernel ISA tier + layer
//!   signature + thread count + tuning schema version. A warm cache
//!   makes the second load of the same model on the same machine type
//!   skip the microbench entirely — fleets bake the file into the
//!   machine image once per hardware generation. Corrupt, stale, or
//!   foreign-version files are ignored (never an error), and writes go
//!   through a write-to-temp + rename so concurrent writers cannot
//!   tear the file.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::mixed::{
    chunk_tasks, GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, ParallelConfig,
    RowPartition, DEFAULT_MICRO_ROWS, DEFAULT_MIN_ROWS_PER_TASK, DEFAULT_TILE_COLS,
};
use super::packed::{PackedActs, PackedWeights};
use super::simd::{Isa, MAX_MICRO_ROWS, MICRO_ROWS_CANDIDATES};
use super::sorted::SortedWeights;
use crate::quant::{Mat, Scheme};
use crate::util::rng::Rng;

/// The untuned implicit-GEMM panel budget: bytes of activation codes per
/// streamed column-tile panel (the pre-autotuner compile-time constant).
pub const DEFAULT_PANEL_BYTES: usize = 32 * 1024;

/// Candidate `tile_cols` widths (the default stays in the grid so it is
/// always measured as the baseline).
const TILE_CANDIDATES: [usize; 4] = [64, 128, DEFAULT_TILE_COLS, 512];
/// Candidate parallel chunk granularities.
const CHUNK_CANDIDATES: [usize; 3] = [4, DEFAULT_MIN_ROWS_PER_TASK, 16];
/// Candidate panel budgets.
const PANEL_CANDIDATES: [usize; 3] = [16 * 1024, DEFAULT_PANEL_BYTES, 64 * 1024];

/// A candidate must beat the incumbent by this factor to replace it —
/// the noise guard that keeps tuning monotone vs the defaults.
const IMPROVEMENT: f64 = 0.98;

/// Version tag of the on-disk tune-cache schema. Bump whenever the key
/// or value layout changes — readers ignore files with any other
/// header, falling back to the live microbench. v2 = the first
/// persisted schema (per-layer signatures + `micro_rows` in the value).
const CACHE_HEADER: &str = "rmsmp-tune-cache v2";

/// Microbench workload shape — one GEMM layer, clamped to keep the
/// load-time cost bounded.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TuneShape {
    /// Weight rows (output channels) of the synthetic layer.
    pub rows: usize,
    /// Columns (reduction depth) of the synthetic layer.
    pub cols: usize,
    /// Activation rows per dispatch (batch, or panel positions).
    pub batch: usize,
}

impl TuneShape {
    /// Shape for a layer of `rows x cols` with up to `batch` activation
    /// rows in flight, clamped so one microbench dispatch stays in the
    /// low-millisecond range.
    pub fn for_layer(rows: usize, cols: usize, batch: usize) -> TuneShape {
        TuneShape {
            rows: rows.clamp(16, 64),
            cols: cols.clamp(32, 1024),
            batch: batch.clamp(8, 64),
        }
    }
}

/// The identity of one layer for tuning purposes: its GEMM shape plus
/// its per-class row counts (in [`RowPartition::CLASS_ORDER`] order).
/// Layers sharing a signature share one microbench — the plan builder
/// dedups by this before calling [`tune_layer`], and it is the layer
/// part of the on-disk cache key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LayerSig {
    /// Weight rows (output channels).
    pub rows: usize,
    /// Columns (reduction depth: `in_ch/groups * k * k` for convs).
    pub cols: usize,
    /// Activation rows per dispatch (batch x spatial positions,
    /// or the linear batch).
    pub batch: usize,
    /// Rows per scheme class, [`RowPartition::CLASS_ORDER`] order.
    pub counts: [usize; 4],
}

impl LayerSig {
    /// Signature of a layer with the canonical 65:30:5 Fixed-4 / PoT-4 /
    /// Fixed-8 mix (the repo's benchmark ratio) — the shape-only entry
    /// point used when no real scheme assignment is at hand.
    pub fn canonical(rows: usize, cols: usize, batch: usize) -> LayerSig {
        let fixed4 = rows * 13 / 20;
        let pot4 = rows * 19 / 20 - fixed4;
        let fixed8 = rows - fixed4 - pot4;
        LayerSig { rows, cols, batch, counts: [pot4, fixed4, fixed8, 0] }
    }

    /// The clamped microbench shape for this signature.
    fn shape(&self) -> TuneShape {
        TuneShape::for_layer(self.rows, self.cols, self.batch)
    }

    /// Scheme mix for the clamped workload: the layer's class ratios
    /// scaled to `rows` synthetic rows (largest-class gets the rounding
    /// remainder so the counts always sum to `rows`).
    fn scaled_counts(&self, rows: usize) -> [usize; 4] {
        let total: usize = self.counts.iter().sum();
        if total == 0 {
            return LayerSig::canonical(rows, 1, 1).counts;
        }
        let mut scaled = [0usize; 4];
        for k in 0..4 {
            scaled[k] = self.counts[k] * rows / total;
        }
        let used: usize = scaled.iter().sum();
        let biggest =
            (0..4).max_by_key(|&k| self.counts[k]).expect("four classes");
        scaled[biggest] += rows - used;
        scaled
    }
}

/// Where a plan's blocking parameters came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneSource {
    /// Chosen by a live load-time microbench in this process.
    Tuned,
    /// Loaded from the persisted on-disk tune cache (no microbench ran
    /// for this signature in this process).
    DiskCache,
    /// The fixed compile-time defaults (`RMSMP_NO_TUNE`, or a builder
    /// that opted out).
    Defaults,
}

impl TuneSource {
    /// Short label for plan descriptions and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            TuneSource::Tuned => "tuned",
            TuneSource::DiskCache => "disk-cache",
            TuneSource::Defaults => "defaults",
        }
    }
}

/// The blocking parameters a compiled plan bakes in (per layer
/// signature).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TunedParams {
    /// Micro-kernel row-block height (see
    /// [`ParallelConfig::micro_rows`]).
    pub micro_rows: usize,
    /// Column-tile width for the packed inner loops.
    pub tile_cols: usize,
    /// Parallel chunk granularity (rows per task).
    pub min_rows_per_task: usize,
    /// Implicit-GEMM panel budget in bytes (positions per panel =
    /// `panel_bytes / layer cols`, clamped as before).
    pub panel_bytes: usize,
    /// Whether these came from a microbench, the disk cache, or the
    /// fixed defaults.
    pub source: TuneSource,
}

impl TunedParams {
    /// The untuned parameters for `cfg` (the `RMSMP_NO_TUNE` path):
    /// whatever the config says, plus the fixed panel budget.
    pub fn defaults(cfg: &ParallelConfig) -> TunedParams {
        TunedParams {
            micro_rows: cfg.micro_rows,
            tile_cols: cfg.tile_cols,
            min_rows_per_task: cfg.min_rows_per_task,
            panel_bytes: DEFAULT_PANEL_BYTES,
            source: TuneSource::Defaults,
        }
    }

    /// Merge into `cfg` under the explicit-wins contract: a knob still at
    /// its documented default takes the tuned value, anything else was an
    /// explicit caller choice and is kept.
    pub fn apply_to(&self, cfg: ParallelConfig) -> ParallelConfig {
        ParallelConfig {
            threads: cfg.threads,
            tile_cols: if cfg.tile_cols == DEFAULT_TILE_COLS {
                self.tile_cols
            } else {
                cfg.tile_cols
            },
            min_rows_per_task: if cfg.min_rows_per_task == DEFAULT_MIN_ROWS_PER_TASK {
                self.min_rows_per_task
            } else {
                cfg.min_rows_per_task
            },
            micro_rows: if cfg.micro_rows == DEFAULT_MICRO_ROWS {
                self.micro_rows
            } else {
                cfg.micro_rows
            },
        }
    }
}

/// Per-plan-compile tuning provenance counters: how many distinct layer
/// signatures were answered from a cache (process or disk) vs by a live
/// microbench. `cache_misses == 0` is the "warm cache skipped every
/// microbench" assertion the tests and CI lean on; the runtime bench
/// reports `cache_hits` as `tune_cache_hits`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TuneStats {
    /// Signatures answered without running a microbench.
    pub cache_hits: usize,
    /// Signatures that ran the live microbench.
    pub cache_misses: usize,
}

/// Whether `RMSMP_NO_TUNE` asks for the deterministic fixed defaults
/// (any non-empty value other than `"0"`).
pub fn no_tune_requested() -> bool {
    std::env::var("RMSMP_NO_TUNE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// The tune-cache path from `RMSMP_TUNE_CACHE`, if set (the default the
/// plan builder uses when no explicit `--tune-cache` was given).
pub fn env_cache_path() -> Option<PathBuf> {
    match std::env::var("RMSMP_TUNE_CACHE") {
        Ok(v) if !v.is_empty() => Some(PathBuf::from(v)),
        _ => None,
    }
}

/// Everything that can change a tuning answer: the layer, the machine
/// (ISA tier + thread count), the pins, and the baseline knobs the
/// explicit-wins contract feeds in. One entry in both caches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct CacheKey {
    isa: Isa,
    sig: LayerSig,
    threads: usize,
    pin_tile: bool,
    /// Forced row-block height (ablations); 0 = not pinned.
    pin_micro_rows: usize,
    base_tile: usize,
    base_chunk: usize,
    base_micro_rows: usize,
}

impl CacheKey {
    fn new(
        sig: LayerSig,
        cfg: &ParallelConfig,
        threads: usize,
        pin_tile: bool,
        pin_micro_rows: Option<usize>,
    ) -> CacheKey {
        CacheKey {
            isa: Isa::detect().validated().get(),
            sig,
            threads,
            pin_tile,
            pin_micro_rows: pin_micro_rows.unwrap_or(0),
            base_tile: cfg.tile_cols,
            base_chunk: cfg.min_rows_per_task,
            base_micro_rows: cfg.micro_rows,
        }
    }

    /// The stable text form used as the on-disk key (one line prefix).
    fn text(&self) -> String {
        let c = self.sig.counts;
        format!(
            "{} t{} sig {} {} {} mix {} {} {} {} pin {} {} base {} {} {}",
            self.isa.name(),
            self.threads,
            self.sig.rows,
            self.sig.cols,
            self.sig.batch,
            c[0],
            c[1],
            c[2],
            c[3],
            self.pin_tile as usize,
            self.pin_micro_rows,
            self.base_tile,
            self.base_chunk,
            self.base_micro_rows,
        )
    }
}

static CACHE: OnceLock<Mutex<Vec<(CacheKey, TunedParams)>>> = OnceLock::new();

fn cache() -> &'static Mutex<Vec<(CacheKey, TunedParams)>> {
    CACHE.get_or_init(|| Mutex::new(Vec::new()))
}

/// Drop every process-cached tuning result. Tests use this to force the
/// next [`tune_layer`] through the disk cache (or a fresh microbench);
/// production code never needs it.
pub fn clear_process_cache() {
    if let Ok(mut hits) = cache().lock() {
        hits.clear();
    }
}

/// Read the on-disk cache: `(key text, params)` pairs. Any problem —
/// missing file, foreign or stale version header, torn or corrupt
/// lines — yields fewer (or zero) entries, never an error: a bad cache
/// degrades to the live microbench.
fn read_disk(path: &Path) -> Vec<(String, TunedParams)> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    let mut lines = text.lines();
    if lines.next().map(str::trim) != Some(CACHE_HEADER) {
        return Vec::new();
    }
    let mut entries = Vec::new();
    for line in lines {
        let Some((key, val)) = line.split_once(" => ") else {
            continue;
        };
        let nums: Vec<usize> =
            val.split_whitespace().filter_map(|t| t.parse().ok()).collect();
        let &[mr, tile, chunk, panel] = nums.as_slice() else {
            continue;
        };
        if mr == 0 || mr > MAX_MICRO_ROWS {
            continue;
        }
        entries.push((
            key.trim().to_string(),
            TunedParams {
                micro_rows: mr,
                tile_cols: tile,
                min_rows_per_task: chunk,
                panel_bytes: panel,
                source: TuneSource::DiskCache,
            },
        ));
    }
    entries
}

/// Merge one result into the on-disk cache: read-modify-write through a
/// temp file + atomic rename, so a reader never sees a torn file and
/// the last of two racing writers wins with a complete file. Failures
/// (unwritable path, rename across devices) are swallowed — persisting
/// is an optimization, never a correctness requirement.
fn write_disk(path: &Path, key_text: &str, p: &TunedParams) {
    let mut entries = read_disk(path);
    entries.retain(|(k, _)| k != key_text);
    entries.push((key_text.to_string(), *p));
    let mut text = String::from(CACHE_HEADER);
    text.push('\n');
    for (k, e) in &entries {
        text.push_str(&format!(
            "{} => {} {} {} {}\n",
            k, e.micro_rows, e.tile_cols, e.min_rows_per_task, e.panel_bytes
        ));
    }
    let pid = std::process::id();
    let tmp = path.with_extension(format!("tmp.{pid}"));
    if std::fs::write(&tmp, text).is_ok() && std::fs::rename(&tmp, path).is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
}

/// Tune one layer signature, answering from the process cache, then the
/// on-disk cache (`disk`, when given), then a live microbench — in that
/// order. `cfg` supplies the baseline knobs (and the thread count:
/// chunk granularity is only tuned when the config resolves to >1
/// thread); `pin_tile` keeps `tile_cols` at the configured value
/// (required when the model carries f32-accumulating APoT rows, whose
/// results are only deterministic for a fixed tile); `pin_micro_rows`
/// forces the row-block height to one value without sweeping (the
/// bench ablation twin). `stats` counts the hit/miss provenance per
/// plan compile.
///
/// This runs at plan-compile (load) time, so its allocations do not
/// disturb the zero-steady-state-allocation property of inference.
pub fn tune_layer(
    sig: LayerSig,
    cfg: &ParallelConfig,
    pin_tile: bool,
    pin_micro_rows: Option<usize>,
    disk: Option<&Path>,
    stats: &mut TuneStats,
) -> TunedParams {
    let threads = if cfg.threads == 1 { 1 } else { cfg.resolved_threads() };
    let key = CacheKey::new(sig, cfg, threads, pin_tile, pin_micro_rows);
    if let Ok(hits) = cache().lock() {
        if let Some((_, p)) = hits.iter().find(|(k, _)| *k == key) {
            stats.cache_hits += 1;
            return *p;
        }
    }
    if let Some(path) = disk {
        let key_text = key.text();
        if let Some((_, p)) = read_disk(path).into_iter().find(|(k, _)| *k == key_text) {
            if let Ok(mut hits) = cache().lock() {
                hits.push((key, p));
            }
            stats.cache_hits += 1;
            return p;
        }
    }
    stats.cache_misses += 1;
    let params = tune_uncached(sig, cfg, threads, pin_tile, pin_micro_rows);
    if let Ok(mut hits) = cache().lock() {
        hits.push((key, params));
    }
    if let Some(path) = disk {
        write_disk(path, &key.text(), &params);
    }
    params
}

/// Shape-only tuning with the canonical scheme mix and no disk cache —
/// the benchmark entry point (kept from the one-shape-per-model tuner).
pub fn tune(shape: TuneShape, cfg: &ParallelConfig, pin_tile: bool) -> TunedParams {
    let sig = LayerSig::canonical(shape.rows, shape.cols, shape.batch);
    tune_layer(sig, cfg, pin_tile, None, None, &mut TuneStats::default())
}

/// One synthetic workload: `counts` rows per scheme class (the tuned
/// layer's own mix) in the class-sorted layout, plus 4-bit activations
/// with `batch` rows.
struct Workload {
    acts: PackedActs,
    sorted: SortedWeights,
    rows: usize,
}

impl Workload {
    fn build(rows: usize, cols: usize, batch: usize, counts: [usize; 4]) -> Workload {
        let mut rng = Rng::new(0x7a11e7);
        let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect();
        let x = Mat::from_vec(batch, cols, xd);
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.4));
        let alpha: Vec<f32> =
            (0..rows).map(|r| crate::quant::default_alpha(w.row(r))).collect();
        let mut schemes = Vec::with_capacity(rows);
        for (k, s) in RowPartition::CLASS_ORDER.iter().enumerate() {
            schemes.extend((0..counts[k]).map(|_| *s));
        }
        debug_assert_eq!(schemes.len(), rows, "counts must sum to rows");
        let packed = PackedWeights::quantize(&w, &schemes, &alpha);
        let sorted = SortedWeights::from_packed(&packed);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        Workload { acts, sorted, rows }
    }

    /// Best-of-`iters` wall time of one full dispatch (after one
    /// warmup), in nanoseconds.
    fn time(
        &self,
        gemm: &MixedGemm,
        min_rows: usize,
        parallel: bool,
        scratch: &mut GemmScratch,
        out: &mut Mat,
    ) -> u64 {
        let chunks = chunk_tasks(self.sorted.partition(), min_rows);
        let mut best = u64::MAX;
        for it in 0..4 {
            let t = Instant::now();
            gemm.dispatch(
                GemmCall {
                    acts: GemmActs::Packed(&self.acts),
                    weights: &self.sorted,
                    chunks: &chunks,
                    parallel,
                    fill: true,
                    out: GemmOut::F32(out),
                },
                scratch,
            );
            let ns = t.elapsed().as_nanos() as u64;
            if it > 0 {
                best = best.min(ns);
            }
        }
        best
    }
}

/// Sequential engine with the two block knobs overridden.
fn engine(micro_rows: usize, tile_cols: usize) -> MixedGemm {
    MixedGemm::with_config(ParallelConfig {
        threads: 1,
        tile_cols,
        min_rows_per_task: DEFAULT_MIN_ROWS_PER_TASK,
        micro_rows,
    })
}

fn tune_uncached(
    sig: LayerSig,
    cfg: &ParallelConfig,
    threads: usize,
    pin_tile: bool,
    pin_micro_rows: Option<usize>,
) -> TunedParams {
    let shape = sig.shape();
    let counts = sig.scaled_counts(shape.rows);
    let wl = Workload::build(shape.rows, shape.cols, shape.batch, counts);
    let mut scratch = GemmScratch::new(1);
    let mut out = Mat::zeros(shape.batch, wl.rows);

    // micro_rows: sequential sweep at the baseline tile, incumbent = the
    // configured height; a pin (the bench ablation twin) or an explicit
    // non-default config value skips the sweep entirely
    let mut micro_rows = pin_micro_rows.unwrap_or(cfg.micro_rows);
    if pin_micro_rows.is_none() && cfg.micro_rows == DEFAULT_MICRO_ROWS {
        let mut best = wl.time(
            &engine(micro_rows, cfg.tile_cols),
            cfg.min_rows_per_task,
            false,
            &mut scratch,
            &mut out,
        );
        for cand in MICRO_ROWS_CANDIDATES {
            if cand == cfg.micro_rows {
                continue;
            }
            let ns = wl.time(
                &engine(cand, cfg.tile_cols),
                cfg.min_rows_per_task,
                false,
                &mut scratch,
                &mut out,
            );
            if (ns as f64) < best as f64 * IMPROVEMENT {
                best = ns;
                micro_rows = cand;
            }
        }
    }

    // tile_cols: sequential sweep at the winning block height,
    // incumbent = the configured value
    let mut tile_cols = cfg.tile_cols;
    if !pin_tile {
        let mut best = wl.time(
            &engine(micro_rows, tile_cols),
            cfg.min_rows_per_task,
            false,
            &mut scratch,
            &mut out,
        );
        for cand in TILE_CANDIDATES {
            if cand == cfg.tile_cols {
                continue;
            }
            let ns = wl.time(
                &engine(micro_rows, cand),
                cfg.min_rows_per_task,
                false,
                &mut scratch,
                &mut out,
            );
            if (ns as f64) < best as f64 * IMPROVEMENT {
                best = ns;
                tile_cols = cand;
            }
        }
    }

    // panel budget: the implicit-GEMM path processes `panel_bytes / cols`
    // positions per dispatch; proxy each candidate with a packed GEMM at
    // that batch height and compare per-element cost (cache-resident
    // panels win, spilled ones lose, tiny ones waste amortization).
    let mut panel_bytes = DEFAULT_PANEL_BYTES;
    {
        let tile_engine = engine(micro_rows, tile_cols);
        let positions = |pb: usize| (pb / shape.cols.max(1)).clamp(8, 256);
        let per_elem = |pb: usize, scratch: &mut GemmScratch| {
            let p = positions(pb);
            let pwl = Workload::build(shape.rows, shape.cols, p, counts);
            let mut pout = Mat::zeros(p, pwl.rows);
            let ns = pwl.time(&tile_engine, cfg.min_rows_per_task, false, scratch, &mut pout);
            ns as f64 / (p * shape.rows * shape.cols) as f64
        };
        let mut best = per_elem(DEFAULT_PANEL_BYTES, &mut scratch);
        for cand in PANEL_CANDIDATES {
            if cand == DEFAULT_PANEL_BYTES || positions(cand) == positions(DEFAULT_PANEL_BYTES) {
                continue;
            }
            let c = per_elem(cand, &mut scratch);
            if c < best * IMPROVEMENT {
                best = c;
                panel_bytes = cand;
            }
        }
    }

    // chunk granularity: only meaningful with a pool; sweep real parallel
    // dispatches so scheduling overhead vs balance is actually measured
    let mut min_rows = cfg.min_rows_per_task;
    if threads > 1 {
        let par = MixedGemm::with_config(ParallelConfig {
            threads,
            tile_cols,
            min_rows_per_task: cfg.min_rows_per_task,
            micro_rows,
        });
        let mut pscratch = GemmScratch::new(par.lanes());
        let mut best = wl.time(&par, min_rows, true, &mut pscratch, &mut out);
        for cand in CHUNK_CANDIDATES {
            if cand == cfg.min_rows_per_task {
                continue;
            }
            let ns = wl.time(&par, cand, true, &mut pscratch, &mut out);
            if (ns as f64) < best as f64 * IMPROVEMENT {
                best = ns;
                min_rows = cand;
            }
        }
    }

    TunedParams {
        micro_rows,
        tile_cols,
        min_rows_per_task: min_rows,
        panel_bytes,
        source: TuneSource::Tuned,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_reflect_config_and_are_marked() {
        let cfg = ParallelConfig {
            threads: 1,
            tile_cols: 33,
            min_rows_per_task: 5,
            micro_rows: 6,
        };
        let p = TunedParams::defaults(&cfg);
        assert_eq!(p.tile_cols, 33);
        assert_eq!(p.min_rows_per_task, 5);
        assert_eq!(p.micro_rows, 6);
        assert_eq!(p.panel_bytes, DEFAULT_PANEL_BYTES);
        assert_eq!(p.source, TuneSource::Defaults);
        assert_eq!(p.source.name(), "defaults");
    }

    #[test]
    fn apply_to_lets_explicit_knobs_win() {
        let tuned = TunedParams {
            micro_rows: 8,
            tile_cols: 128,
            min_rows_per_task: 16,
            panel_bytes: 64 * 1024,
            source: TuneSource::Tuned,
        };
        // defaults are replaced by the tuned values
        let base = ParallelConfig { threads: 3, ..ParallelConfig::default() };
        let merged = tuned.apply_to(base);
        assert_eq!(merged.threads, 3);
        assert_eq!(merged.tile_cols, 128);
        assert_eq!(merged.min_rows_per_task, 16);
        assert_eq!(merged.micro_rows, 8);
        // explicit values survive
        let explicit = ParallelConfig {
            threads: 1,
            tile_cols: 48,
            min_rows_per_task: 2,
            micro_rows: 6,
        };
        let kept = tuned.apply_to(explicit);
        assert_eq!(kept.tile_cols, 48);
        assert_eq!(kept.min_rows_per_task, 2);
        assert_eq!(kept.micro_rows, 6);
    }

    #[test]
    fn shape_is_clamped_to_the_microbench_budget() {
        let s = TuneShape::for_layer(4096, 100_000, 9999);
        assert_eq!(s, TuneShape { rows: 64, cols: 1024, batch: 64 });
        let t = TuneShape::for_layer(1, 1, 1);
        assert_eq!(t, TuneShape { rows: 16, cols: 32, batch: 8 });
    }

    #[test]
    fn canonical_sig_counts_sum_and_scale() {
        let sig = LayerSig::canonical(40, 64, 8);
        assert_eq!(sig.counts.iter().sum::<usize>(), 40);
        assert_eq!(sig.counts[3], 0, "canonical mix has no APoT rows");
        // scaling a real mix preserves totals and keeps every class that
        // had rows when the clamp budget allows
        let real = LayerSig { rows: 4096, cols: 4096, batch: 256, counts: [1024, 2048, 512, 512] };
        let scaled = real.scaled_counts(64);
        assert_eq!(scaled.iter().sum::<usize>(), 64);
        assert!(scaled[1] >= scaled[0], "largest class stays largest");
    }

    #[test]
    fn tune_picks_candidates_and_caches() {
        let cfg = ParallelConfig::sequential();
        let shape = TuneShape::for_layer(16, 48, 8);
        let a = tune(shape, &cfg, false);
        assert_eq!(a.source, TuneSource::Tuned);
        assert!(
            TILE_CANDIDATES.contains(&a.tile_cols) || a.tile_cols == cfg.tile_cols,
            "tile {}",
            a.tile_cols
        );
        assert!(
            MICRO_ROWS_CANDIDATES.contains(&a.micro_rows),
            "micro_rows {}",
            a.micro_rows
        );
        assert!(PANEL_CANDIDATES.contains(&a.panel_bytes));
        // sequential config never tunes the chunk granularity
        assert_eq!(a.min_rows_per_task, cfg.min_rows_per_task);
        // second call is a cache hit with an identical answer
        let b = tune(shape, &cfg, false);
        assert_eq!(a, b);
    }

    #[test]
    fn pinned_tile_is_never_changed() {
        let cfg = ParallelConfig::sequential();
        let shape = TuneShape::for_layer(16, 40, 8);
        let p = tune(shape, &cfg, true);
        assert_eq!(p.tile_cols, cfg.tile_cols);
        assert_eq!(p.source, TuneSource::Tuned);
    }

    #[test]
    fn pinned_micro_rows_skips_the_sweep() {
        let cfg = ParallelConfig::sequential();
        let sig = LayerSig::canonical(16, 40, 8);
        let mut stats = TuneStats::default();
        let p = tune_layer(sig, &cfg, false, Some(4), None, &mut stats);
        assert_eq!(p.micro_rows, 4);
        assert_eq!(stats, TuneStats { cache_hits: 0, cache_misses: 1 });
        // explicit non-default config heights are honored the same way
        let explicit = ParallelConfig { micro_rows: 6, ..ParallelConfig::sequential() };
        let q = tune_layer(sig, &explicit, false, None, None, &mut stats);
        assert_eq!(q.micro_rows, 6);
    }
}
