//! 4-bit nibble packing — the memory format the hardware actually stores.
//!
//! The paper's model-size and bandwidth numbers assume 4-bit rows occupy
//! 4 bits in DRAM/BRAM (two codes per byte) and 8-bit rows one byte. This
//! module implements that packing for both row classes:
//!
//! * Fixed-4 / APoT-4 rows: the signed code in `[-7, 7]` is stored as a
//!   sign-magnitude nibble (sign bit + 3 magnitude bits).
//! * PoT-4 rows: the [`super::packed::pot_pack`] code in `[-7, 7]` uses
//!   the same nibble encoding (sign + shift-index).
//! * Fixed-8 rows: raw `i8` bytes.
//!
//! Round-trip exactness is the contract (`unpack(pack(x)) == x`), and the
//! packed stream length matches `PackedWeights::storage_bits`.

use super::packed::PackedWeights;
use crate::quant::Scheme;

/// Encode an i8 code in [-7, 7] as a sign-magnitude nibble (0..=15).
#[inline]
pub fn to_nibble(code: i8) -> u8 {
    debug_assert!((-7..=7).contains(&code), "nibble range: {code}");
    if code < 0 {
        0x8 | (-code) as u8
    } else {
        code as u8
    }
}

/// Decode a sign-magnitude nibble back to i8.
#[inline]
pub fn from_nibble(n: u8) -> i8 {
    let mag = (n & 0x7) as i8;
    if n & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

/// A layer's weights in the deployment bit format.
#[derive(Clone, Debug)]
pub struct NibblePacked {
    pub rows: usize,
    pub cols: usize,
    pub scheme: Vec<Scheme>,
    /// Per-row byte streams: 4-bit rows hold ceil(cols/2) bytes (low
    /// nibble first), 8-bit rows hold cols bytes.
    pub rows_data: Vec<Vec<u8>>,
}

impl NibblePacked {
    /// Pack from the integer-code form.
    pub fn pack(w: &PackedWeights) -> NibblePacked {
        let rows_data = (0..w.rows)
            .map(|r| {
                let codes = w.row(r);
                match w.scheme[r] {
                    Scheme::FixedW8A4 => codes.iter().map(|&c| c as u8).collect(),
                    _ => {
                        let mut out = Vec::with_capacity(w.cols.div_ceil(2));
                        for pair in codes.chunks(2) {
                            let lo = to_nibble(pair[0]);
                            let hi = pair.get(1).map(|&c| to_nibble(c)).unwrap_or(0);
                            out.push(lo | (hi << 4));
                        }
                        out
                    }
                }
            })
            .collect();
        NibblePacked { rows: w.rows, cols: w.cols, scheme: w.scheme.clone(), rows_data }
    }

    /// Unpack row `r` back to i8 codes.
    pub fn unpack_row(&self, r: usize) -> Vec<i8> {
        let data = &self.rows_data[r];
        match self.scheme[r] {
            Scheme::FixedW8A4 => data.iter().map(|&b| b as i8).collect(),
            _ => {
                let mut out = Vec::with_capacity(self.cols);
                for &b in data {
                    out.push(from_nibble(b & 0xF));
                    if out.len() < self.cols {
                        out.push(from_nibble(b >> 4));
                    }
                }
                out
            }
        }
    }

    /// Total packed bytes (the DRAM footprint).
    pub fn bytes(&self) -> usize {
        self.rows_data.iter().map(|r| r.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_alpha, Mat};
    use crate::util::rng::Rng;

    #[test]
    fn nibble_roundtrip_all_codes() {
        for c in -7i8..=7 {
            assert_eq!(from_nibble(to_nibble(c)), c, "code {c}");
        }
    }

    fn packed(rows: usize, cols: usize, seed: u64) -> PackedWeights {
        let mut rng = Rng::new(seed);
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
        let schemes: Vec<Scheme> = (0..rows)
            .map(|r| match r % 3 {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                _ => Scheme::FixedW8A4,
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
        PackedWeights::quantize(&w, &schemes, &alpha)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for cols in [1usize, 2, 7, 16, 33] {
            let pw = packed(6, cols, cols as u64);
            let np = NibblePacked::pack(&pw);
            for r in 0..pw.rows {
                assert_eq!(np.unpack_row(r), pw.row(r).to_vec(), "row {r} cols {cols}");
            }
        }
    }

    #[test]
    fn footprint_matches_storage_bits() {
        let pw = packed(9, 16, 3); // even cols: bits exact
        let np = NibblePacked::pack(&pw);
        assert_eq!(np.bytes() * 8, pw.storage_bits());
    }

    #[test]
    fn odd_cols_pad_half_byte() {
        let pw = packed(3, 7, 4);
        let np = NibblePacked::pack(&pw);
        // 4-bit rows: ceil(7/2)=4 bytes; 8-bit row: 7 bytes
        assert_eq!(np.rows_data[0].len(), 4);
        assert_eq!(np.rows_data[2].len(), 7);
    }
}
