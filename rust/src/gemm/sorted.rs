//! Class-sorted kernel layout for one layer's weights.
//!
//! [`super::PackedWeights`] keeps rows in model order, which scatters the
//! rows of each scheme class across memory; dispatching through per-row
//! index lists made every micro-kernel block gather from disjoint cache
//! lines. [`SortedWeights`] is the layout the GEMM actually runs on: the
//! rows are **permuted once at load time** so each class occupies one
//! contiguous block (PoT-4, Fixed-4, Fixed-8, APoT-4 — the scheme-code
//! order), matching how the FPGA streams each class's filters into its
//! PE array back-to-back (paper §4.1).
//!
//! The stored codes are the **kernel operands**, not the storage codes:
//! PoT rows are pre-decoded to their `±2^(6-shift)` i8 multipliers so the
//! inner loop is the same u8 x i8 MAC for all three RMSMP classes. The
//! permutation (`perm`: sorted → original) and its inverse (`inv`:
//! original → sorted) are kept so outputs scatter back to model row
//! order; because `perm` is a bijection, every output cell is still
//! written by exactly one task in the parallel dispatch.

use super::mixed::RowPartition;
use super::packed::PackedWeights;
use crate::ensure;
use crate::quant::Scheme;
use crate::util::error::Result;
use crate::util::mmap::Plane;

/// One layer's weights in class-sorted kernel form (see module docs).
#[derive(Clone, Debug)]
pub struct SortedWeights {
    pub rows: usize,
    pub cols: usize,
    /// Kernel operand codes, row-major in **sorted** row order: Fixed
    /// rows hold signed level codes, PoT rows the decoded `±2^(6-shift)`
    /// multipliers, APoT rows signed level indices. A [`Plane`]: owned
    /// when built by [`SortedWeights::from_packed`], an aliased artifact
    /// section on the mapped load path.
    ops: Plane,
    /// `perm[sorted_row] = original_row` — the output scatter map.
    pub perm: Vec<usize>,
    /// `inv[original_row] = sorted_row`.
    pub inv: Vec<usize>,
    /// Per-row clip scale, sorted order (`alpha[r] == packed.alpha[perm[r]]`).
    pub alpha: Vec<f32>,
    /// Contiguous class ranges over the sorted row space.
    part: RowPartition,
}

impl SortedWeights {
    /// Build the sorted layout from packed weights. Rows keep their
    /// original relative order within each class (a stable sort), so the
    /// permutation is deterministic.
    pub fn from_packed(pw: &PackedWeights) -> SortedWeights {
        let (rows, cols) = (pw.rows, pw.cols);
        let part = RowPartition::from_schemes(&pw.scheme);
        let mut perm = Vec::with_capacity(rows);
        for class in RowPartition::CLASS_ORDER {
            for (i, s) in pw.scheme.iter().enumerate() {
                if *s == class {
                    perm.push(i);
                }
            }
        }
        debug_assert_eq!(perm.len(), rows);
        let mut inv = vec![0usize; rows];
        let mut ops = vec![0i8; rows * cols];
        let mut alpha = Vec::with_capacity(rows);
        for (sr, &orig) in perm.iter().enumerate() {
            inv[orig] = sr;
            let src = match pw.scheme[orig] {
                Scheme::PotW4A4 => pw.pot_mult_row(orig),
                _ => pw.row(orig),
            };
            ops[sr * cols..(sr + 1) * cols].copy_from_slice(src);
            alpha.push(pw.alpha[orig]);
        }
        SortedWeights { rows, cols, ops: Plane::owned(ops), perm, inv, alpha, part }
    }

    /// Assemble from precomputed parts — the artifact load path, where
    /// `ops` aliases a mapped file range and `perm` was validated against
    /// the stable class sort by the loader. Checks lengths and that
    /// `perm`/`inv` are mutually inverse bijections (so the output
    /// scatter stays in bounds and collision-free), and rebuilds the
    /// partition from the class counts.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        ops: Plane,
        perm: Vec<usize>,
        alpha: Vec<f32>,
        counts: [usize; 4],
    ) -> Result<SortedWeights> {
        let elems = rows
            .checked_mul(cols)
            .ok_or_else(|| crate::err!("weight shape {rows}x{cols} overflows"))?;
        ensure!(ops.len() == elems, "ops section holds {} of {elems} elements", ops.len());
        ensure!(perm.len() == rows, "perm holds {} of {rows} rows", perm.len());
        ensure!(alpha.len() == rows, "alpha holds {} of {rows} rows", alpha.len());
        let part = RowPartition::from_counts(counts);
        ensure!(part.total() == rows, "class counts cover {} of {rows} rows", part.total());
        let mut inv = vec![usize::MAX; rows];
        for (sr, &orig) in perm.iter().enumerate() {
            ensure!(orig < rows, "perm[{sr}] = {orig} out of {rows} rows");
            ensure!(inv[orig] == usize::MAX, "perm maps row {orig} twice");
            inv[orig] = sr;
        }
        Ok(SortedWeights { rows, cols, ops, perm, inv, alpha, part })
    }

    /// Operand row `sr` (sorted index).
    #[inline]
    pub fn op_row(&self, sr: usize) -> &[i8] {
        &self.ops[sr * self.cols..(sr + 1) * self.cols]
    }

    /// `nr` contiguous operand rows starting at sorted row `r0` — the
    /// micro-kernel block slab (row `j` of the slab starts at
    /// `j * self.cols`).
    #[inline]
    pub fn op_rows(&self, r0: usize, nr: usize) -> &[i8] {
        &self.ops[r0 * self.cols..(r0 + nr) * self.cols]
    }

    /// Scheme class of sorted row `sr`.
    #[inline]
    pub fn scheme_of(&self, sr: usize) -> Scheme {
        self.part.scheme_of(sr)
    }

    /// The class partition (contiguous ranges in sorted row space).
    #[inline]
    pub fn partition(&self) -> &RowPartition {
        &self.part
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{default_alpha, Mat};
    use crate::util::rng::Rng;

    fn mixed_packed(rows: usize, cols: usize, seed: u64) -> PackedWeights {
        let mut rng = Rng::new(seed);
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.5));
        let schemes: Vec<Scheme> = (0..rows)
            .map(|_| match rng.below(4) {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                2 => Scheme::FixedW8A4,
                _ => Scheme::ApotW4A4,
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
        PackedWeights::quantize(&w, &schemes, &alpha)
    }

    #[test]
    fn perm_is_a_bijection_with_inverse() {
        let pw = mixed_packed(37, 5, 3);
        let sw = SortedWeights::from_packed(&pw);
        assert_eq!(sw.perm.len(), 37);
        for orig in 0..37 {
            assert_eq!(sw.perm[sw.inv[orig]], orig);
        }
        let mut seen = sw.perm.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..37).collect::<Vec<_>>());
    }

    #[test]
    fn classes_are_contiguous_and_rows_match_source() {
        let pw = mixed_packed(41, 7, 9);
        let sw = SortedWeights::from_packed(&pw);
        for sr in 0..sw.rows {
            let orig = sw.perm[sr];
            // the sorted class equals the source scheme
            assert_eq!(sw.scheme_of(sr), pw.scheme[orig]);
            // the operand row is the kernel operand of the source row
            let want: &[i8] = match pw.scheme[orig] {
                Scheme::PotW4A4 => pw.pot_mult_row(orig),
                _ => pw.row(orig),
            };
            assert_eq!(sw.op_row(sr), want, "sorted row {sr}");
            assert_eq!(sw.alpha[sr], pw.alpha[orig]);
        }
        // ranges tile 0..rows in class order
        let part = sw.partition();
        let mut next = 0usize;
        for class in RowPartition::CLASS_ORDER {
            let r = part.range(class);
            assert_eq!(r.start, next);
            next = r.end;
        }
        assert_eq!(next, sw.rows);
    }

    #[test]
    fn stable_within_class() {
        let pw = mixed_packed(23, 3, 21);
        let sw = SortedWeights::from_packed(&pw);
        for class in RowPartition::CLASS_ORDER {
            let r = sw.partition().range(class);
            let origs: Vec<usize> = sw.perm[r].to_vec();
            let mut sorted = origs.clone();
            sorted.sort_unstable();
            assert_eq!(origs, sorted, "{class} rows not in stable order");
        }
    }
}
