//! The row-partitioned mixed GEMM (the paper's core §3 computation).
//!
//! Rows of a layer's weight matrix are grouped by scheme class into a
//! [`RowPartition`]; [`MixedGemm`] dispatches each class to its core —
//! exactly how the FPGA feeds filter classes to the GEMM_PoT-4 /
//! GEMM_Fixed-4 / GEMM_Fixed-8 PE arrays. Because the ratio is layer-wise
//! uniform, the partition shape (and thus per-layer schedule) is identical
//! in every layer.
//!
//! # Class-sorted execution
//!
//! The engine runs on the [`SortedWeights`] layout: rows are permuted at
//! load time so every class is one contiguous block, and a partition is
//! just four ranges over that sorted row space. Dispatch walks
//! [`TaskChunk`] ranges (no per-row index lists), hands each chunk to its
//! core's [`GemmCore::run_block_tiled`] micro-kernel in
//! [`ParallelConfig::micro_rows`]-row blocks (a tuned height, 4 by
//! default, at most [`MAX_MICRO_ROWS`]), and scatters the block outputs
//! back to model row order through the stored permutation.
//!
//! # Parallel execution
//!
//! Row classes are embarrassingly parallel: every output cell `(b, r)` is
//! produced by exactly one weight row `r`. [`chunk_tasks`] therefore
//! splits each class's sorted range into chunks of `min_rows_per_task`
//! rows and interleaves the chunks round-robin across classes (so cheap
//! PoT shift-add rows and expensive Fixed-8 MAC rows load-balance instead
//! of convoying per class); dispatch drains the task list on the shared
//! [`ThreadPool`] via its work-pulling `scoped_for`. Each task writes a
//! disjoint set of output cells (the row permutation is a bijection), and
//! per-row arithmetic is identical to the sequential path, so parallel
//! output is bit-exact regardless of thread count or scheduling order.
//!
//! # One entry point
//!
//! All of the above is reached through [`MixedGemm::dispatch`], which
//! takes a [`GemmCall`] describing the full GEMM: where activations come
//! from ([`GemmActs`] — a materialized [`PackedActs`] matrix, or
//! implicit column tiles packed on the fly by a [`ColTileSource`] into
//! per-lane cache-resident panels), and where output goes ([`GemmOut`] —
//! an f32 matrix, or activation codes through the fused
//! [`QuantEpilogue`]: dequant → bias → add → requantize → layout
//! scatter). On the implicit path, parallelism moves to the tile axis —
//! each tile owns a disjoint set of output positions, so tasks still
//! write disjoint cells — and outputs stay bit-exact for any panel
//! width.

use std::ops::Range;
use std::sync::Arc;

use super::cores::{
    requant_block, GemmApot4, GemmCore, GemmFixed4, GemmFixed8, GemmPoT4, Requant,
};
use super::packed::{ActsView, PackedActs, PackedWeights};
use super::panels::ColTileSource;
use super::simd::{Isa, KernelIsa, MAX_MICRO_ROWS, MICRO_ROWS};
use super::sorted::SortedWeights;
use crate::quant::{Mat, Scheme};
use crate::util::pool::ThreadPool;

/// Contiguous class ranges over the class-sorted row space: sorted rows
/// `bounds[k]..bounds[k + 1]` belong to class `k` of
/// [`RowPartition::CLASS_ORDER`]. (Until the class-sorted layout landed
/// this held four per-class `Vec<usize>` index lists; ranges carry the
/// same information once rows are contiguous, at zero per-row storage.)
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RowPartition {
    bounds: [usize; 5],
}

impl RowPartition {
    /// Scheme classes in sorted-layout order (== the numeric scheme
    /// codes shared with the Python side).
    pub const CLASS_ORDER: [Scheme; 4] = [
        Scheme::PotW4A4,
        Scheme::FixedW4A4,
        Scheme::FixedW8A4,
        Scheme::ApotW4A4,
    ];

    pub fn from_schemes(schemes: &[Scheme]) -> RowPartition {
        let mut counts = [0usize; 4];
        for s in schemes {
            counts[*s as usize] += 1;
        }
        RowPartition::from_counts(counts)
    }

    /// Partition from per-class row counts (in [`RowPartition::CLASS_ORDER`]
    /// order).
    pub fn from_counts(counts: [usize; 4]) -> RowPartition {
        let mut bounds = [0usize; 5];
        for k in 0..4 {
            bounds[k + 1] = bounds[k] + counts[k];
        }
        RowPartition { bounds }
    }

    pub fn total(&self) -> usize {
        self.bounds[4]
    }

    /// The sorted-row range of one scheme class.
    #[inline]
    pub fn range(&self, s: Scheme) -> Range<usize> {
        self.bounds[s as usize]..self.bounds[s as usize + 1]
    }

    /// Rows in one scheme class.
    #[inline]
    pub fn len_of(&self, s: Scheme) -> usize {
        self.range(s).len()
    }

    /// Scheme class owning sorted row `sr`.
    #[inline]
    pub fn scheme_of(&self, sr: usize) -> Scheme {
        for s in RowPartition::CLASS_ORDER {
            if sr < self.bounds[s as usize + 1] {
                return s;
            }
        }
        panic!("sorted row {sr} outside partition of {} rows", self.total());
    }

    /// Per-class fractions `[pot4, fixed4, fixed8, apot4]` — checked
    /// against the configured ratio by the coordinator's admission tests.
    /// All four classes are reported so the fractions sum to 1 whenever
    /// the partition is non-empty (the earlier 3-tuple silently dropped
    /// the APoT share).
    pub fn fractions(&self) -> [f64; 4] {
        let t = self.total().max(1) as f64;
        [
            self.len_of(Scheme::PotW4A4) as f64 / t,
            self.len_of(Scheme::FixedW4A4) as f64 / t,
            self.len_of(Scheme::FixedW8A4) as f64 / t,
            self.len_of(Scheme::ApotW4A4) as f64 / t,
        ]
    }
}

/// Execution knobs for the parallel mixed GEMM, threaded from the CLI
/// through the runtime, the layer executor, and the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Column-tile width for the packed inner loops (0 = untiled). 256
    /// i8 codes keep a `micro_rows`-row weight tile comfortably inside
    /// L1 next to the activation tile.
    pub tile_cols: usize,
    /// Minimum rows per parallel task: the chunk granularity of the
    /// per-class queues (smaller = better balance, more overhead).
    pub min_rows_per_task: usize,
    /// Micro-kernel row-block height: how many sorted rows each
    /// [`GemmCore::run_block_tiled`] block sweeps per activation pass.
    /// Must be in `1..=`[`MAX_MICRO_ROWS`]; the SIMD tiers carry fused
    /// kernels for the [`super::simd::MICRO_ROWS_CANDIDATES`] heights
    /// (other values compose 4-row + single-row kernels). Any height
    /// produces bit-identical output — i32 accumulation per cell is
    /// independent of how rows are grouped into blocks.
    pub micro_rows: usize,
}

/// The untuned `tile_cols` default. The plan-compile autotuner treats a
/// config still holding this value as "not explicitly chosen" and may
/// replace it with the machine-tuned winner; any other value is an
/// explicit caller decision and wins over tuning.
pub const DEFAULT_TILE_COLS: usize = 256;
/// The untuned `min_rows_per_task` default (same explicit-wins contract
/// as [`DEFAULT_TILE_COLS`]).
pub const DEFAULT_MIN_ROWS_PER_TASK: usize = 8;
/// The untuned `micro_rows` default (same explicit-wins contract as
/// [`DEFAULT_TILE_COLS`]): the classic 4-row block every ISA tier
/// carries a fused kernel for.
pub const DEFAULT_MICRO_ROWS: usize = MICRO_ROWS;

impl Default for ParallelConfig {
    fn default() -> ParallelConfig {
        ParallelConfig {
            threads: 0,
            tile_cols: DEFAULT_TILE_COLS,
            min_rows_per_task: DEFAULT_MIN_ROWS_PER_TASK,
            micro_rows: DEFAULT_MICRO_ROWS,
        }
    }
}

impl ParallelConfig {
    /// Single-threaded config (the seed's behaviour).
    pub fn sequential() -> ParallelConfig {
        ParallelConfig { threads: 1, ..ParallelConfig::default() }
    }

    /// `threads` with 0 resolved to the machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// GEMM scratch lanes an engine built from this config will use:
    /// the calling thread plus every pool worker when a pool is spawned
    /// (>1 resolved thread), else just the caller. Must agree with
    /// [`MixedGemm::lanes`] for a pool of `resolved_threads()` workers —
    /// `rmsmp plan` sizes footprints with this without building an
    /// engine.
    pub fn lanes(&self) -> usize {
        let threads = self.resolved_threads();
        if threads > 1 {
            threads + 1
        } else {
            1
        }
    }
}

/// One schedulable unit of the mixed GEMM: sorted rows `start..end`, all
/// of one scheme class (a sub-range of that class's contiguous range in
/// the [`SortedWeights`] layout). Chunk lists are compiled once (per
/// layer, by the plan compiler, or per call by the compatibility
/// wrappers) and replayed on every dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskChunk {
    pub scheme: Scheme,
    /// First sorted row of the chunk (absolute index).
    pub start: usize,
    /// One past the last sorted row.
    pub end: usize,
}

/// Build the task list for a partition: per-class row chunks of at most
/// `chunk_rows` rows, interleaved round-robin across the four class
/// ranges (so cheap PoT shift-add chunks and expensive Fixed-8 MAC chunks
/// alternate in the task list instead of convoying per class).
pub fn chunk_tasks(part: &RowPartition, chunk_rows: usize) -> Vec<TaskChunk> {
    let chunk = chunk_rows.max(1);
    let mut tasks = Vec::new();
    let mut offset: [usize; 4] = [0; 4];
    for (k, s) in RowPartition::CLASS_ORDER.iter().enumerate() {
        offset[k] = part.range(*s).start;
    }
    loop {
        let mut pushed = false;
        for (k, &scheme) in RowPartition::CLASS_ORDER.iter().enumerate() {
            let class_end = part.range(scheme).end;
            let o = offset[k];
            if o < class_end {
                let end = class_end.min(o + chunk);
                tasks.push(TaskChunk { scheme, start: o, end });
                offset[k] = end;
                pushed = true;
            }
        }
        if !pushed {
            return tasks;
        }
    }
}

/// One lane of GEMM dispatch scratch: the f32 output block of one
/// [`MAX_MICRO_ROWS`]-row micro-kernel block across the batch (row-major
/// `[j * batch + b]`), the i32 accumulator block the cores MAC into,
/// the u8 code block the fused requantization epilogue writes before
/// the scatter (integer-resident dispatch only), and the u8 activation
/// panel the implicit-GEMM path packs column tiles into (implicit
/// dispatch only — the explicit path reads a prebuilt [`PackedActs`]).
struct Lane {
    col: Vec<f32>,
    acc: Vec<i32>,
    codes: Vec<u8>,
    panel: Vec<u8>,
}

impl Lane {
    fn with_capacity(elems: usize, panel_elems: usize) -> Lane {
        Lane {
            col: Vec::with_capacity(elems),
            acc: Vec::with_capacity(elems),
            codes: Vec::with_capacity(elems),
            panel: Vec::with_capacity(panel_elems),
        }
    }
}

/// Per-lane reusable block scratch for the GEMM dispatch (see [`Lane`]).
/// One lane per drain loop of the pool's `scoped_for_indexed` (lane 0 =
/// caller, 1..=threads = helpers); preallocating them in the inference
/// [`crate::model::Workspace`] is what makes steady-state dispatch
/// allocation-free.
pub struct GemmScratch {
    lanes: Vec<Lane>,
}

impl GemmScratch {
    /// `lanes` empty lanes (grown per dispatch as batches demand).
    pub fn new(lanes: usize) -> GemmScratch {
        GemmScratch::with_capacity(lanes, 0, 0)
    }

    /// `lanes` lanes preallocated for `elems` scratch elements each
    /// (i.e. [`MAX_MICRO_ROWS`] x the largest batch or panel tile) plus
    /// `panel_elems` u8 codes of implicit-GEMM panel space.
    pub fn with_capacity(lanes: usize, elems: usize, panel_elems: usize) -> GemmScratch {
        GemmScratch {
            lanes: (0..lanes.max(1))
                .map(|_| Lane::with_capacity(elems, panel_elems))
                .collect(),
        }
    }

    /// Resize the first `lanes` lanes to one micro-kernel block
    /// (`MAX_MICRO_ROWS * batch` elements — the widest block any tuned
    /// `micro_rows` can sweep, so retuning a layer never regrows a
    /// lane), creating them if missing; allocation-free when within the
    /// preallocated capacities. The panel buffer is left alone — the
    /// packer resizes it per tile, inside its reserved capacity. Lanes
    /// beyond `lanes` are left untouched — the sequential path only
    /// pays for lane 0 even when the engine owns a wide pool.
    fn ensure(&mut self, lanes: usize, batch: usize) {
        let lanes = lanes.max(1);
        let elems = MAX_MICRO_ROWS * batch;
        while self.lanes.len() < lanes {
            self.lanes.push(Lane::with_capacity(elems, 0));
        }
        for lane in self.lanes[..lanes].iter_mut() {
            lane.col.resize(elems, 0.0);
            lane.acc.resize(elems, 0);
            lane.codes.resize(elems, 0);
        }
    }

    /// Lane 0 sliced to a single row of `batch` elements (the grouped-conv
    /// row path).
    pub fn lane0(&mut self, batch: usize) -> (&mut [f32], &mut [i32]) {
        self.ensure(1, batch);
        let lane = &mut self.lanes[0];
        (&mut lane.col[..batch], &mut lane.acc[..batch])
    }

    /// Lane 0 as a full `MAX_MICRO_ROWS * batch` block (the sequential
    /// block dispatch).
    fn lane0_block(&mut self, batch: usize) -> &mut Lane {
        self.ensure(1, batch);
        &mut self.lanes[0]
    }

    /// Data pointers of every lane buffer (steady-state reuse tests pin
    /// these across calls).
    pub fn buffer_ptrs(&self) -> Vec<usize> {
        self.lanes
            .iter()
            .flat_map(|l| {
                [
                    l.col.as_ptr() as usize,
                    l.acc.as_ptr() as usize,
                    l.codes.as_ptr() as usize,
                    l.panel.as_ptr() as usize,
                ]
            })
            .collect()
    }

    /// Bytes currently reserved across all lanes.
    pub fn allocated_bytes(&self) -> usize {
        self.lanes
            .iter()
            .map(|l| {
                4 * l.col.capacity()
                    + 4 * l.acc.capacity()
                    + l.codes.capacity()
                    + l.panel.capacity()
            })
            .sum()
    }
}

/// How integer-resident GEMM output codes land in the destination
/// buffer. `Nchw` fuses the col2im fold into the epilogue scatter: the
/// conv path writes each output channel's codes straight into the NCHW
/// code slot, so the f32 staging matrix *and* the separate col2im pass
/// both disappear from the integer path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutLayout {
    /// Row-major (batch, cols) matrix: cell `(b, r)` at `b * cols + r`
    /// (the linear-layer path).
    RowMajor { cols: usize },
    /// NCHW feature map with `hw` spatial positions per image: GEMM
    /// batch index `b = img * hw + pos` and row `r` (the output channel)
    /// land at `((img * channels) + r) * hw + pos`.
    Nchw { channels: usize, hw: usize },
}

impl OutLayout {
    /// Total output elements for a GEMM of (`batch`, `rows`). Hard
    /// asserts (not debug): these invariants gate the unchecked
    /// raw-pointer scatter of the quant dispatch, and this runs once
    /// per dispatch, not per cell.
    fn len(self, batch: usize, rows: usize) -> usize {
        match self {
            OutLayout::RowMajor { cols } => {
                assert_eq!(cols, rows, "layout cols != weight rows");
                batch * cols
            }
            OutLayout::Nchw { channels, hw } => {
                assert_eq!(channels, rows, "layout channels != weight rows");
                assert!(hw > 0 && batch % hw == 0, "batch not a multiple of hw");
                (batch / hw) * channels * hw
            }
        }
    }

    /// Destination offset of GEMM cell (batch row `b`, weight row `r`)
    /// — the one copy of the layout's index math, shared by the
    /// epilogue scatter and the partial-schedule pre-fill (for `Nchw`,
    /// cells of one row are contiguous per image: `index(img * hw, r)`
    /// is the base of an `hw`-length run).
    #[inline]
    fn index(self, b: usize, r: usize) -> usize {
        match self {
            OutLayout::RowMajor { cols } => b * cols + r,
            OutLayout::Nchw { channels, hw } => ((b / hw) * channels + r) * hw + b % hw,
        }
    }
}

/// Raw output pointer shared across GEMM tasks. Each task writes a
/// disjoint set of output cells — sorted rows are partitioned across
/// tasks and the row permutation is a bijection (in both the row-major
/// and the NCHW layout, a row owns its cells exclusively) — so
/// unsynchronized writes are sound; the pool's join barrier publishes
/// them to the caller.
struct SyncOutPtr<T> {
    p: *mut T,
}

unsafe impl<T> Send for SyncOutPtr<T> {}
unsafe impl<T> Sync for SyncOutPtr<T> {}

/// Raw pointer to the scratch lanes, shared across GEMM tasks. Lane `i`
/// is only ever touched by the drain loop that `scoped_for_indexed`
/// reports as lane `i`, and those run on distinct threads, so access is
/// exclusive per lane.
struct SyncLanesPtr {
    p: *mut Lane,
}

unsafe impl Send for SyncLanesPtr {}
unsafe impl Sync for SyncLanesPtr {}

/// Where a [`GemmCall`]'s activation operand comes from.
pub enum GemmActs<'a> {
    /// A materialized, quantized activation matrix (the staged explicit
    /// path: explicit-im2col convs and the linear layers).
    Packed(&'a PackedActs),
    /// Implicit column-tile streaming: the batch dimension is walked in
    /// `positions`-wide tiles, each packed on the fly into a per-lane
    /// cache-resident panel (the implicit-GEMM conv path and the
    /// depthwise per-group kernel).
    Tiles {
        src: &'a ColTileSource<'a>,
        /// Compiled panel width (output positions per column tile).
        positions: usize,
    },
}

/// The fused integer-resident epilogue of a [`GemmCall`]:
/// dequant → bias → (add) → requantize → layout scatter, mapping every
/// accumulator straight to the *consumer layer's* activation code.
pub struct QuantEpilogue<'a> {
    /// Per-row bias, model row order (gathered through the sorted
    /// layout's permutation).
    pub bias: &'a [f32],
    /// The consumer's requantizer (its clamp at 0 subsumes ReLU).
    pub rq: Requant,
    /// Where codes land (see [`OutLayout`]).
    pub layout: OutLayout,
    /// Fused elementwise addend (the epilogue-fusion rewrite): an f32
    /// buffer indexed exactly like the output — `layout.index` — whose
    /// cell is added after the bias, before requantization. Must have
    /// the output's length. f32 addition is commutative bit-for-bit, so
    /// `(acc + bias) + addend` equals the unfused `addend + (acc + bias)`.
    pub addend: Option<&'a [f32]>,
}

/// Where a [`GemmCall`]'s output goes: a plain f32 matrix (model row
/// order, `(batch, rows)`) or activation codes through the fused
/// [`QuantEpilogue`].
pub enum GemmOut<'a> {
    F32(&'a mut Mat),
    Quant {
        out: &'a mut [u8],
        epi: QuantEpilogue<'a>,
    },
}

/// One mixed-GEMM dispatch, fully described: the single public entry
/// point ([`MixedGemm::dispatch`]) replacing the old
/// `run_partitioned*_into` / `run_implicit*_into` family. The four
/// (acts × out) combinations select the explicit/implicit × f32/quant
/// kernels; every combination is bit-exact against every other way of
/// computing the same GEMM (see the dispatch docs).
pub struct GemmCall<'a> {
    pub acts: GemmActs<'a>,
    /// Class-sorted weight layout (built once at load).
    pub weights: &'a SortedWeights,
    /// Precompiled task schedule (see [`chunk_tasks`]); chunks must
    /// cover disjoint sorted-row ranges.
    pub chunks: &'a [TaskChunk],
    /// Allow pool dispatch (the caller's row-parallel policy).
    pub parallel: bool,
    /// Handling of rows **absent** from `chunks`: `true` gives them the
    /// standalone-call semantics (f32 cells zeroed; quant cells hold the
    /// code of bias [+ addend] alone, the value a zeroed accumulator
    /// produces) — `false` leaves them untouched, for callers that
    /// schedule complementary calls covering every row exactly once (the
    /// depthwise per-group dispatch).
    pub fill: bool,
    pub out: GemmOut<'a>,
}

/// The mixed GEMM engine: owns the four cores plus the execution config,
/// the resolved SIMD ISA, and (optionally) a thread pool.
pub struct MixedGemm {
    fixed4: GemmFixed4,
    fixed8: GemmFixed8,
    pot4: GemmPoT4,
    apot4: GemmApot4,
    cfg: ParallelConfig,
    isa: KernelIsa,
    pool: Option<Arc<ThreadPool>>,
}

impl Default for MixedGemm {
    fn default() -> Self {
        MixedGemm::with_config(ParallelConfig::sequential())
    }
}

impl MixedGemm {
    /// Sequential engine (no pool) — the drop-in default.
    pub fn new() -> MixedGemm {
        MixedGemm::default()
    }

    /// Engine with its own pool when `cfg` resolves to >1 thread.
    pub fn with_config(cfg: ParallelConfig) -> MixedGemm {
        let threads = cfg.resolved_threads();
        let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        MixedGemm::build(cfg, pool)
    }

    /// Engine sharing an existing pool (one pool per server, shared by
    /// every worker's executor).
    pub fn with_shared_pool(cfg: ParallelConfig, pool: Arc<ThreadPool>) -> MixedGemm {
        MixedGemm::build(cfg, Some(pool))
    }

    fn build(cfg: ParallelConfig, pool: Option<Arc<ThreadPool>>) -> MixedGemm {
        MixedGemm {
            fixed4: GemmFixed4,
            fixed8: GemmFixed8,
            pot4: GemmPoT4,
            apot4: GemmApot4::default(),
            cfg,
            isa: Isa::detect().validated(),
            pool,
        }
    }

    pub fn config(&self) -> ParallelConfig {
        self.cfg
    }

    /// The SIMD ISA the integer micro-kernels run on.
    pub fn isa(&self) -> Isa {
        self.isa.get()
    }

    /// Force a kernel ISA (benchmarks and differential tests). This —
    /// together with engine construction in [`MixedGemm::with_config`] /
    /// [`MixedGemm::with_shared_pool`] — is the single point where the
    /// SIMD safety invariant is resolved: [`Isa::validated`] clamps the
    /// request to what the hardware supports (never UB), producing the
    /// [`KernelIsa`] token the kernels then trust without per-call
    /// re-checks. Every ISA produces bit-identical output.
    pub fn set_isa(&mut self, isa: Isa) {
        self.isa = isa.validated();
    }

    /// Install one layer's tuned block knobs before its dispatch: the
    /// micro-kernel row-block height (clamped to
    /// `1..=`[`MAX_MICRO_ROWS`]) and the column-tile width (0 =
    /// untiled). The plan executor calls this per op with the knobs the
    /// per-layer autotuner baked into [`crate::model::PlanOp`]; knobs
    /// never change output bits (see [`ParallelConfig::micro_rows`] /
    /// the dispatch docs), only the schedule.
    pub fn set_block_knobs(&mut self, micro_rows: usize, tile_cols: usize) {
        self.cfg.micro_rows = micro_rows.clamp(1, MAX_MICRO_ROWS);
        self.cfg.tile_cols = tile_cols;
    }

    /// Whether a pool is attached (i.e. parallel dispatch is possible).
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// The core owning `scheme`'s rows.
    pub fn core_for(&self, scheme: Scheme) -> &dyn GemmCore {
        match scheme {
            Scheme::PotW4A4 => &self.pot4,
            Scheme::FixedW4A4 => &self.fixed4,
            Scheme::FixedW8A4 => &self.fixed8,
            Scheme::ApotW4A4 => &self.apot4,
        }
    }

    /// `y = Qa(x) @ Qw(w)^T` over integer codes. Output is (batch, rows).
    /// Test convenience wrapper: sorts the layout per call — the serving
    /// path uses a load-time [`SortedWeights`] with
    /// [`MixedGemm::dispatch`] instead.
    #[cfg(test)]
    pub(crate) fn run(&self, acts: &PackedActs, w: &PackedWeights) -> Mat {
        let part = RowPartition::from_schemes(&w.scheme);
        self.run_partitioned(acts, w, &part)
    }

    /// Run with a precomputed partition, parallel when a pool is
    /// attached and the shape is worth it.
    #[cfg(test)]
    pub(crate) fn run_partitioned(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
    ) -> Mat {
        self.run_partitioned_with(acts, w, part, true)
    }

    /// Sequential reference path — bit-exact oracle for the parallel one.
    #[cfg(test)]
    pub(crate) fn run_partitioned_seq(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
    ) -> Mat {
        self.run_partitioned_with(acts, w, part, false)
    }

    /// `parallel = false` forces the sequential path (the coordinator
    /// disables row-level parallelism for batches that already fill the
    /// machine via the batch dimension). Compatibility wrapper around
    /// [`MixedGemm::dispatch`] for the reference interpreter: sorts the
    /// weight layout, chunks the partition, and allocates the output and
    /// scratch per call.
    pub(crate) fn run_partitioned_with(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
        parallel: bool,
    ) -> Mat {
        let sw = SortedWeights::from_packed(w);
        // fail loudly on a partition built from different weights — this
        // wrapper sorts per call, so the check is off the hot path
        assert_eq!(sw.partition(), part, "partition does not match weights");
        let chunks = chunk_tasks(sw.partition(), self.cfg.min_rows_per_task);
        let mut scratch = GemmScratch::new(self.lanes());
        let mut out = Mat::zeros(acts.rows, w.rows);
        self.dispatch(
            GemmCall {
                acts: GemmActs::Packed(acts),
                weights: &sw,
                chunks: &chunks,
                parallel,
                fill: true,
                out: GemmOut::F32(&mut out),
            },
            &mut scratch,
        );
        out
    }

    /// Run one fully-described mixed GEMM (see [`GemmCall`]) — the
    /// single public dispatch entry point. The (acts × out) combination
    /// selects the kernel:
    ///
    /// * `Packed` + `F32` — the staged explicit GEMM.
    /// * `Packed` + `Quant` — explicit GEMM with the fused
    ///   requantization epilogue.
    /// * `Tiles` + `F32` — implicit column-tile streaming.
    /// * `Tiles` + `Quant` — implicit streaming + fused epilogue (the
    ///   conv hot path: no col buffer, no f32 staging matrix).
    ///
    /// All four are bit-exact against each other and against the
    /// sequential scalar path, for any chunk schedule, panel width,
    /// thread count, and kernel ISA: per-cell arithmetic is identical
    /// (same K tiling, same i32 accumulation, same dequant expression,
    /// per-cell epilogue), tasks write disjoint cells, and the pool's
    /// join barrier publishes them. No heap allocation once `scratch`
    /// has warmed up to the batch/panel size.
    pub fn dispatch(&self, call: GemmCall<'_>, scratch: &mut GemmScratch) {
        let GemmCall { acts, weights: sw, chunks, parallel, fill, out } = call;
        match (acts, out) {
            (GemmActs::Packed(acts), GemmOut::F32(out)) => {
                self.run_packed_f32(acts, sw, chunks, parallel, fill, scratch, out)
            }
            (GemmActs::Packed(acts), GemmOut::Quant { out, epi }) => {
                self.run_packed_quant(acts, sw, chunks, &epi, parallel, fill, scratch, out)
            }
            (GemmActs::Tiles { src, positions }, GemmOut::F32(out)) => {
                self.run_tiles_f32(src, sw, chunks, positions, parallel, fill, scratch, out)
            }
            (GemmActs::Tiles { src, positions }, GemmOut::Quant { out, epi }) => {
                self.run_tiles_quant(src, sw, chunks, &epi, positions, parallel, fill, scratch, out)
            }
        }
    }

    /// Scratch lanes this engine's dispatch can use concurrently: the
    /// calling thread plus every pool worker.
    pub fn lanes(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads() + 1)
    }

    /// [`GemmCall`] kernel: explicit packed activations, f32 output.
    /// Allocation-free: runs the mixed GEMM over the class-sorted layout
    /// `sw` with a precompiled `chunks` schedule (see [`chunk_tasks`]),
    /// MACing through caller-provided `scratch` lanes in
    /// [`ParallelConfig::micro_rows`]-row micro-kernel blocks and scattering into the
    /// caller-provided `out` (model row order, via the stored
    /// permutation), which must already be sized to `(acts.rows,
    /// sw.rows)`. No heap allocation happens here once `scratch` has
    /// warmed up to the batch size.
    ///
    /// With `fill`, cells of rows absent from `chunks` are zeroed; every
    /// chunked row is written by exactly one chunk, so the result is
    /// bit-exact vs the sequential path for any chunk schedule, thread
    /// count, and kernel ISA.
    #[allow(clippy::too_many_arguments)]
    fn run_packed_f32(
        &self,
        acts: &PackedActs,
        sw: &SortedWeights,
        chunks: &[TaskChunk],
        parallel: bool,
        fill: bool,
        scratch: &mut GemmScratch,
        out: &mut Mat,
    ) {
        assert_eq!(acts.cols, sw.cols, "inner dims");
        assert_eq!((out.rows, out.cols), (acts.rows, sw.rows), "output shape");
        let batch = acts.rows;
        // a full schedule (each sorted row exactly once — the only shape
        // `chunk_tasks` produces) overwrites every cell, so zeroing is
        // only needed for partial standalone schedules; `fill = false`
        // callers (the depthwise per-group loop) own the union contract
        let covered: usize = chunks.iter().map(|c| c.end - c.start).sum();
        if fill && covered < sw.rows {
            out.data.fill(0.0);
        }
        let use_pool = parallel
            && self.pool.is_some()
            && chunks.len() > 1
            && covered >= 2 * self.cfg.min_rows_per_task.max(1);

        let out_cols = out.cols;
        let ptr = SyncOutPtr { p: out.data.as_mut_ptr() };
        let view = acts.view();

        if !use_pool {
            let lane = scratch.lane0_block(batch);
            for chunk in chunks {
                // SAFETY: `ptr` points into `out`, exclusively borrowed
                // for this call; chunks cover disjoint sorted rows.
                unsafe {
                    self.run_chunk(
                        view,
                        sw,
                        *chunk,
                        0,
                        &mut lane.acc,
                        &mut lane.col,
                        &ptr,
                        out_cols,
                    )
                };
            }
            return;
        }

        let pool = self.pool.as_ref().expect("use_pool implies a pool");
        scratch.ensure(pool.threads() + 1, batch);
        let lanes = SyncLanesPtr { p: scratch.lanes.as_mut_ptr() };
        pool.scoped_for_indexed(chunks.len(), |ti, lane| {
            let chunk = chunks[ti];
            // SAFETY: `lane` is exclusive to this drain loop for the
            // duration of the scoped_for (see `scoped_for_indexed`), and
            // `ensure` above sized the lane list to every lane the pool
            // can hand out. Each chunk owns a disjoint sorted-row range,
            // and the permutation is a bijection, so the output cells
            // written through `ptr` are disjoint across tasks; the
            // scoped join orders them before the caller's reads.
            unsafe {
                let l = &mut *lanes.p.add(lane);
                self.run_chunk(view, sw, chunk, 0, &mut l.acc, &mut l.col, &ptr, out_cols);
            }
        });
    }

    /// Pre-fill every output cell with the code its row would hold under
    /// a zero accumulator: `rq.code(bias[row])`, or `rq.code(bias[row] +
    /// addend[cell])` when the epilogue carries a fused residual. This
    /// matches the f32 path's semantics for rows absent from a partial
    /// standalone schedule (zeroed accumulator, then the bias/add
    /// epilogue); chunked rows are simply overwritten.
    fn prefill_quant(epi: &QuantEpilogue<'_>, batch: usize, rows: usize, out: &mut [u8]) {
        for orig in 0..rows {
            match epi.addend {
                None => {
                    let c = epi.rq.code(epi.bias[orig]);
                    for b in 0..batch {
                        out[epi.layout.index(b, orig)] = c;
                    }
                }
                Some(add) => {
                    for b in 0..batch {
                        let idx = epi.layout.index(b, orig);
                        out[idx] = epi.rq.code(epi.bias[orig] + add[idx]);
                    }
                }
            }
        }
    }

    /// [`GemmCall`] kernel: explicit packed activations, quantized
    /// output. Runs the mixed GEMM and maps every accumulator straight
    /// to the *consumer layer's* activation code — `rq.code(dequant +
    /// bias [+ addend])`, the fused dequant → bias → add → ReLU →
    /// requantize epilogue ([`requant_block`]) — scattering codes into
    /// `out` in the requested [`OutLayout`]. For the conv layout
    /// (`Nchw`) this also fuses the col2im fold, so the integer path
    /// writes the next layer's NCHW code slot directly: no f32 staging
    /// matrix, no separate bias/ReLU pass, no col2im, no requantize
    /// pass.
    ///
    /// `epi.bias` is in model row order (the epilogue gathers it through
    /// the sorted layout's permutation). Codes are bit-exact vs running
    /// the f32-resident path and quantizing its stored output at the top
    /// of the next layer, for any chunk schedule, thread count, and
    /// kernel ISA (same argument as the f32 dispatch: disjoint cells,
    /// identical per-row arithmetic, and the epilogue is per-cell). With
    /// `fill`, rows absent from a partial schedule hold the
    /// zero-accumulator code (see [`MixedGemm::prefill_quant`]).
    #[allow(clippy::too_many_arguments)]
    fn run_packed_quant(
        &self,
        acts: &PackedActs,
        sw: &SortedWeights,
        chunks: &[TaskChunk],
        epi: &QuantEpilogue<'_>,
        parallel: bool,
        fill: bool,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) {
        assert_eq!(acts.cols, sw.cols, "inner dims");
        assert_eq!(epi.bias.len(), sw.rows, "bias length");
        assert_eq!(out.len(), epi.layout.len(acts.rows, sw.rows), "output length");
        if let Some(add) = epi.addend {
            assert_eq!(add.len(), out.len(), "addend length");
        }
        let batch = acts.rows;
        let covered: usize = chunks.iter().map(|c| c.end - c.start).sum();
        if fill && covered < sw.rows {
            MixedGemm::prefill_quant(epi, batch, sw.rows, out);
        }
        let use_pool = parallel
            && self.pool.is_some()
            && chunks.len() > 1
            && covered >= 2 * self.cfg.min_rows_per_task.max(1);

        let ptr = SyncOutPtr { p: out.as_mut_ptr() };
        let view = acts.view();
        let (bias, rq, layout, addend) = (epi.bias, epi.rq, epi.layout, epi.addend);

        if !use_pool {
            let lane = scratch.lane0_block(batch);
            for chunk in chunks {
                // SAFETY: `ptr` points into `out`, exclusively borrowed
                // for this call; chunks cover disjoint sorted rows.
                unsafe {
                    self.run_chunk_quant(
                        view,
                        sw,
                        *chunk,
                        0,
                        bias,
                        rq,
                        layout,
                        addend,
                        &mut lane.acc,
                        &mut lane.col,
                        &mut lane.codes,
                        &ptr,
                    )
                };
            }
            return;
        }

        let pool = self.pool.as_ref().expect("use_pool implies a pool");
        scratch.ensure(pool.threads() + 1, batch);
        let lanes = SyncLanesPtr { p: scratch.lanes.as_mut_ptr() };
        pool.scoped_for_indexed(chunks.len(), |ti, lane| {
            let chunk = chunks[ti];
            // SAFETY: as in `run_packed_f32` — exclusive lane per drain
            // loop, disjoint output cells per chunk in either layout,
            // join barrier publishes the writes.
            unsafe {
                let l = &mut *lanes.p.add(lane);
                self.run_chunk_quant(
                    view,
                    sw,
                    chunk,
                    0,
                    bias,
                    rq,
                    layout,
                    addend,
                    &mut l.acc,
                    &mut l.col,
                    &mut l.codes,
                    &ptr,
                );
            }
        });
    }

    /// Positions per packed panel for an implicit dispatch: the compiled
    /// width, clamped to the batch and (when a pool drains the tiles)
    /// halved — never below 8 — until there are at least two tiles per
    /// lane to pull. Panel width never changes any output bit: every
    /// cell's arithmetic is independent of how positions are grouped.
    fn panel_tile(batch: usize, panel_positions: usize, lanes: usize) -> usize {
        let mut tb = panel_positions.max(1).min(batch.max(1));
        while lanes > 1 && batch.div_ceil(tb) < 2 * lanes && tb > 8 {
            tb = (tb / 2).max(8);
        }
        tb
    }

    /// [`GemmCall`] kernel: implicit column tiles, f32 output. Like
    /// [`MixedGemm::run_packed_f32`], but the activation matrix is
    /// never materialized — the batch dimension (conv output positions)
    /// is walked in `panel_positions`-wide column tiles, each packed by
    /// `src` into a per-lane L1/L2-sized panel
    /// ([`ColTileSource::view`]) that **every** chunk and micro-kernel
    /// block of the layer then sweeps while it is hot. Parallelism moves
    /// to the tile axis: tiles own disjoint output positions (every row
    /// of every position), so tasks write disjoint cells for any
    /// schedule.
    ///
    /// Bit-exact vs packing the full matrix and running the explicit
    /// kernel: the panel rows hold exactly the codes the explicit
    /// im2col + quantize would produce (shared gather kernel), and
    /// per-cell arithmetic is identical — same K tiling, same i32
    /// accumulation, same dequant expression — for any panel width,
    /// thread count, and ISA.
    #[allow(clippy::too_many_arguments)]
    fn run_tiles_f32(
        &self,
        src: &ColTileSource,
        sw: &SortedWeights,
        chunks: &[TaskChunk],
        panel_positions: usize,
        parallel: bool,
        fill: bool,
        scratch: &mut GemmScratch,
        out: &mut Mat,
    ) {
        let batch = src.batch();
        assert_eq!(src.cols(), sw.cols, "inner dims");
        assert_eq!((out.rows, out.cols), (batch, sw.rows), "output shape");
        let covered: usize = chunks.iter().map(|c| c.end - c.start).sum();
        if fill && covered < sw.rows {
            out.data.fill(0.0);
        }
        if batch == 0 || chunks.is_empty() {
            return;
        }
        let out_cols = out.cols;
        let ptr = SyncOutPtr { p: out.data.as_mut_ptr() };
        let use_pool = parallel && self.pool.is_some() && batch > 1;

        if !use_pool {
            let tb = MixedGemm::panel_tile(batch, panel_positions, 1);
            scratch.ensure(1, tb);
            let Lane { col, acc, panel, .. } = &mut scratch.lanes[0];
            let mut b0 = 0usize;
            while b0 < batch {
                let nb = tb.min(batch - b0);
                let view = src.view(b0, nb, panel);
                for chunk in chunks {
                    // SAFETY: `ptr` points into `out`, exclusively
                    // borrowed for this call; sequential tiles write
                    // disjoint position ranges.
                    unsafe { self.run_chunk(view, sw, *chunk, b0, acc, col, &ptr, out_cols) };
                }
                b0 += nb;
            }
            return;
        }

        let pool = self.pool.as_ref().expect("use_pool implies a pool");
        let lanes_n = pool.threads() + 1;
        let tb = MixedGemm::panel_tile(batch, panel_positions, lanes_n);
        let ntiles = batch.div_ceil(tb);
        scratch.ensure(lanes_n, tb);
        let lanes = SyncLanesPtr { p: scratch.lanes.as_mut_ptr() };
        pool.scoped_for_indexed(ntiles, |ti, lane| {
            // SAFETY: the lane is exclusive to this drain loop (see
            // `scoped_for_indexed`) and `ensure` sized the lane list;
            // tile `ti` owns positions `b0..b0 + nb` exclusively, so all
            // cells written through `ptr` are disjoint across tasks and
            // the scoped join publishes them.
            unsafe {
                let Lane { col, acc, panel, .. } = &mut *lanes.p.add(lane);
                let b0 = ti * tb;
                let nb = tb.min(batch - b0);
                let view = src.view(b0, nb, panel);
                for chunk in chunks {
                    self.run_chunk(view, sw, *chunk, b0, acc, col, &ptr, out_cols);
                }
            }
        });
    }

    /// [`GemmCall`] kernel: implicit column tiles, quantized output —
    /// implicit packing on the way in ([`MixedGemm::run_tiles_f32`]),
    /// the fused dequant → bias → add → ReLU → requantize epilogue and
    /// layout scatter ([`MixedGemm::run_packed_quant`]) on the way out.
    /// The conv hot path touches neither a col buffer nor an f32 staging
    /// matrix. Same bit-exactness contract as both parents.
    #[allow(clippy::too_many_arguments)]
    fn run_tiles_quant(
        &self,
        src: &ColTileSource,
        sw: &SortedWeights,
        chunks: &[TaskChunk],
        epi: &QuantEpilogue<'_>,
        panel_positions: usize,
        parallel: bool,
        fill: bool,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) {
        let batch = src.batch();
        assert_eq!(src.cols(), sw.cols, "inner dims");
        assert_eq!(epi.bias.len(), sw.rows, "bias length");
        assert_eq!(out.len(), epi.layout.len(batch, sw.rows), "output length");
        if let Some(add) = epi.addend {
            assert_eq!(add.len(), out.len(), "addend length");
        }
        let covered: usize = chunks.iter().map(|c| c.end - c.start).sum();
        if fill && covered < sw.rows {
            MixedGemm::prefill_quant(epi, batch, sw.rows, out);
        }
        if batch == 0 || chunks.is_empty() {
            return;
        }
        let ptr = SyncOutPtr { p: out.as_mut_ptr() };
        let use_pool = parallel && self.pool.is_some() && batch > 1;
        let (bias, rq, layout, addend) = (epi.bias, epi.rq, epi.layout, epi.addend);

        if !use_pool {
            let tb = MixedGemm::panel_tile(batch, panel_positions, 1);
            scratch.ensure(1, tb);
            let Lane { col, acc, codes, panel } = &mut scratch.lanes[0];
            let mut b0 = 0usize;
            while b0 < batch {
                let nb = tb.min(batch - b0);
                let view = src.view(b0, nb, panel);
                for chunk in chunks {
                    // SAFETY: as in `run_tiles_f32`.
                    unsafe {
                        self.run_chunk_quant(
                            view, sw, *chunk, b0, bias, rq, layout, addend, acc, col, codes, &ptr,
                        )
                    };
                }
                b0 += nb;
            }
            return;
        }

        let pool = self.pool.as_ref().expect("use_pool implies a pool");
        let lanes_n = pool.threads() + 1;
        let tb = MixedGemm::panel_tile(batch, panel_positions, lanes_n);
        let ntiles = batch.div_ceil(tb);
        scratch.ensure(lanes_n, tb);
        let lanes = SyncLanesPtr { p: scratch.lanes.as_mut_ptr() };
        pool.scoped_for_indexed(ntiles, |ti, lane| {
            // SAFETY: as in `run_tiles_f32` — exclusive lane per drain
            // loop, disjoint position ranges per tile in either layout,
            // join barrier publishes the writes.
            unsafe {
                let Lane { col, acc, codes, panel } = &mut *lanes.p.add(lane);
                let b0 = ti * tb;
                let nb = tb.min(batch - b0);
                let view = src.view(b0, nb, panel);
                for chunk in chunks {
                    self.run_chunk_quant(
                        view, sw, *chunk, b0, bias, rq, layout, addend, acc, col, codes, &ptr,
                    );
                }
            }
        });
    }

    /// Run one chunk through the fused requantization epilogue: block
    /// GEMM into the lane's f32 block, [`requant_block`] into the lane's
    /// code block, then scatter codes through `sw.perm` in the output
    /// layout. `acts` is the activation view the chunk sweeps — the
    /// whole matrix (explicit dispatch, `b_base = 0`) or one packed
    /// column-tile panel whose rows are global positions
    /// `b_base..b_base + acts.rows` (implicit dispatch).
    ///
    /// With a fused `addend` the per-cell expression becomes
    /// `rq.code(dequant + bias + addend[cell])` — requantize and
    /// scatter collapse into one per-cell pass since the addend is
    /// indexed in output layout. IEEE f32 addition is commutative, so
    /// the sum is bit-identical to adding the addend to the stored f32
    /// output afterwards; the unsigned quantizer's clamp at zero
    /// subsumes a fused ReLU.
    ///
    /// # Safety
    ///
    /// `out.p` must point at a buffer of `layout.len(total batch,
    /// sw.rows)` u8 elements that outlives the call, and no other thread
    /// may concurrently write the cells this (chunk × position-range)
    /// task owns.
    #[allow(clippy::too_many_arguments)]
    unsafe fn run_chunk_quant(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        chunk: TaskChunk,
        b_base: usize,
        bias: &[f32],
        rq: Requant,
        layout: OutLayout,
        addend: Option<&[f32]>,
        acc: &mut [i32],
        col: &mut [f32],
        codes: &mut [u8],
        out: &SyncOutPtr<u8>,
    ) {
        let batch = acts.rows;
        let core = self.core_for(chunk.scheme);
        let tile = self.cfg.tile_cols;
        let mr = self.cfg.micro_rows.clamp(1, MAX_MICRO_ROWS);
        let mut r = chunk.start;
        while r < chunk.end {
            let nr = mr.min(chunk.end - r);
            core.run_block_tiled(acts, sw, r, nr, tile, self.isa, acc, col);
            if let Some(add) = addend {
                // fused-residual epilogue: per-cell, straight from the
                // dequantized block — the codes staging buffer is idle
                for j in 0..nr {
                    let orig = sw.perm[r + j];
                    let brow = bias[orig];
                    for b in 0..batch {
                        let idx = layout.index(b_base + b, orig);
                        *out.p.add(idx) = rq.code(col[j * batch + b] + brow + add[idx]);
                    }
                }
                r += nr;
                continue;
            }
            let mut bias_block = [0.0f32; MAX_MICRO_ROWS];
            for (j, b) in bias_block.iter_mut().enumerate().take(nr) {
                *b = bias[sw.perm[r + j]];
            }
            requant_block(col, nr, batch, &bias_block, rq, codes);
            for j in 0..nr {
                let orig = sw.perm[r + j];
                let src = &codes[j * batch..(j + 1) * batch];
                match layout {
                    OutLayout::RowMajor { .. } => {
                        for (b, &c) in src.iter().enumerate() {
                            *out.p.add(layout.index(b_base + b, orig)) = c;
                        }
                    }
                    OutLayout::Nchw { hw, .. } => {
                        // contiguous per-image runs: this row's codes for
                        // the positions of one image land back to back in
                        // the channel's NCHW plane, even when a panel
                        // straddles an image boundary
                        let mut b = 0usize;
                        while b < batch {
                            let gb = b_base + b;
                            let run = (hw - gb % hw).min(batch - b);
                            let dst = out.p.add(layout.index(gb, orig));
                            std::ptr::copy_nonoverlapping(src.as_ptr().add(b), dst, run);
                            b += run;
                        }
                    }
                }
            }
            r += nr;
        }
    }

    /// Run one chunk in [`ParallelConfig::micro_rows`]-row micro-kernel
    /// blocks, scattering each block's output to model row order through
    /// `sw.perm`. `acts` and `b_base` as in
    /// [`MixedGemm::run_chunk_quant`].
    ///
    /// # Safety
    ///
    /// `out.p` must point at a `(total batch, out_cols)` row-major f32
    /// matrix that outlives the call, and no other thread may
    /// concurrently write the cells this (chunk × position-range) task
    /// owns.
    #[allow(clippy::too_many_arguments)]
    unsafe fn run_chunk(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        chunk: TaskChunk,
        b_base: usize,
        acc: &mut [i32],
        col: &mut [f32],
        out: &SyncOutPtr<f32>,
        out_cols: usize,
    ) {
        let batch = acts.rows;
        let core = self.core_for(chunk.scheme);
        let tile = self.cfg.tile_cols;
        let mr = self.cfg.micro_rows.clamp(1, MAX_MICRO_ROWS);
        let mut r = chunk.start;
        while r < chunk.end {
            let nr = mr.min(chunk.end - r);
            core.run_block_tiled(acts, sw, r, nr, tile, self.isa, acc, col);
            for j in 0..nr {
                let orig = sw.perm[r + j];
                for (b, &v) in col[j * batch..(j + 1) * batch].iter().enumerate() {
                    *out.p.add((b_base + b) * out_cols + orig) = v;
                }
            }
            r += nr;
        }
    }

    /// Single-row dispatch used by the reference interpreter's grouped
    /// path: `out[b] += ...` with the engine's tile size. `acc` is i32
    /// scratch (len = batch).
    pub(crate) fn run_row_into(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        self.core_for(w.scheme[r]).run_row_tiled(acts, w, r, self.cfg.tile_cols, acc, out);
    }

    /// Float-path equivalent: fake-quant the operands and matmul. Used by
    /// tests to pin integer == fake-quant and by the runtime comparison
    /// against the AOT reference outputs.
    pub fn run_float(
        &self,
        x: &Mat,
        w: &Mat,
        schemes: &[Scheme],
        alpha: &[f32],
        act_alpha: f32,
        act_bits: u32,
    ) -> Mat {
        let mut xq = x.clone();
        for v in xq.data.iter_mut() {
            *v = crate::quant::act_quant(*v, act_alpha, act_bits);
        }
        let wq = crate::quant::rowwise_quant(w, alpha, schemes);
        xq.matmul_nt(&wq)
    }
}

/// MAC counts per scheme class for one GEMM — feeds the FPGA cycle model
/// and the GOP/s accounting in Table 6.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MacCounts {
    pub pot4: u64,
    pub fixed4: u64,
    pub fixed8: u64,
    pub apot4: u64,
}

impl MacCounts {
    pub fn of(part: &RowPartition, batch: usize, cols: usize) -> MacCounts {
        let per_row = (batch * cols) as u64;
        MacCounts {
            pot4: part.len_of(Scheme::PotW4A4) as u64 * per_row,
            fixed4: part.len_of(Scheme::FixedW4A4) as u64 * per_row,
            fixed8: part.len_of(Scheme::FixedW8A4) as u64 * per_row,
            apot4: part.len_of(Scheme::ApotW4A4) as u64 * per_row,
        }
    }

    pub fn total(&self) -> u64 {
        self.pot4 + self.fixed4 + self.fixed8 + self.apot4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::default_alpha;
    use crate::util::rng::Rng;

    fn rand_problem(
        rows: usize,
        cols: usize,
        batch: usize,
        seed: u64,
    ) -> (Mat, Mat, Vec<Scheme>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.2)).collect();
        let x = Mat::from_vec(batch, cols, xd);
        let wd: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.5).collect();
        let w = Mat::from_vec(rows, cols, wd);
        let schemes: Vec<Scheme> = (0..rows)
            .map(|_| match rng.below(4) {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                2 => Scheme::FixedW8A4,
                _ => Scheme::ApotW4A4,
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
        (x, w, schemes, alpha)
    }

    // thin GemmCall builders so the grids below stay readable
    #[allow(clippy::too_many_arguments)]
    fn dispatch_f32(
        g: &MixedGemm,
        acts: GemmActs<'_>,
        sw: &SortedWeights,
        chunks: &[TaskChunk],
        parallel: bool,
        fill: bool,
        scratch: &mut GemmScratch,
        out: &mut Mat,
    ) {
        g.dispatch(
            GemmCall { acts, weights: sw, chunks, parallel, fill, out: GemmOut::F32(out) },
            scratch,
        );
    }

    #[allow(clippy::too_many_arguments)]
    fn dispatch_quant(
        g: &MixedGemm,
        acts: GemmActs<'_>,
        sw: &SortedWeights,
        chunks: &[TaskChunk],
        epi: QuantEpilogue<'_>,
        parallel: bool,
        fill: bool,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) {
        g.dispatch(
            GemmCall {
                acts,
                weights: sw,
                chunks,
                parallel,
                fill,
                out: GemmOut::Quant { out, epi },
            },
            scratch,
        );
    }

    #[test]
    fn integer_equals_fake_quant() {
        let (x, w, schemes, alpha) = rand_problem(17, 29, 5, 7);
        let g = MixedGemm::new();
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let int_out = g.run(&acts, &pw);
        let float_out = g.run_float(&x, &w, &schemes, &alpha, 1.0, 4);
        let err = int_out.max_abs_err(&float_out);
        assert!(err < 1e-3, "int vs fake-quant err {err}");
    }

    #[test]
    fn partition_ranges_tile_all_rows() {
        let (_, _, schemes, _) = rand_problem(100, 4, 1, 3);
        let p = RowPartition::from_schemes(&schemes);
        assert_eq!(p.total(), 100);
        let mut next = 0usize;
        for s in RowPartition::CLASS_ORDER {
            let r = p.range(s);
            assert_eq!(r.start, next, "{s} range not contiguous");
            assert_eq!(r.len(), schemes.iter().filter(|x| **x == s).count());
            for sr in r.clone() {
                assert_eq!(p.scheme_of(sr), s);
            }
            next = r.end;
        }
        assert_eq!(next, 100);
    }

    #[test]
    fn fractions_cover_all_four_classes() {
        let schemes = vec![
            Scheme::PotW4A4,
            Scheme::FixedW4A4,
            Scheme::FixedW8A4,
            Scheme::ApotW4A4,
            Scheme::ApotW4A4,
            Scheme::ApotW4A4,
            Scheme::PotW4A4,
            Scheme::PotW4A4,
        ];
        let p = RowPartition::from_schemes(&schemes);
        let f = p.fractions();
        assert_eq!(f, [3.0 / 8.0, 1.0 / 8.0, 1.0 / 8.0, 3.0 / 8.0]);
        // the regression the 3-tuple version had: APoT rows must not make
        // the fractions sum fall short of 1.
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(RowPartition::default().fractions(), [0.0; 4]);
    }

    #[test]
    fn parallel_is_bit_exact_vs_sequential() {
        let (x, w, schemes, alpha) = rand_problem(67, 41, 6, 11);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let part = RowPartition::from_schemes(&schemes);
        // every tuned block height must agree with the sequential path
        for micro_rows in [1usize, 4, 6, 8] {
            let cfg = ParallelConfig {
                threads: 4,
                tile_cols: 16,
                min_rows_per_task: 3,
                micro_rows,
            };
            let par = MixedGemm::with_config(cfg);
            let want = par.run_partitioned_seq(&acts, &pw, &part);
            for _ in 0..3 {
                let got = par.run_partitioned(&acts, &pw, &part);
                assert_eq!(got.data, want.data, "mr {micro_rows} parallel output diverged");
            }
        }
    }

    #[test]
    fn chunk_tasks_interleave_and_cover() {
        let schemes = [
            vec![Scheme::PotW4A4; 10],
            vec![Scheme::FixedW4A4; 5],
            vec![Scheme::FixedW8A4; 1],
        ]
        .concat();
        let part = RowPartition::from_schemes(&schemes);
        let tasks = chunk_tasks(&part, 4);
        // chunks: pot 4+4+2, fixed4 4+1, fixed8 1 — interleaved
        assert_eq!(tasks.len(), 6);
        let covered: usize = tasks.iter().map(|t| t.end - t.start).sum();
        assert_eq!(covered, 16);
        // round-robin: first three tasks are one chunk per class
        assert_eq!(tasks[0].scheme, Scheme::PotW4A4);
        assert_eq!(tasks[1].scheme, Scheme::FixedW4A4);
        assert_eq!(tasks[2].scheme, Scheme::FixedW8A4);
        // chunk ranges are absolute sorted rows: pot rows 0..10, fixed4
        // rows 10..15, fixed8 row 15
        assert_eq!((tasks[0].start, tasks[0].end), (0, 4));
        assert_eq!((tasks[1].start, tasks[1].end), (10, 14));
        assert_eq!((tasks[2].start, tasks[2].end), (15, 16));
        assert_eq!((tasks[5].start, tasks[5].end), (8, 10));
        // chunks stay inside their class range
        for t in &tasks {
            let r = part.range(t.scheme);
            assert!(t.start >= r.start && t.end <= r.end, "{t:?} outside {r:?}");
        }
    }

    #[test]
    fn dispatch_matches_allocating_path() {
        let (x, w, schemes, alpha) = rand_problem(33, 24, 5, 21);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let part = RowPartition::from_schemes(&schemes);
        let g = MixedGemm::with_config(ParallelConfig {
            threads: 3,
            tile_cols: 16,
            min_rows_per_task: 4,
            ..ParallelConfig::default()
        });
        let want = g.run_partitioned_seq(&acts, &pw, &part);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), 4);
        let mut scratch = GemmScratch::with_capacity(g.lanes(), MAX_MICRO_ROWS * acts.rows, 0);
        let mut out = Mat::zeros(acts.rows, pw.rows);
        for parallel in [false, true] {
            out.data.fill(f32::NAN); // must be fully overwritten
            dispatch_f32(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                &chunks,
                parallel,
                true,
                &mut scratch,
                &mut out,
            );
            assert_eq!(out.data, want.data, "parallel={parallel}");
        }
    }

    #[test]
    fn partial_schedules_zero_unchunked_rows() {
        let (x, w, schemes, alpha) = rand_problem(12, 9, 3, 31);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let sw = SortedWeights::from_packed(&pw);
        let full = chunk_tasks(sw.partition(), 3);
        let g = MixedGemm::new();
        let mut scratch = GemmScratch::new(1);
        let mut want = Mat::zeros(3, 12);
        dispatch_f32(&g, GemmActs::Packed(&acts), &sw, &full, false, true, &mut scratch, &mut want);
        // drop the last chunk: its rows must come back zeroed
        let partial = &full[..full.len() - 1];
        let dropped = full[full.len() - 1];
        let mut got = Mat::zeros(3, 12);
        got.data.fill(f32::NAN);
        dispatch_f32(
            &g,
            GemmActs::Packed(&acts),
            &sw,
            partial,
            false,
            true,
            &mut scratch,
            &mut got,
        );
        for sr in 0..12 {
            let orig = sw.perm[sr];
            for b in 0..3 {
                if sr >= dropped.start && sr < dropped.end {
                    assert_eq!(got.at(b, orig), 0.0, "dropped row {sr} not zeroed");
                } else {
                    assert_eq!(got.at(b, orig), want.at(b, orig));
                }
            }
        }
    }

    #[test]
    fn no_fill_leaves_unchunked_cells_untouched() {
        // the depthwise per-group contract: a fill=false call writes its
        // chunks' rows and nothing else, so complementary calls compose
        let (x, w, schemes, alpha) = rand_problem(12, 9, 3, 57);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let sw = SortedWeights::from_packed(&pw);
        let full = chunk_tasks(sw.partition(), 3);
        let g = MixedGemm::new();
        let mut scratch = GemmScratch::new(1);
        let mut want = Mat::zeros(3, 12);
        dispatch_f32(&g, GemmActs::Packed(&acts), &sw, &full, false, true, &mut scratch, &mut want);
        // run the schedule one chunk at a time with fill=false: the
        // sentinel must survive in every not-yet-written cell, and the
        // union must equal the single full-schedule call
        let mut got = Mat::zeros(3, 12);
        got.data.fill(f32::NAN);
        for (i, chunk) in full.iter().enumerate() {
            let one = [*chunk];
            dispatch_f32(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                &one,
                false,
                false,
                &mut scratch,
                &mut got,
            );
            let written: usize = full[..=i].iter().map(|c| c.end - c.start).sum();
            let nans = got.data.iter().filter(|v| v.is_nan()).count();
            assert_eq!(nans, 3 * (12 - written), "chunk {i} touched foreign cells");
        }
        assert_eq!(got.data, want.data);

        // quant flavor: bias-only cells must also survive
        let bias: Vec<f32> = (0..12).map(|r| r as f32 * 0.01).collect();
        let rq = Requant::new(0.9, 4);
        let layout = OutLayout::RowMajor { cols: 12 };
        let mut want_q = vec![0u8; 3 * 12];
        dispatch_quant(
            &g,
            GemmActs::Packed(&acts),
            &sw,
            &full,
            QuantEpilogue { bias: &bias, rq, layout, addend: None },
            false,
            true,
            &mut scratch,
            &mut want_q,
        );
        let mut got_q = vec![0xffu8; 3 * 12];
        for chunk in &full {
            let one = [*chunk];
            dispatch_quant(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                &one,
                QuantEpilogue { bias: &bias, rq, layout, addend: None },
                false,
                false,
                &mut scratch,
                &mut got_q,
            );
        }
        assert_eq!(got_q, want_q);
    }

    #[test]
    fn fused_addend_matches_separate_add_then_requantize() {
        // the epilogue-fusion contract: code(acc + bias + addend) must
        // equal adding the addend to the stored f32 output and then
        // requantizing — bit-exact in both layouts, seq and parallel,
        // explicit and implicit
        let (x, w, schemes, alpha) = rand_problem(16, 18, 6, 63);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), 3);
        let bias: Vec<f32> = (0..16).map(|r| (r as f32 - 7.0) * 0.02).collect();
        let rq = Requant::new(0.7, 4);
        let g = MixedGemm::with_config(ParallelConfig {
            threads: 3,
            tile_cols: 16,
            min_rows_per_task: 3,
            micro_rows: 6,
        });
        let mut scratch = GemmScratch::new(g.lanes());

        let mut stage = Mat::zeros(6, 16);
        dispatch_f32(
            &g,
            GemmActs::Packed(&acts),
            &sw,
            &chunks,
            false,
            true,
            &mut scratch,
            &mut stage,
        );

        let (channels, hw) = (16usize, 3usize); // batch 6 = 2 images x 3 positions
        let mut rng = Rng::new(7);
        for (layout, len) in [
            (OutLayout::RowMajor { cols: 16 }, 6 * 16),
            (OutLayout::Nchw { channels, hw }, 2 * channels * hw),
        ] {
            let addend: Vec<f32> = (0..len).map(|_| rng.uniform(-0.3, 0.3)).collect();
            let mut want = vec![0u8; len];
            for b in 0..6 {
                for r in 0..16 {
                    let idx = layout.index(b, r);
                    want[idx] = rq.code(stage.at(b, r) + bias[r] + addend[idx]);
                }
            }
            for parallel in [false, true] {
                let mut got = vec![0xffu8; len];
                dispatch_quant(
                    &g,
                    GemmActs::Packed(&acts),
                    &sw,
                    &chunks,
                    QuantEpilogue { bias: &bias, rq, layout, addend: Some(&addend) },
                    parallel,
                    true,
                    &mut scratch,
                    &mut got,
                );
                assert_eq!(got, want, "explicit {layout:?} parallel={parallel}");
                // implicit flavor: same epilogue over column tiles
                let src = ColTileSource::Packed {
                    codes: &acts.codes,
                    rows: acts.rows,
                    cols: acts.cols,
                    alpha: 1.0,
                    bits: 4,
                };
                let mut got = vec![0xffu8; len];
                dispatch_quant(
                    &g,
                    GemmActs::Tiles { src: &src, positions: 4 },
                    &sw,
                    &chunks,
                    QuantEpilogue { bias: &bias, rq, layout, addend: Some(&addend) },
                    parallel,
                    true,
                    &mut scratch,
                    &mut got,
                );
                assert_eq!(got, want, "implicit {layout:?} parallel={parallel}");
            }
            // partial schedule: dropped rows hold code(bias + addend)
            let partial = &chunks[..chunks.len() - 1];
            let dropped = chunks[chunks.len() - 1];
            let mut got = vec![0xffu8; len];
            dispatch_quant(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                partial,
                QuantEpilogue { bias: &bias, rq, layout, addend: Some(&addend) },
                false,
                true,
                &mut scratch,
                &mut got,
            );
            for sr in 0..16 {
                let orig = sw.perm[sr];
                for b in 0..6 {
                    let idx = layout.index(b, orig);
                    let w = if sr >= dropped.start && sr < dropped.end {
                        rq.code(bias[orig] + addend[idx])
                    } else {
                        want[idx]
                    };
                    assert_eq!(got[idx], w, "partial sr {sr} b {b}");
                }
            }
        }
    }

    #[test]
    fn quant_dispatch_matches_f32_path_then_requantize() {
        // the fused epilogue must equal: f32 dispatch -> +bias ->
        // quantize with the consumer scale — bit-exact, in both layouts,
        // sequential and parallel.
        let (x, w, schemes, alpha) = rand_problem(24, 27, 6, 41);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), 3);
        let bias: Vec<f32> = (0..24).map(|r| (r as f32 - 11.0) * 0.01).collect();
        let rq = Requant::new(0.8, 4);
        let g = MixedGemm::with_config(ParallelConfig {
            threads: 3,
            tile_cols: 16,
            min_rows_per_task: 4,
            ..ParallelConfig::default()
        });
        let mut scratch = GemmScratch::new(g.lanes());

        // reference: f32 dispatch, then the separate bias + requantize
        let mut stage = Mat::zeros(6, 24);
        dispatch_f32(
            &g,
            GemmActs::Packed(&acts),
            &sw,
            &chunks,
            false,
            true,
            &mut scratch,
            &mut stage,
        );
        let mut want_rm = vec![0u8; 6 * 24];
        for b in 0..6 {
            for r in 0..24 {
                want_rm[b * 24 + r] = rq.code(stage.at(b, r) + bias[r]);
            }
        }
        // NCHW reference: batch 6 = 2 images x 3 spatial positions
        let (channels, hw) = (24usize, 3usize);
        let mut want_nchw = vec![0u8; 2 * channels * hw];
        for img in 0..2 {
            for r in 0..channels {
                for pos in 0..hw {
                    want_nchw[((img * channels) + r) * hw + pos] =
                        want_rm[(img * hw + pos) * 24 + r];
                }
            }
        }

        for parallel in [false, true] {
            let mut got = vec![0xffu8; 6 * 24];
            dispatch_quant(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                &chunks,
                QuantEpilogue {
                    bias: &bias,
                    rq,
                    layout: OutLayout::RowMajor { cols: 24 },
                    addend: None,
                },
                parallel,
                true,
                &mut scratch,
                &mut got,
            );
            assert_eq!(got, want_rm, "row-major parallel={parallel}");
            let mut got = vec![0xffu8; 2 * channels * hw];
            dispatch_quant(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                &chunks,
                QuantEpilogue {
                    bias: &bias,
                    rq,
                    layout: OutLayout::Nchw { channels, hw },
                    addend: None,
                },
                parallel,
                true,
                &mut scratch,
                &mut got,
            );
            assert_eq!(got, want_nchw, "nchw parallel={parallel}");
        }

        // partial schedule: dropped rows come back as code(bias) — what
        // the f32 path's zeroed accumulator yields after its bias pass
        let partial = &chunks[..chunks.len() - 1];
        let dropped = chunks[chunks.len() - 1];
        let mut got = vec![0xffu8; 6 * 24];
        dispatch_quant(
            &g,
            GemmActs::Packed(&acts),
            &sw,
            partial,
            QuantEpilogue {
                bias: &bias,
                rq,
                layout: OutLayout::RowMajor { cols: 24 },
                addend: None,
            },
            false,
            true,
            &mut scratch,
            &mut got,
        );
        for sr in 0..24 {
            let orig = sw.perm[sr];
            for b in 0..6 {
                let want = if sr >= dropped.start && sr < dropped.end {
                    rq.code(bias[orig])
                } else {
                    want_rm[b * 24 + orig]
                };
                assert_eq!(got[b * 24 + orig], want, "partial sr {sr} b {b}");
            }
        }
    }

    #[test]
    fn implicit_dispatch_matches_explicit_for_any_panel_width() {
        use crate::gemm::panels::{ColTileSource, PatchGeometry};
        // a real conv shape: gather panels from an NCHW f32 map and from
        // its code twin; both must equal explicit im2col + quantize +
        // run_partitioned_into bit for bit, for every panel width,
        // sequentially and in parallel.
        let (n, c, h, w, k, stride, pad) = (2usize, 3usize, 6usize, 5usize, 3usize, 1usize, 1usize);
        let mut rng = Rng::new(91);
        let data: Vec<f32> = (0..n * c * h * w).map(|_| rng.uniform(-0.2, 1.2)).collect();
        let geo = PatchGeometry::new(n, c, h, w, 0, c, k, stride, pad);
        let (batch, cols) = (geo.batch(), geo.cols());
        let (alpha, bits) = (1.1f32, 4u32);

        // explicit reference operand
        let mut patches = vec![0.0f32; batch * cols];
        crate::gemm::panels::pack_patch_rows(&data, 0.0, &geo, 0, batch, &mut patches);
        let acts = PackedActs::quantize(&Mat::from_vec(batch, cols, patches), alpha, bits);

        let rows = 13usize;
        let wd: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.5).collect();
        let wmat = Mat::from_vec(rows, cols, wd);
        let schemes: Vec<Scheme> = (0..rows)
            .map(|r| match r % 4 {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                2 => Scheme::FixedW8A4,
                _ => Scheme::ApotW4A4,
            })
            .collect();
        let av: Vec<f32> = (0..rows).map(|r| default_alpha(wmat.row(r))).collect();
        let pw = PackedWeights::quantize(&wmat, &schemes, &av);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), 3);

        let g = MixedGemm::with_config(ParallelConfig {
            threads: 3,
            tile_cols: 16,
            min_rows_per_task: 3,
            micro_rows: 6,
        });
        let mut scratch = GemmScratch::new(g.lanes());
        let mut want = Mat::zeros(batch, rows);
        dispatch_f32(
            &g,
            GemmActs::Packed(&acts),
            &sw,
            &chunks,
            false,
            true,
            &mut scratch,
            &mut want,
        );

        let codes: Vec<u8> = acts.codes.clone();
        // NCHW codes for the Codes source: quantize the map itself
        let top = ((1u32 << bits) - 1) as f32;
        let inv = top / alpha;
        let nchw_codes: Vec<u8> = data
            .iter()
            .map(|&v| (v * inv).clamp(0.0, top).round_ties_even() as u8)
            .collect();

        for panel_positions in [1usize, 5, 8, 64, 1024] {
            for parallel in [false, true] {
                let sources = [
                    ColTileSource::F32 { data: &data, geo, alpha, bits },
                    ColTileSource::Codes { data: &nchw_codes, geo, alpha, bits },
                    ColTileSource::Packed { codes: &codes, rows: batch, cols, alpha, bits },
                ];
                for (si, src) in sources.iter().enumerate() {
                    let mut got = Mat::zeros(batch, rows);
                    got.data.fill(f32::NAN);
                    dispatch_f32(
                        &g,
                        GemmActs::Tiles { src, positions: panel_positions },
                        &sw,
                        &chunks,
                        parallel,
                        true,
                        &mut scratch,
                        &mut got,
                    );
                    assert_eq!(
                        got.data, want.data,
                        "src {si} panel {panel_positions} parallel {parallel}"
                    );
                }
            }
        }
    }

    #[test]
    fn implicit_quant_dispatch_matches_explicit_in_both_layouts() {
        use crate::gemm::panels::{ColTileSource, PatchGeometry};
        let (n, c, h, w) = (2usize, 2usize, 4usize, 6usize);
        let mut rng = Rng::new(77);
        let data: Vec<f32> = (0..n * c * h * w).map(|_| rng.uniform(0.0, 1.1)).collect();
        let geo = PatchGeometry::new(n, c, h, w, 0, c, 3, 1, 1);
        let (batch, cols) = (geo.batch(), geo.cols());
        let hw = geo.oh * geo.ow;
        let (alpha, bits) = (0.9f32, 4u32);

        let mut patches = vec![0.0f32; batch * cols];
        crate::gemm::panels::pack_patch_rows(&data, 0.0, &geo, 0, batch, &mut patches);
        let acts = PackedActs::quantize(&Mat::from_vec(batch, cols, patches), alpha, bits);

        let rows = 9usize;
        let wd: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.4).collect();
        let wmat = Mat::from_vec(rows, cols, wd);
        let schemes: Vec<Scheme> = (0..rows)
            .map(|r| match r % 3 {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                _ => Scheme::FixedW8A4,
            })
            .collect();
        let av: Vec<f32> = (0..rows).map(|r| default_alpha(wmat.row(r))).collect();
        let pw = PackedWeights::quantize(&wmat, &schemes, &av);
        let sw = SortedWeights::from_packed(&pw);
        let chunks = chunk_tasks(sw.partition(), 2);
        let bias: Vec<f32> = (0..rows).map(|r| (r as f32 - 4.0) * 0.02).collect();
        let rq = Requant::new(0.8, 4);

        let g = MixedGemm::with_config(ParallelConfig {
            threads: 2,
            tile_cols: 8,
            min_rows_per_task: 2,
            micro_rows: 8,
        });
        let mut scratch = GemmScratch::new(g.lanes());

        for (layout, len) in [
            (OutLayout::RowMajor { cols: rows }, batch * rows),
            (OutLayout::Nchw { channels: rows, hw }, n * rows * hw),
        ] {
            let mut want = vec![0u8; len];
            dispatch_quant(
                &g,
                GemmActs::Packed(&acts),
                &sw,
                &chunks,
                QuantEpilogue { bias: &bias, rq, layout, addend: None },
                false,
                true,
                &mut scratch,
                &mut want,
            );
            let src = ColTileSource::F32 { data: &data, geo, alpha, bits };
            for panel_positions in [1usize, 3, 7, 512] {
                for parallel in [false, true] {
                    let mut got = vec![0xffu8; len];
                    dispatch_quant(
                        &g,
                        GemmActs::Tiles { src: &src, positions: panel_positions },
                        &sw,
                        &chunks,
                        QuantEpilogue { bias: &bias, rq, layout, addend: None },
                        parallel,
                        true,
                        &mut scratch,
                        &mut got,
                    );
                    assert_eq!(
                        got, want,
                        "layout {layout:?} panel {panel_positions} parallel {parallel}"
                    );
                }
            }
        }
    }

    #[test]
    fn mac_accounting() {
        let schemes = vec![Scheme::PotW4A4, Scheme::PotW4A4, Scheme::FixedW4A4];
        let p = RowPartition::from_schemes(&schemes);
        let m = MacCounts::of(&p, 8, 16);
        assert_eq!(m.pot4, 2 * 8 * 16);
        assert_eq!(m.fixed4, 8 * 16);
        assert_eq!(m.total(), 3 * 8 * 16);
    }

    #[test]
    fn empty_batch_ok() {
        let (x, w, schemes, alpha) = rand_problem(4, 8, 1, 1);
        let g = MixedGemm::new();
        let acts = PackedActs::quantize(&Mat::zeros(0, 8), 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let out = g.run(&acts, &pw);
        assert_eq!(out.rows, 0);
        let _ = (x, w); // silence
    }
}
