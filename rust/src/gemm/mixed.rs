//! The row-partitioned mixed GEMM (the paper's core §3 computation).
//!
//! Rows of a layer's weight matrix are grouped by scheme class into a
//! [`RowPartition`]; [`MixedGemm`] dispatches each class to its core —
//! exactly how the FPGA feeds filter classes to the GEMM_PoT-4 /
//! GEMM_Fixed-4 / GEMM_Fixed-8 PE arrays. Because the ratio is layer-wise
//! uniform, the partition shape (and thus per-layer schedule) is identical
//! in every layer.

use super::cores::{GemmApot4, GemmCore, GemmFixed4, GemmFixed8, GemmPoT4};
use super::packed::{PackedActs, PackedWeights};
use crate::quant::{Mat, Scheme};

/// Row indices grouped by scheme class.
#[derive(Clone, Debug, Default)]
pub struct RowPartition {
    pub pot4: Vec<usize>,
    pub fixed4: Vec<usize>,
    pub fixed8: Vec<usize>,
    pub apot4: Vec<usize>,
}

impl RowPartition {
    pub fn from_schemes(schemes: &[Scheme]) -> RowPartition {
        let mut p = RowPartition::default();
        for (i, s) in schemes.iter().enumerate() {
            match s {
                Scheme::PotW4A4 => p.pot4.push(i),
                Scheme::FixedW4A4 => p.fixed4.push(i),
                Scheme::FixedW8A4 => p.fixed8.push(i),
                Scheme::ApotW4A4 => p.apot4.push(i),
            }
        }
        p
    }

    pub fn total(&self) -> usize {
        self.pot4.len() + self.fixed4.len() + self.fixed8.len() + self.apot4.len()
    }

    /// (pot4, fixed4, fixed8) fractions — checked against the configured
    /// ratio by the coordinator's admission tests.
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total().max(1) as f64;
        (
            self.pot4.len() as f64 / t,
            self.fixed4.len() as f64 / t,
            self.fixed8.len() as f64 / t,
        )
    }
}

/// The mixed GEMM engine: owns the four cores and a row partition cache.
pub struct MixedGemm {
    fixed4: GemmFixed4,
    fixed8: GemmFixed8,
    pot4: GemmPoT4,
    apot4: GemmApot4,
}

impl Default for MixedGemm {
    fn default() -> Self {
        MixedGemm {
            fixed4: GemmFixed4,
            fixed8: GemmFixed8,
            pot4: GemmPoT4,
            apot4: GemmApot4::default(),
        }
    }
}

impl MixedGemm {
    pub fn new() -> MixedGemm {
        MixedGemm::default()
    }

    /// `y = Qa(x) @ Qw(w)^T` over integer codes. Output is (batch, rows).
    pub fn run(&self, acts: &PackedActs, w: &PackedWeights) -> Mat {
        let part = RowPartition::from_schemes(&w.scheme);
        self.run_partitioned(acts, w, &part)
    }

    /// Run with a precomputed partition (the executor caches it per layer).
    pub fn run_partitioned(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
    ) -> Mat {
        assert_eq!(acts.cols, w.cols, "inner dims");
        let mut out = Mat::zeros(acts.rows, w.rows);
        let mut col = vec![0.0f32; acts.rows];
        for (core, rows) in [
            (&self.pot4 as &dyn GemmCore, &part.pot4),
            (&self.fixed4, &part.fixed4),
            (&self.fixed8, &part.fixed8),
            (&self.apot4, &part.apot4),
        ] {
            for &r in rows {
                col.iter_mut().for_each(|v| *v = 0.0);
                core.run_row(acts, w, r, &mut col);
                for b in 0..acts.rows {
                    out.set(b, r, col[b]);
                }
            }
        }
        out
    }

    /// Float-path equivalent: fake-quant the operands and matmul. Used by
    /// tests to pin integer == fake-quant and by the runtime comparison
    /// against the AOT HLO artifact.
    pub fn run_float(&self, x: &Mat, w: &Mat, schemes: &[Scheme], alpha: &[f32],
                     act_alpha: f32, act_bits: u32) -> Mat {
        let mut xq = x.clone();
        for v in xq.data.iter_mut() {
            *v = crate::quant::act_quant(*v, act_alpha, act_bits);
        }
        let wq = crate::quant::rowwise_quant(w, alpha, schemes);
        xq.matmul_nt(&wq)
    }
}

/// MAC counts per scheme class for one GEMM — feeds the FPGA cycle model
/// and the GOP/s accounting in Table 6.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MacCounts {
    pub pot4: u64,
    pub fixed4: u64,
    pub fixed8: u64,
    pub apot4: u64,
}

impl MacCounts {
    pub fn of(part: &RowPartition, batch: usize, cols: usize) -> MacCounts {
        let per_row = (batch * cols) as u64;
        MacCounts {
            pot4: part.pot4.len() as u64 * per_row,
            fixed4: part.fixed4.len() as u64 * per_row,
            fixed8: part.fixed8.len() as u64 * per_row,
            apot4: part.apot4.len() as u64 * per_row,
        }
    }

    pub fn total(&self) -> u64 {
        self.pot4 + self.fixed4 + self.fixed8 + self.apot4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::default_alpha;
    use crate::util::rng::Rng;

    fn rand_problem(rows: usize, cols: usize, batch: usize, seed: u64)
        -> (Mat, Mat, Vec<Scheme>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(batch, cols, (0..batch * cols).map(|_| rng.uniform(0.0, 1.2)).collect());
        let w = Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() * 0.5).collect());
        let schemes: Vec<Scheme> = (0..rows)
            .map(|_| match rng.below(4) {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                2 => Scheme::FixedW8A4,
                _ => Scheme::ApotW4A4,
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
        (x, w, schemes, alpha)
    }

    #[test]
    fn integer_equals_fake_quant() {
        let (x, w, schemes, alpha) = rand_problem(17, 29, 5, 7);
        let g = MixedGemm::new();
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let int_out = g.run(&acts, &pw);
        let float_out = g.run_float(&x, &w, &schemes, &alpha, 1.0, 4);
        let err = int_out.max_abs_err(&float_out);
        assert!(err < 1e-3, "int vs fake-quant err {err}");
    }

    #[test]
    fn partition_covers_all_rows() {
        let (_, _, schemes, _) = rand_problem(100, 4, 1, 3);
        let p = RowPartition::from_schemes(&schemes);
        assert_eq!(p.total(), 100);
        let mut all: Vec<usize> =
            [&p.pot4[..], &p.fixed4[..], &p.fixed8[..], &p.apot4[..]].concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mac_accounting() {
        let schemes = vec![Scheme::PotW4A4, Scheme::PotW4A4, Scheme::FixedW4A4];
        let p = RowPartition::from_schemes(&schemes);
        let m = MacCounts::of(&p, 8, 16);
        assert_eq!(m.pot4, 2 * 8 * 16);
        assert_eq!(m.fixed4, 8 * 16);
        assert_eq!(m.total(), 3 * 8 * 16);
    }

    #[test]
    fn empty_batch_ok() {
        let (x, w, schemes, alpha) = rand_problem(4, 8, 1, 1);
        let g = MixedGemm::new();
        let acts = PackedActs::quantize(&Mat::zeros(0, 8), 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let out = g.run(&acts, &pw);
        assert_eq!(out.rows, 0);
        let _ = (x, w); // silence
    }
}
