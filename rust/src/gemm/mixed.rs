//! The row-partitioned mixed GEMM (the paper's core §3 computation).
//!
//! Rows of a layer's weight matrix are grouped by scheme class into a
//! [`RowPartition`]; [`MixedGemm`] dispatches each class to its core —
//! exactly how the FPGA feeds filter classes to the GEMM_PoT-4 /
//! GEMM_Fixed-4 / GEMM_Fixed-8 PE arrays. Because the ratio is layer-wise
//! uniform, the partition shape (and thus per-layer schedule) is identical
//! in every layer.
//!
//! # Parallel execution
//!
//! Row classes are embarrassingly parallel: every output cell `(b, r)` is
//! produced by exactly one weight row `r`. [`MixedGemm::run_partitioned`]
//! therefore splits each class's row list into chunks of
//! `min_rows_per_task` rows, interleaves the chunks round-robin across
//! classes (so cheap PoT shift-add rows and expensive Fixed-8 MAC rows
//! load-balance instead of convoying per class), and drains the task list
//! on the shared [`ThreadPool`] via its work-pulling `scoped_for`. Each
//! task writes a disjoint set of output cells, and per-row arithmetic is
//! identical to the sequential path, so parallel output is bit-exact
//! regardless of thread count or scheduling order.

use std::sync::Arc;

use super::cores::{GemmApot4, GemmCore, GemmFixed4, GemmFixed8, GemmPoT4};
use super::packed::{PackedActs, PackedWeights};
use crate::quant::{Mat, Scheme};
use crate::util::pool::ThreadPool;

/// Row indices grouped by scheme class.
#[derive(Clone, Debug, Default)]
pub struct RowPartition {
    pub pot4: Vec<usize>,
    pub fixed4: Vec<usize>,
    pub fixed8: Vec<usize>,
    pub apot4: Vec<usize>,
}

impl RowPartition {
    pub fn from_schemes(schemes: &[Scheme]) -> RowPartition {
        let mut p = RowPartition::default();
        for (i, s) in schemes.iter().enumerate() {
            match s {
                Scheme::PotW4A4 => p.pot4.push(i),
                Scheme::FixedW4A4 => p.fixed4.push(i),
                Scheme::FixedW8A4 => p.fixed8.push(i),
                Scheme::ApotW4A4 => p.apot4.push(i),
            }
        }
        p
    }

    pub fn total(&self) -> usize {
        self.pot4.len() + self.fixed4.len() + self.fixed8.len() + self.apot4.len()
    }

    /// The row list of one scheme class.
    pub fn class(&self, s: Scheme) -> &[usize] {
        match s {
            Scheme::PotW4A4 => &self.pot4,
            Scheme::FixedW4A4 => &self.fixed4,
            Scheme::FixedW8A4 => &self.fixed8,
            Scheme::ApotW4A4 => &self.apot4,
        }
    }

    /// Per-class fractions `[pot4, fixed4, fixed8, apot4]` — checked
    /// against the configured ratio by the coordinator's admission tests.
    /// All four classes are reported so the fractions sum to 1 whenever
    /// the partition is non-empty (the earlier 3-tuple silently dropped
    /// the APoT share).
    pub fn fractions(&self) -> [f64; 4] {
        let t = self.total().max(1) as f64;
        [
            self.pot4.len() as f64 / t,
            self.fixed4.len() as f64 / t,
            self.fixed8.len() as f64 / t,
            self.apot4.len() as f64 / t,
        ]
    }
}

/// Execution knobs for the parallel mixed GEMM, threaded from the CLI
/// through the runtime, the layer executor, and the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Column-tile width for the packed inner loops (0 = untiled). 256
    /// i8 codes keep a weight-row tile comfortably inside L1 next to the
    /// activation tile.
    pub tile_cols: usize,
    /// Minimum rows per parallel task: the chunk granularity of the
    /// per-class queues (smaller = better balance, more overhead).
    pub min_rows_per_task: usize,
}

impl Default for ParallelConfig {
    fn default() -> ParallelConfig {
        ParallelConfig { threads: 0, tile_cols: 256, min_rows_per_task: 8 }
    }
}

impl ParallelConfig {
    /// Single-threaded config (the seed's behaviour).
    pub fn sequential() -> ParallelConfig {
        ParallelConfig { threads: 1, ..ParallelConfig::default() }
    }

    /// `threads` with 0 resolved to the machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// GEMM scratch lanes an engine built from this config will use:
    /// the calling thread plus every pool worker when a pool is spawned
    /// (>1 resolved thread), else just the caller. Must agree with
    /// [`MixedGemm::lanes`] for a pool of `resolved_threads()` workers —
    /// `rmsmp plan` sizes footprints with this without building an
    /// engine.
    pub fn lanes(&self) -> usize {
        let threads = self.resolved_threads();
        if threads > 1 {
            threads + 1
        } else {
            1
        }
    }
}

/// One schedulable unit of the mixed GEMM: rows `start..end` of one
/// scheme class's row list in a [`RowPartition`]. Chunk lists are
/// compiled once (per layer, by the plan compiler, or per call by the
/// compatibility wrappers) and replayed on every dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskChunk {
    pub scheme: Scheme,
    pub start: usize,
    pub end: usize,
}

/// Build the task list for a partition: per-class row chunks of at most
/// `chunk_rows` rows, interleaved round-robin across the four per-class
/// queues (so cheap PoT shift-add chunks and expensive Fixed-8 MAC chunks
/// alternate in the task list instead of convoying per class).
pub fn chunk_tasks(part: &RowPartition, chunk_rows: usize) -> Vec<TaskChunk> {
    let classes = [
        Scheme::PotW4A4,
        Scheme::FixedW4A4,
        Scheme::FixedW8A4,
        Scheme::ApotW4A4,
    ];
    let chunk = chunk_rows.max(1);
    let mut tasks = Vec::new();
    let mut offset = [0usize; 4];
    loop {
        let mut pushed = false;
        for (i, &scheme) in classes.iter().enumerate() {
            let rows = part.class(scheme);
            let o = offset[i];
            if o < rows.len() {
                let end = rows.len().min(o + chunk);
                tasks.push(TaskChunk { scheme, start: o, end });
                offset[i] = end;
                pushed = true;
            }
        }
        if !pushed {
            return tasks;
        }
    }
}

/// Per-lane reusable row scratch for the GEMM dispatch: a float column
/// (`out` accumulation target of one weight row across the batch) and the
/// i32 accumulator the cores MAC into. One lane per drain loop of the
/// pool's `scoped_for_indexed` (lane 0 = caller, 1..=threads = helpers);
/// preallocating them in the inference [`crate::model::Workspace`] is
/// what makes steady-state dispatch allocation-free.
pub struct GemmScratch {
    lanes: Vec<(Vec<f32>, Vec<i32>)>,
}

impl GemmScratch {
    /// `lanes` empty lanes (grown per dispatch as batches demand).
    pub fn new(lanes: usize) -> GemmScratch {
        GemmScratch::with_capacity(lanes, 0)
    }

    /// `lanes` lanes preallocated for batches up to `batch` rows.
    pub fn with_capacity(lanes: usize, batch: usize) -> GemmScratch {
        GemmScratch {
            lanes: (0..lanes.max(1))
                .map(|_| (Vec::with_capacity(batch), Vec::with_capacity(batch)))
                .collect(),
        }
    }

    /// Resize the first `lanes` lanes to `batch` elements, creating them
    /// if missing; allocation-free when within the preallocated
    /// capacities. Lanes beyond `lanes` are left untouched — the
    /// sequential path only pays for lane 0 even when the engine owns a
    /// wide pool.
    fn ensure(&mut self, lanes: usize, batch: usize) {
        let lanes = lanes.max(1);
        while self.lanes.len() < lanes {
            self.lanes.push((Vec::with_capacity(batch), Vec::with_capacity(batch)));
        }
        for (col, acc) in self.lanes[..lanes].iter_mut() {
            col.resize(batch, 0.0);
            acc.resize(batch, 0);
        }
    }

    /// Lane 0 (the sequential / calling-thread lane), resized to `batch`.
    pub fn lane0(&mut self, batch: usize) -> (&mut [f32], &mut [i32]) {
        self.ensure(1, batch);
        let (col, acc) = &mut self.lanes[0];
        (col, acc)
    }

    /// Data pointers of every lane buffer (steady-state reuse tests pin
    /// these across calls).
    pub fn buffer_ptrs(&self) -> Vec<usize> {
        self.lanes
            .iter()
            .flat_map(|(col, acc)| [col.as_ptr() as usize, acc.as_ptr() as usize])
            .collect()
    }

    /// Bytes currently reserved across all lanes.
    pub fn allocated_bytes(&self) -> usize {
        self.lanes
            .iter()
            .map(|(col, acc)| 4 * col.capacity() + 4 * acc.capacity())
            .sum()
    }
}

/// Raw output pointer shared across GEMM tasks. Each task writes a
/// disjoint set of `(batch, row)` cells — rows are partitioned across
/// tasks — so unsynchronized writes are sound; the pool's join barrier
/// publishes them to the caller.
struct SyncOutPtr {
    p: *mut f32,
}

unsafe impl Send for SyncOutPtr {}
unsafe impl Sync for SyncOutPtr {}

/// Raw pointer to the scratch lanes, shared across GEMM tasks. Lane `i`
/// is only ever touched by the drain loop that `scoped_for_indexed`
/// reports as lane `i`, and those run on distinct threads, so access is
/// exclusive per lane.
struct SyncLanesPtr {
    p: *mut (Vec<f32>, Vec<i32>),
}

unsafe impl Send for SyncLanesPtr {}
unsafe impl Sync for SyncLanesPtr {}

/// The mixed GEMM engine: owns the four cores plus the execution config
/// and (optionally) a thread pool.
pub struct MixedGemm {
    fixed4: GemmFixed4,
    fixed8: GemmFixed8,
    pot4: GemmPoT4,
    apot4: GemmApot4,
    cfg: ParallelConfig,
    pool: Option<Arc<ThreadPool>>,
}

impl Default for MixedGemm {
    fn default() -> Self {
        MixedGemm::with_config(ParallelConfig::sequential())
    }
}

impl MixedGemm {
    /// Sequential engine (no pool) — the drop-in default.
    pub fn new() -> MixedGemm {
        MixedGemm::default()
    }

    /// Engine with its own pool when `cfg` resolves to >1 thread.
    pub fn with_config(cfg: ParallelConfig) -> MixedGemm {
        let threads = cfg.resolved_threads();
        let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        MixedGemm::build(cfg, pool)
    }

    /// Engine sharing an existing pool (one pool per server, shared by
    /// every worker's executor).
    pub fn with_shared_pool(cfg: ParallelConfig, pool: Arc<ThreadPool>) -> MixedGemm {
        MixedGemm::build(cfg, Some(pool))
    }

    fn build(cfg: ParallelConfig, pool: Option<Arc<ThreadPool>>) -> MixedGemm {
        MixedGemm {
            fixed4: GemmFixed4,
            fixed8: GemmFixed8,
            pot4: GemmPoT4,
            apot4: GemmApot4::default(),
            cfg,
            pool,
        }
    }

    pub fn config(&self) -> ParallelConfig {
        self.cfg
    }

    /// Whether a pool is attached (i.e. parallel dispatch is possible).
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// The core owning `scheme`'s rows.
    pub fn core_for(&self, scheme: Scheme) -> &dyn GemmCore {
        match scheme {
            Scheme::PotW4A4 => &self.pot4,
            Scheme::FixedW4A4 => &self.fixed4,
            Scheme::FixedW8A4 => &self.fixed8,
            Scheme::ApotW4A4 => &self.apot4,
        }
    }

    /// `y = Qa(x) @ Qw(w)^T` over integer codes. Output is (batch, rows).
    pub fn run(&self, acts: &PackedActs, w: &PackedWeights) -> Mat {
        let part = RowPartition::from_schemes(&w.scheme);
        self.run_partitioned(acts, w, &part)
    }

    /// Run with a precomputed partition (the executor caches it per
    /// layer), parallel when a pool is attached and the shape is worth it.
    pub fn run_partitioned(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
    ) -> Mat {
        self.run_partitioned_with(acts, w, part, true)
    }

    /// Sequential reference path — bit-exact oracle for the parallel one.
    pub fn run_partitioned_seq(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
    ) -> Mat {
        self.run_partitioned_with(acts, w, part, false)
    }

    /// `parallel = false` forces the sequential path (the coordinator
    /// disables row-level parallelism for batches that already fill the
    /// machine via the batch dimension). Compatibility wrapper around
    /// [`MixedGemm::run_partitioned_into`]: chunks the partition and
    /// allocates the output and scratch per call.
    pub fn run_partitioned_with(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
        parallel: bool,
    ) -> Mat {
        let chunks = chunk_tasks(part, self.cfg.min_rows_per_task);
        let mut scratch = GemmScratch::new(self.lanes());
        let mut out = Mat::zeros(acts.rows, w.rows);
        self.run_partitioned_into(acts, w, part, &chunks, parallel, &mut scratch, &mut out);
        out
    }

    /// Scratch lanes this engine's dispatch can use concurrently: the
    /// calling thread plus every pool worker.
    pub fn lanes(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads() + 1)
    }

    /// The allocation-free dispatch at the bottom of the compiled-plan
    /// path: run the partitioned mixed GEMM over a precompiled `chunks`
    /// schedule (see [`chunk_tasks`]), MACing through caller-provided
    /// `scratch` lanes and writing the caller-provided `out`, which must
    /// already be sized to `(acts.rows, w.rows)`. No heap allocation
    /// happens here once `scratch` has warmed up to the batch size.
    ///
    /// Cells of rows absent from `part` are zeroed; every partitioned row
    /// is written by exactly one chunk, so the result is bit-exact vs the
    /// sequential path for any chunk schedule and thread count.
    pub fn run_partitioned_into(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        part: &RowPartition,
        chunks: &[TaskChunk],
        parallel: bool,
        scratch: &mut GemmScratch,
        out: &mut Mat,
    ) {
        assert_eq!(acts.cols, w.cols, "inner dims");
        assert_eq!((out.rows, out.cols), (acts.rows, w.rows), "output shape");
        let batch = acts.rows;
        let tile = self.cfg.tile_cols;
        // a full partition (each row exactly once — the only shape the
        // plan compiler and `from_schemes` produce) overwrites every
        // cell, so zeroing is only needed for partial partitions
        if part.total() < w.rows {
            out.data.fill(0.0);
        }
        let use_pool = parallel
            && self.pool.is_some()
            && chunks.len() > 1
            && part.total() >= 2 * self.cfg.min_rows_per_task.max(1);

        if !use_pool {
            let (col, acc) = scratch.lane0(batch);
            for chunk in chunks {
                let core = self.core_for(chunk.scheme);
                for &r in &part.class(chunk.scheme)[chunk.start..chunk.end] {
                    col.fill(0.0);
                    core.run_row_tiled(acts, w, r, tile, acc, col);
                    for (b, &v) in col.iter().enumerate() {
                        out.set(b, r, v);
                    }
                }
            }
            return;
        }

        let pool = self.pool.as_ref().expect("use_pool implies a pool");
        scratch.ensure(pool.threads() + 1, batch);
        let out_cols = out.cols;
        let ptr = SyncOutPtr { p: out.data.as_mut_ptr() };
        let lanes = SyncLanesPtr { p: scratch.lanes.as_mut_ptr() };
        pool.scoped_for_indexed(chunks.len(), |ti, lane| {
            let chunk = chunks[ti];
            let core = self.core_for(chunk.scheme);
            // SAFETY: `lane` is exclusive to this drain loop for the
            // duration of the scoped_for (see `scoped_for_indexed`), and
            // `ensure` above sized the lane list to every lane the pool
            // can hand out.
            let (col, acc) = unsafe { &mut *lanes.p.add(lane) };
            for &r in &part.class(chunk.scheme)[chunk.start..chunk.end] {
                col.fill(0.0);
                core.run_row_tiled(acts, w, r, tile, acc, col);
                for (b, &v) in col.iter().enumerate() {
                    // SAFETY: row `r` belongs to exactly one chunk, so no
                    // other task writes cell (b, r); the scoped join
                    // orders these writes before the caller's reads.
                    unsafe { *ptr.p.add(b * out_cols + r) = v };
                }
            }
        });
    }

    /// Single-row dispatch used by the grouped-conv path: `out[b] += ...`
    /// with the engine's tile size. `acc` is i32 scratch (len = batch).
    pub fn run_row_into(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        self.core_for(w.scheme[r]).run_row_tiled(acts, w, r, self.cfg.tile_cols, acc, out);
    }

    /// Float-path equivalent: fake-quant the operands and matmul. Used by
    /// tests to pin integer == fake-quant and by the runtime comparison
    /// against the AOT reference outputs.
    pub fn run_float(
        &self,
        x: &Mat,
        w: &Mat,
        schemes: &[Scheme],
        alpha: &[f32],
        act_alpha: f32,
        act_bits: u32,
    ) -> Mat {
        let mut xq = x.clone();
        for v in xq.data.iter_mut() {
            *v = crate::quant::act_quant(*v, act_alpha, act_bits);
        }
        let wq = crate::quant::rowwise_quant(w, alpha, schemes);
        xq.matmul_nt(&wq)
    }
}

/// MAC counts per scheme class for one GEMM — feeds the FPGA cycle model
/// and the GOP/s accounting in Table 6.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MacCounts {
    pub pot4: u64,
    pub fixed4: u64,
    pub fixed8: u64,
    pub apot4: u64,
}

impl MacCounts {
    pub fn of(part: &RowPartition, batch: usize, cols: usize) -> MacCounts {
        let per_row = (batch * cols) as u64;
        MacCounts {
            pot4: part.pot4.len() as u64 * per_row,
            fixed4: part.fixed4.len() as u64 * per_row,
            fixed8: part.fixed8.len() as u64 * per_row,
            apot4: part.apot4.len() as u64 * per_row,
        }
    }

    pub fn total(&self) -> u64 {
        self.pot4 + self.fixed4 + self.fixed8 + self.apot4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::default_alpha;
    use crate::util::rng::Rng;

    fn rand_problem(
        rows: usize,
        cols: usize,
        batch: usize,
        seed: u64,
    ) -> (Mat, Mat, Vec<Scheme>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.2)).collect();
        let x = Mat::from_vec(batch, cols, xd);
        let wd: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 0.5).collect();
        let w = Mat::from_vec(rows, cols, wd);
        let schemes: Vec<Scheme> = (0..rows)
            .map(|_| match rng.below(4) {
                0 => Scheme::PotW4A4,
                1 => Scheme::FixedW4A4,
                2 => Scheme::FixedW8A4,
                _ => Scheme::ApotW4A4,
            })
            .collect();
        let alpha: Vec<f32> = (0..rows).map(|r| default_alpha(w.row(r))).collect();
        (x, w, schemes, alpha)
    }

    #[test]
    fn integer_equals_fake_quant() {
        let (x, w, schemes, alpha) = rand_problem(17, 29, 5, 7);
        let g = MixedGemm::new();
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let int_out = g.run(&acts, &pw);
        let float_out = g.run_float(&x, &w, &schemes, &alpha, 1.0, 4);
        let err = int_out.max_abs_err(&float_out);
        assert!(err < 1e-3, "int vs fake-quant err {err}");
    }

    #[test]
    fn partition_covers_all_rows() {
        let (_, _, schemes, _) = rand_problem(100, 4, 1, 3);
        let p = RowPartition::from_schemes(&schemes);
        assert_eq!(p.total(), 100);
        let mut all: Vec<usize> =
            [&p.pot4[..], &p.fixed4[..], &p.fixed8[..], &p.apot4[..]].concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fractions_cover_all_four_classes() {
        let schemes = vec![
            Scheme::PotW4A4,
            Scheme::FixedW4A4,
            Scheme::FixedW8A4,
            Scheme::ApotW4A4,
            Scheme::ApotW4A4,
            Scheme::ApotW4A4,
            Scheme::PotW4A4,
            Scheme::PotW4A4,
        ];
        let p = RowPartition::from_schemes(&schemes);
        let f = p.fractions();
        assert_eq!(f, [3.0 / 8.0, 1.0 / 8.0, 1.0 / 8.0, 3.0 / 8.0]);
        // the regression the 3-tuple version had: APoT rows must not make
        // the fractions sum fall short of 1.
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(RowPartition::default().fractions(), [0.0; 4]);
    }

    #[test]
    fn parallel_is_bit_exact_vs_sequential() {
        let (x, w, schemes, alpha) = rand_problem(67, 41, 6, 11);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let part = RowPartition::from_schemes(&schemes);
        let cfg = ParallelConfig { threads: 4, tile_cols: 16, min_rows_per_task: 3 };
        let par = MixedGemm::with_config(cfg);
        let want = par.run_partitioned_seq(&acts, &pw, &part);
        for _ in 0..3 {
            let got = par.run_partitioned(&acts, &pw, &part);
            assert_eq!(got.data, want.data, "parallel output diverged");
        }
    }

    #[test]
    fn chunk_tasks_interleave_and_cover() {
        let schemes = [
            vec![Scheme::PotW4A4; 10],
            vec![Scheme::FixedW4A4; 5],
            vec![Scheme::FixedW8A4; 1],
        ]
        .concat();
        let part = RowPartition::from_schemes(&schemes);
        let tasks = chunk_tasks(&part, 4);
        // chunks: pot 4+4+2, fixed4 4+1, fixed8 1 — interleaved
        assert_eq!(tasks.len(), 6);
        let covered: usize = tasks.iter().map(|t| t.end - t.start).sum();
        assert_eq!(covered, 16);
        // round-robin: first three tasks are one chunk per class
        assert_eq!(tasks[0].scheme, Scheme::PotW4A4);
        assert_eq!(tasks[1].scheme, Scheme::FixedW4A4);
        assert_eq!(tasks[2].scheme, Scheme::FixedW8A4);
        // chunk ranges index into the class row lists and cover them
        assert_eq!((tasks[0].start, tasks[0].end), (0, 4));
        assert_eq!((tasks[5].start, tasks[5].end), (8, 10));
    }

    #[test]
    fn run_partitioned_into_matches_allocating_path() {
        let (x, w, schemes, alpha) = rand_problem(33, 24, 5, 21);
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let part = RowPartition::from_schemes(&schemes);
        let g = MixedGemm::with_config(ParallelConfig {
            threads: 3,
            tile_cols: 16,
            min_rows_per_task: 4,
        });
        let want = g.run_partitioned_seq(&acts, &pw, &part);
        let chunks = chunk_tasks(&part, 4);
        let mut scratch = GemmScratch::with_capacity(g.lanes(), acts.rows);
        let mut out = Mat::zeros(acts.rows, pw.rows);
        for parallel in [false, true] {
            out.data.fill(f32::NAN); // must be fully overwritten
            g.run_partitioned_into(&acts, &pw, &part, &chunks, parallel, &mut scratch, &mut out);
            assert_eq!(out.data, want.data, "parallel={parallel}");
        }
    }

    #[test]
    fn mac_accounting() {
        let schemes = vec![Scheme::PotW4A4, Scheme::PotW4A4, Scheme::FixedW4A4];
        let p = RowPartition::from_schemes(&schemes);
        let m = MacCounts::of(&p, 8, 16);
        assert_eq!(m.pot4, 2 * 8 * 16);
        assert_eq!(m.fixed4, 8 * 16);
        assert_eq!(m.total(), 3 * 8 * 16);
    }

    #[test]
    fn empty_batch_ok() {
        let (x, w, schemes, alpha) = rand_problem(4, 8, 1, 1);
        let g = MixedGemm::new();
        let acts = PackedActs::quantize(&Mat::zeros(0, 8), 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        let out = g.run(&acts, &pw);
        assert_eq!(out.rows, 0);
        let _ = (x, w); // silence
    }
}
