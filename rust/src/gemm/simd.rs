//! Runtime-dispatched SIMD micro-kernels for the integer GEMM cores.
//!
//! The unit of work is a **row block**: up to [`MAX_MICRO_ROWS`] weight
//! rows of one scheme class, dotted against one activation row per
//! call. The multi-row form is what makes the class-sorted layout pay
//! off — one vector-width activation load feeds every weight row of the
//! block, so the activation bandwidth of the inner loop drops by the
//! block height versus the row-at-a-time kernel. The height itself is a
//! **tuned parameter**: fused kernels exist at 4, 6, and 8 rows
//! ([`MICRO_ROWS_CANDIDATES`]) on the register-rich tiers (AVX-512
//! VNNI, AVX2, NEON — 6 or 8 accumulators still fit comfortably), the
//! load-time autotuner picks the winner per layer, and
//! [`MICRO_ROWS`] (4) stays the default that untuned configs run.
//!
//! Five implementations sit behind [`dot_block`] — the ISA ladder:
//!
//! * **AVX-512 VNNI** — `vpdpbusd` over 64 u8xi8 lanes: one instruction
//!   fuses the widen-multiply and the pair sums straight into the i32
//!   accumulators (collapsing the AVX2 tier's `vpmaddubsw`+`vpmaddwd`
//!   pair), with a 32-lane `AVX512VL` step for the 32..63-byte
//!   remainder. Because the accumulation is u8xi8 -> i32 with **no i16
//!   intermediate**, this tier is exact for the full u8 code range —
//!   it is the only vector tier that never falls back to scalar for
//!   activations wider than 7 bits (see [`Isa::wide_code_tier`]).
//! * **AVX2** — `vpmaddubsw` + `vpmaddwd` over 32 u8xi8 lanes, four i32
//!   vector accumulators (one per row), horizontal sum per tile.
//! * **SSE (SSSE3/SSE4.1)** — the same shape over 16 lanes.
//! * **NEON dot-product** (aarch64) — `sdot` over 16 lanes, so one crate
//!   builds natively on Graviton-class boxes. The activation codes are
//!   reinterpreted as i8 (exact for codes `<= 127`, which the
//!   wide-code clamp guarantees on this tier); `udot` is not usable
//!   here because the weight operand is signed.
//! * **Scalar** — the portable fallback, and the oracle the property
//!   tests pin the SIMD paths against.
//!
//! All five accumulate the dot product exactly in i32, so they are
//! **bit-identical** for any vector width, block height, remainder
//! handling, or ISA — integer addition is associative. The numeric
//! caveat is **per 32-bit lane**, not per tier count, so it applies
//! identically to the 4-, 6-, and 8-row variants of a tier: on the
//! `maddubs`-based x86 tiers (AVX2, SSE) the 16-bit intermediate
//! saturates for activation codes above 127, and NEON `sdot` reads the
//! activation byte as signed (wrong for codes above 127). AVX-512 VNNI
//! accumulates u8xi8 straight into i32 with no i16 intermediate, so it
//! is exact for the full u8 range at every block height.
//! [`Isa::wide_code_tier`] encodes exactly that split across the
//! five-tier ladder: `bits > 7` activations reroute AVX2, SSE4.1, and
//! NEON (every `maddubs`/`sdot` tier) to the scalar kernel, while
//! AVX-512 VNNI and scalar keep their own path. This repo quantizes
//! activations to 4 bits by default, so the reroute only triggers for
//! the 8-bit-activation layers.
//!
//! ISA selection is runtime-only (`is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!`), never a compile-time feature, so one
//! binary serves every machine of its architecture and other targets
//! compile straight to the scalar kernel. `RMSMP_ISA=scalar|sse41|avx2|
//! avx512vnni|neon` forces a tier (clamped to the hardware, with a
//! warning for unavailable requests); the legacy `RMSMP_NO_SIMD=1` is a
//! deprecated alias for `RMSMP_ISA=scalar` — the CI legs that pin the
//! portable fallback and each vector tier use exactly these overrides.
//!
//! The validated-ISA token ([`KernelIsa`]) is the hoisted form of what
//! used to be a per-call `Isa::available()` clamp inside [`dot_block`]
//! (an atomic load + branch on every 4-row micro-kernel invocation):
//! the clamp now runs **once**, where the engine resolves its ISA, and
//! the token type proves it to the kernel layer.

/// Default weight rows per micro-kernel block. Four rows keep the vector
/// kernels at four accumulators plus one activation register —
/// comfortably inside 16 ymm / 32 zmm / 32 NEON registers — while
/// quartering activation reloads. The per-layer autotuner may widen a
/// block up to [`MAX_MICRO_ROWS`] where the microbench shows a win.
pub const MICRO_ROWS: usize = 4;

/// The widest row block any kernel accepts (and the height the per-lane
/// GEMM scratch is sized for). Eight accumulators plus one activation
/// register still fit the 16-ymm AVX2 budget and leave the 32-register
/// zmm/NEON files mostly idle.
pub const MAX_MICRO_ROWS: usize = 8;

/// Block heights with a fused multi-row kernel on the register-rich
/// tiers — the candidate set the load-time autotuner sweeps per layer.
/// (SSE4.1 composes 6/8-row blocks from its 4-row kernel: correct, but
/// never faster, so the tuner naturally keeps 4 there.)
pub const MICRO_ROWS_CANDIDATES: [usize; 3] = [4, 6, 8];

/// Instruction-set choice for the integer dot kernels, resolved once per
/// [`crate::gemm::MixedGemm`] (see [`Isa::detect`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    /// 512-bit `vpdpbusd` kernels (x86_64 with AVX-512 F+VL+VNNI);
    /// exact for the full u8 activation range.
    Avx512Vnni,
    /// 256-bit `vpmaddubsw`-based kernels (x86_64 with AVX2).
    Avx2,
    /// 128-bit kernels (x86_64 with SSSE3 + SSE4.1).
    Sse41,
    /// 128-bit `sdot` kernels (aarch64 with the NEON dot-product
    /// extension).
    Neon,
    /// Portable scalar kernels — correct everywhere, and the bit-exact
    /// oracle for the vector paths.
    Scalar,
}

/// Every tier, widest first — the probe order of [`Isa::detect_cpu`]
/// and the iteration order of tests and benches.
pub const ISA_LADDER: [Isa; 5] =
    [Isa::Avx512Vnni, Isa::Avx2, Isa::Sse41, Isa::Neon, Isa::Scalar];

impl Isa {
    /// Pick the ISA this process should use: the `RMSMP_ISA` environment
    /// override wins (clamped to the hardware, warning once on
    /// unavailable or unparseable requests), then the deprecated
    /// `RMSMP_NO_SIMD` alias (any non-empty value other than `"0"`
    /// means `RMSMP_ISA=scalar`), then CPU feature detection.
    pub fn detect() -> Isa {
        if let Ok(v) = std::env::var("RMSMP_ISA") {
            if !v.is_empty() {
                match Isa::parse(&v) {
                    Some(want) => {
                        let got = want.available();
                        if got != want {
                            warn_once(&format!(
                                "rmsmp: RMSMP_ISA={} not available on this CPU, \
                                 using {}",
                                want.name(),
                                got.name()
                            ));
                        }
                        return got;
                    }
                    None => warn_once(&format!(
                        "rmsmp: unknown RMSMP_ISA value {v:?} (expected one of \
                         scalar|sse41|avx2|avx512vnni|neon), using detection"
                    )),
                }
            }
        }
        let disabled = std::env::var("RMSMP_NO_SIMD")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if disabled {
            return Isa::Scalar;
        }
        Isa::detect_cpu()
    }

    /// CPU feature detection only (ignores the environment overrides):
    /// the widest supported tier of [`ISA_LADDER`].
    pub fn detect_cpu() -> Isa {
        for isa in ISA_LADDER {
            if isa.supported() {
                return isa;
            }
        }
        Isa::Scalar
    }

    /// The `RMSMP_ISA` spelling of this tier.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx512Vnni => "avx512vnni",
            Isa::Avx2 => "avx2",
            Isa::Sse41 => "sse41",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        }
    }

    /// Parse an `RMSMP_ISA` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.to_ascii_lowercase().as_str() {
            "avx512vnni" | "vnni" => Some(Isa::Avx512Vnni),
            "avx2" => Some(Isa::Avx2),
            "sse41" | "sse" => Some(Isa::Sse41),
            "neon" | "dotprod" => Some(Isa::Neon),
            "scalar" => Some(Isa::Scalar),
            _ => None,
        }
    }

    /// Whether the running CPU can execute this tier's kernels.
    fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx512Vnni => {
                is_x86_feature_detected!("avx512f")
                    && is_x86_feature_detected!("avx512vl")
                    && is_x86_feature_detected!("avx512vnni")
            }
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            Isa::Sse41 => {
                is_x86_feature_detected!("ssse3") && is_x86_feature_detected!("sse4.1")
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => std::arch::is_aarch64_feature_detected!("dotprod"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Width rank for the clamping tests (scalar narrowest; the x86 and
    /// aarch64 ladders never compete on one machine).
    fn rank(self) -> u8 {
        match self {
            Isa::Scalar => 0,
            Isa::Sse41 => 1,
            Isa::Neon => 2,
            Isa::Avx2 => 3,
            Isa::Avx512Vnni => 4,
        }
    }

    /// `self`, clamped to what this CPU actually supports. Forcing a
    /// tier the hardware lacks (wider, or the wrong architecture)
    /// degrades to the hardware's best — an
    /// [`crate::gemm::MixedGemm::set_isa`] caller can never reach an
    /// illegal-instruction fault.
    pub fn available(self) -> Isa {
        if self.supported() {
            self
        } else {
            Isa::detect_cpu()
        }
    }

    /// The tier that handles activation codes wider than 7 bits: the
    /// `maddubs`-based x86 tiers saturate their i16 intermediate above
    /// code 127 and NEON `sdot` reads the activation byte as signed, so
    /// they degrade to scalar; AVX-512 VNNI accumulates u8xi8 directly
    /// in i32 and keeps the vector path. Pure (no hardware query) —
    /// [`KernelIsa::for_wide_codes`] is the validated form.
    pub fn wide_code_tier(self) -> Isa {
        match self {
            Isa::Avx512Vnni | Isa::Scalar => self,
            Isa::Avx2 | Isa::Sse41 | Isa::Neon => Isa::Scalar,
        }
    }

    /// Validate against the hardware once, yielding the token the kernel
    /// layer trusts (see [`KernelIsa`]).
    pub fn validated(self) -> KernelIsa {
        KernelIsa(self.available())
    }
}

/// A hardware-validated [`Isa`]: the **single resolution point** of the
/// SIMD safety invariant. The only constructor is [`Isa::validated`],
/// which clamps through [`Isa::available`], so every `KernelIsa` in the
/// program names a tier the running CPU supports — [`dot_block`] and the
/// GEMM cores dispatch on it without re-checking CPU features per call
/// (the old per-block `available()` clamp cost an atomic load + branch
/// on every 4-row micro-kernel invocation). [`crate::gemm::MixedGemm`]
/// resolves its token once at construction / [`set_isa`] and passes it
/// through pre-validated.
///
/// [`set_isa`]: crate::gemm::MixedGemm::set_isa
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelIsa(Isa);

impl KernelIsa {
    /// The validated tier.
    pub fn get(self) -> Isa {
        self.0
    }

    /// The validated tier for activation codes wider than 7 bits (see
    /// [`Isa::wide_code_tier`]). Closed over validity: the result is
    /// either `self` or scalar, both supported.
    pub fn for_wide_codes(self) -> KernelIsa {
        KernelIsa(self.0.wide_code_tier())
    }
}

/// `sums[j] = Σ_i a[i] * w[j * stride + i]` for `j in 0..nr` — the block
/// dot product at the bottom of every integer GEMM core. `a` holds
/// unsigned activation codes (callers guarantee `<= 127` on every
/// vector tier except AVX-512 VNNI — see [`KernelIsa::for_wide_codes`]),
/// `w` holds `nr` signed operand rows laid out `stride` apart
/// (`w[j * stride..j * stride + a.len()]` is row `j`). Entries of `sums`
/// beyond `nr` are left untouched.
///
/// Every ISA produces bit-identical results (i32 accumulation is exact);
/// the `isa` token only selects speed, and its type proves the tier was
/// clamped to the hardware at resolution time.
#[inline]
pub fn dot_block(
    isa: KernelIsa,
    a: &[u8],
    w: &[i8],
    stride: usize,
    nr: usize,
    sums: &mut [i32; MAX_MICRO_ROWS],
) {
    debug_assert!(nr >= 1 && nr <= MAX_MICRO_ROWS);
    debug_assert!(nr == 1 || stride >= a.len());
    debug_assert!(w.len() >= (nr - 1) * stride + a.len());
    match isa.get() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: a KernelIsa can only be constructed through
        // Isa::validated(), which clamped the variant to what the
        // runtime CPU feature check allows; slice bounds are asserted.
        Isa::Avx512Vnni => unsafe {
            match nr {
                4 => x86::dot4_vnni(a, w, stride, sums),
                6 => x86::dotn_vnni::<6>(a, w, stride, sums),
                8 => x86::dotn_vnni::<8>(a, w, stride, sums),
                _ => x86::dot_any_vnni(a, w, stride, nr, sums),
            }
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — the token proved AVX2 is present.
        Isa::Avx2 => unsafe {
            match nr {
                4 => x86::dot4_avx2(a, w, stride, sums),
                6 => x86::dotn_avx2::<6>(a, w, stride, sums),
                8 => x86::dotn_avx2::<8>(a, w, stride, sums),
                _ => x86::dot_any_avx2(a, w, stride, nr, sums),
            }
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — the token proved SSSE3/SSE4.1 are present.
        // SSE has no fused 6/8-row kernel (the xmm file is tight):
        // wider blocks compose 4-row kernels + single-row remainders.
        Isa::Sse41 => unsafe {
            if nr == MICRO_ROWS {
                x86::dot4_sse(a, w, stride, sums);
            } else {
                x86::dot_any_sse(a, w, stride, nr, sums);
            }
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above — the token proved the NEON dot-product
        // extension is present. The caller guarantees codes <= 127 on
        // this tier (for_wide_codes), so the i8 reinterpretation of the
        // activation bytes is value-preserving.
        Isa::Neon => unsafe {
            match nr {
                4 => arm::dot4_neon(a, w, stride, sums),
                6 => arm::dotn_neon::<6>(a, w, stride, sums),
                8 => arm::dotn_neon::<8>(a, w, stride, sums),
                _ => arm::dot_any_neon(a, w, stride, nr, sums),
            }
        },
        _ => dot_block_scalar(a, w, stride, nr, sums),
    }
}

/// The portable kernel (also the oracle the SIMD property tests compare
/// against).
fn dot_block_scalar(
    a: &[u8],
    w: &[i8],
    stride: usize,
    nr: usize,
    sums: &mut [i32; MAX_MICRO_ROWS],
) {
    for (j, s) in sums.iter_mut().enumerate().take(nr) {
        let wj = &w[j * stride..j * stride + a.len()];
        let mut t = 0i32;
        for (&x, &c) in a.iter().zip(wj) {
            t += x as i32 * c as i32;
        }
        *s = t;
    }
}

/// Print `msg` to stderr exactly once per process (env-override
/// diagnostics; engines are built per worker, the warning is not).
fn warn_once(msg: &str) {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    if !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!("{msg}");
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MAX_MICRO_ROWS, MICRO_ROWS};
    use std::arch::x86_64::*;

    /// Horizontal sum of the four i32 lanes of `v`. SSE2-only ops, which
    /// x86_64 guarantees statically.
    #[inline]
    unsafe fn hsum_epi32_sse(v: __m128i) -> i32 {
        let hi64 = _mm_unpackhi_epi64(v, v);
        let s = _mm_add_epi32(v, hi64);
        let hi32 = _mm_shuffle_epi32::<0x55>(s);
        _mm_cvtsi128_si32(_mm_add_epi32(s, hi32))
    }

    /// Horizontal sum of the eight i32 lanes of `v`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32_avx2(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        hsum_epi32_sse(_mm_add_epi32(lo, hi))
    }

    /// One 32-lane u8 x i8 dot-product step: widen-multiply adjacent
    /// pairs to i16 (`maddubs`), pair-sum to i32 (`madd` with ones), add
    /// into `acc`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn fma_step_avx2(acc: __m256i, a: __m256i, w: __m256i, ones: __m256i) -> __m256i {
        _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(a, w), ones))
    }

    /// `NR`-row fused AVX2 dot (instantiated at 6 and 8): one activation
    /// load per 32 bytes feeds all `NR` weight rows. The accumulator
    /// array is indexed only by constants after unrolling, so it lives
    /// entirely in ymm registers (8 accumulators + the activation + the
    /// ones constant still fit the 16-register file).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dotn_avx2<const NR: usize>(
        a: &[u8],
        w: &[i8],
        stride: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let n = a.len();
        let ap = a.as_ptr();
        let mut wp = [w.as_ptr(); NR];
        for (j, p) in wp.iter_mut().enumerate() {
            *p = p.add(j * stride);
        }
        let ones = _mm256_set1_epi16(1);
        let mut acc = [_mm256_setzero_si256(); NR];
        let mut i = 0usize;
        while i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            for j in 0..NR {
                acc[j] = fma_step_avx2(
                    acc[j],
                    av,
                    _mm256_loadu_si256(wp[j].add(i) as *const __m256i),
                    ones,
                );
            }
            i += 32;
        }
        let mut s = [0i32; NR];
        for j in 0..NR {
            s[j] = hsum_epi32_avx2(acc[j]);
        }
        while i < n {
            let x = *ap.add(i) as i32;
            for j in 0..NR {
                s[j] += x * *wp[j].add(i) as i32;
            }
            i += 1;
        }
        sums[..NR].copy_from_slice(&s);
    }

    /// Any-height AVX2 block (tails and heights without a fused kernel):
    /// 4-row kernels over full quads, single-row dots for the rest.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_any_avx2(
        a: &[u8],
        w: &[i8],
        stride: usize,
        nr: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let mut j = 0usize;
        while nr - j >= MICRO_ROWS {
            let mut quad = [0i32; MAX_MICRO_ROWS];
            dot4_avx2(a, &w[j * stride..], stride, &mut quad);
            sums[j..j + MICRO_ROWS].copy_from_slice(&quad[..MICRO_ROWS]);
            j += MICRO_ROWS;
        }
        while j < nr {
            sums[j] = dot1_avx2(a, &w[j * stride..j * stride + a.len()]);
            j += 1;
        }
    }

    /// Four-row fused AVX2 dot: one activation load per 32 bytes feeds
    /// all four weight rows.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4_avx2(a: &[u8], w: &[i8], stride: usize, sums: &mut [i32; MAX_MICRO_ROWS]) {
        let n = a.len();
        let ap = a.as_ptr();
        let w0 = w.as_ptr();
        let w1 = w0.add(stride);
        let w2 = w0.add(2 * stride);
        let w3 = w0.add(3 * stride);
        let ones = _mm256_set1_epi16(1);
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            acc0 = fma_step_avx2(acc0, av, _mm256_loadu_si256(w0.add(i) as *const __m256i), ones);
            acc1 = fma_step_avx2(acc1, av, _mm256_loadu_si256(w1.add(i) as *const __m256i), ones);
            acc2 = fma_step_avx2(acc2, av, _mm256_loadu_si256(w2.add(i) as *const __m256i), ones);
            acc3 = fma_step_avx2(acc3, av, _mm256_loadu_si256(w3.add(i) as *const __m256i), ones);
            i += 32;
        }
        let mut s = [
            hsum_epi32_avx2(acc0),
            hsum_epi32_avx2(acc1),
            hsum_epi32_avx2(acc2),
            hsum_epi32_avx2(acc3),
        ];
        while i < n {
            let x = *ap.add(i) as i32;
            s[0] += x * *w0.add(i) as i32;
            s[1] += x * *w1.add(i) as i32;
            s[2] += x * *w2.add(i) as i32;
            s[3] += x * *w3.add(i) as i32;
            i += 1;
        }
        sums[..MICRO_ROWS].copy_from_slice(&s);
    }

    /// Single-row AVX2 dot (block remainders).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot1_avx2(a: &[u8], w: &[i8]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let ones = _mm256_set1_epi16(1);
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let wv = _mm256_loadu_si256(wp.add(i) as *const __m256i);
            acc = fma_step_avx2(acc, av, wv, ones);
            i += 32;
        }
        let mut s = hsum_epi32_avx2(acc);
        while i < n {
            s += *ap.add(i) as i32 * *wp.add(i) as i32;
            i += 1;
        }
        s
    }

    /// `NR`-row fused AVX-512 VNNI dot (instantiated at 6 and 8): the
    /// same `vpdpbusd` shape as [`dot4_vnni`] with `NR` zmm accumulators
    /// — 9 of the 32 zmm registers at the widest block, so register
    /// pressure never forces a spill. 64-byte main loop, one 32-byte
    /// `AVX512VL` step for the wide remainder, scalar below that.
    #[target_feature(enable = "avx512f,avx512vl,avx512vnni")]
    pub unsafe fn dotn_vnni<const NR: usize>(
        a: &[u8],
        w: &[i8],
        stride: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let n = a.len();
        let ap = a.as_ptr();
        let mut wp = [w.as_ptr(); NR];
        for (j, p) in wp.iter_mut().enumerate() {
            *p = p.add(j * stride);
        }
        let mut acc = [_mm512_setzero_si512(); NR];
        let mut i = 0usize;
        while i + 64 <= n {
            let av = _mm512_loadu_si512(ap.add(i) as *const _);
            for j in 0..NR {
                acc[j] = _mm512_dpbusd_epi32(
                    acc[j],
                    av,
                    _mm512_loadu_si512(wp[j].add(i) as *const _),
                );
            }
            i += 64;
        }
        let mut s = [0i32; NR];
        for j in 0..NR {
            s[j] = _mm512_reduce_add_epi32(acc[j]);
        }
        if i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let z = _mm256_setzero_si256();
            for j in 0..NR {
                let d = _mm256_dpbusd_epi32(
                    z,
                    av,
                    _mm256_loadu_si256(wp[j].add(i) as *const __m256i),
                );
                s[j] += hsum_epi32_avx2(d);
            }
            i += 32;
        }
        while i < n {
            let x = *ap.add(i) as i32;
            for j in 0..NR {
                s[j] += x * *wp[j].add(i) as i32;
            }
            i += 1;
        }
        sums[..NR].copy_from_slice(&s);
    }

    /// Any-height AVX-512 VNNI block (tails and heights without a fused
    /// kernel): 4-row kernels over full quads, single-row dots after.
    #[target_feature(enable = "avx512f,avx512vl,avx512vnni")]
    pub unsafe fn dot_any_vnni(
        a: &[u8],
        w: &[i8],
        stride: usize,
        nr: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let mut j = 0usize;
        while nr - j >= MICRO_ROWS {
            let mut quad = [0i32; MAX_MICRO_ROWS];
            dot4_vnni(a, &w[j * stride..], stride, &mut quad);
            sums[j..j + MICRO_ROWS].copy_from_slice(&quad[..MICRO_ROWS]);
            j += MICRO_ROWS;
        }
        while j < nr {
            sums[j] = dot1_vnni(a, &w[j * stride..j * stride + a.len()]);
            j += 1;
        }
    }

    /// Four-row fused AVX-512 VNNI dot: `vpdpbusd` accumulates each
    /// 4-byte u8xi8 group straight into an i32 lane — no i16
    /// intermediate, so no saturation for any u8 code. 64-byte main
    /// loop, one 32-byte `AVX512VL` step for the wide remainder, scalar
    /// below that.
    #[target_feature(enable = "avx512f,avx512vl,avx512vnni")]
    pub unsafe fn dot4_vnni(a: &[u8], w: &[i8], stride: usize, sums: &mut [i32; MAX_MICRO_ROWS]) {
        let n = a.len();
        let ap = a.as_ptr();
        let w0 = w.as_ptr();
        let w1 = w0.add(stride);
        let w2 = w0.add(2 * stride);
        let w3 = w0.add(3 * stride);
        let mut acc0 = _mm512_setzero_si512();
        let mut acc1 = _mm512_setzero_si512();
        let mut acc2 = _mm512_setzero_si512();
        let mut acc3 = _mm512_setzero_si512();
        let mut i = 0usize;
        while i + 64 <= n {
            let av = _mm512_loadu_si512(ap.add(i) as *const _);
            acc0 = _mm512_dpbusd_epi32(acc0, av, _mm512_loadu_si512(w0.add(i) as *const _));
            acc1 = _mm512_dpbusd_epi32(acc1, av, _mm512_loadu_si512(w1.add(i) as *const _));
            acc2 = _mm512_dpbusd_epi32(acc2, av, _mm512_loadu_si512(w2.add(i) as *const _));
            acc3 = _mm512_dpbusd_epi32(acc3, av, _mm512_loadu_si512(w3.add(i) as *const _));
            i += 64;
        }
        let mut s = [
            _mm512_reduce_add_epi32(acc0),
            _mm512_reduce_add_epi32(acc1),
            _mm512_reduce_add_epi32(acc2),
            _mm512_reduce_add_epi32(acc3),
        ];
        if i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let z = _mm256_setzero_si256();
            let d0 =
                _mm256_dpbusd_epi32(z, av, _mm256_loadu_si256(w0.add(i) as *const __m256i));
            let d1 =
                _mm256_dpbusd_epi32(z, av, _mm256_loadu_si256(w1.add(i) as *const __m256i));
            let d2 =
                _mm256_dpbusd_epi32(z, av, _mm256_loadu_si256(w2.add(i) as *const __m256i));
            let d3 =
                _mm256_dpbusd_epi32(z, av, _mm256_loadu_si256(w3.add(i) as *const __m256i));
            s[0] += hsum_epi32_avx2(d0);
            s[1] += hsum_epi32_avx2(d1);
            s[2] += hsum_epi32_avx2(d2);
            s[3] += hsum_epi32_avx2(d3);
            i += 32;
        }
        while i < n {
            let x = *ap.add(i) as i32;
            s[0] += x * *w0.add(i) as i32;
            s[1] += x * *w1.add(i) as i32;
            s[2] += x * *w2.add(i) as i32;
            s[3] += x * *w3.add(i) as i32;
            i += 1;
        }
        sums[..MICRO_ROWS].copy_from_slice(&s);
    }

    /// Single-row AVX-512 VNNI dot (block remainders).
    #[target_feature(enable = "avx512f,avx512vl,avx512vnni")]
    pub unsafe fn dot1_vnni(a: &[u8], w: &[i8]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let mut acc = _mm512_setzero_si512();
        let mut i = 0usize;
        while i + 64 <= n {
            let av = _mm512_loadu_si512(ap.add(i) as *const _);
            let wv = _mm512_loadu_si512(wp.add(i) as *const _);
            acc = _mm512_dpbusd_epi32(acc, av, wv);
            i += 64;
        }
        let mut s = _mm512_reduce_add_epi32(acc);
        if i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let wv = _mm256_loadu_si256(wp.add(i) as *const __m256i);
            s += hsum_epi32_avx2(_mm256_dpbusd_epi32(_mm256_setzero_si256(), av, wv));
            i += 32;
        }
        while i < n {
            s += *ap.add(i) as i32 * *wp.add(i) as i32;
            i += 1;
        }
        s
    }

    /// One 16-lane u8 x i8 dot-product step (SSSE3 `maddubs` + SSE2
    /// `madd`).
    #[inline]
    #[target_feature(enable = "ssse3,sse4.1")]
    unsafe fn fma_step_sse(acc: __m128i, a: __m128i, w: __m128i, ones: __m128i) -> __m128i {
        _mm_add_epi32(acc, _mm_madd_epi16(_mm_maddubs_epi16(a, w), ones))
    }

    /// Any-height SSE block: the 16-xmm file has no room for a fused
    /// 6/8-row variant, so wider blocks (and tails) compose the 4-row
    /// kernel over full quads plus single-row dots — bit-identical,
    /// just not faster, which is why the autotuner keeps 4 on this tier.
    #[target_feature(enable = "ssse3,sse4.1")]
    pub unsafe fn dot_any_sse(
        a: &[u8],
        w: &[i8],
        stride: usize,
        nr: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let mut j = 0usize;
        while nr - j >= MICRO_ROWS {
            let mut quad = [0i32; MAX_MICRO_ROWS];
            dot4_sse(a, &w[j * stride..], stride, &mut quad);
            sums[j..j + MICRO_ROWS].copy_from_slice(&quad[..MICRO_ROWS]);
            j += MICRO_ROWS;
        }
        while j < nr {
            sums[j] = dot1_sse(a, &w[j * stride..j * stride + a.len()]);
            j += 1;
        }
    }

    /// Four-row fused SSE dot.
    #[target_feature(enable = "ssse3,sse4.1")]
    pub unsafe fn dot4_sse(a: &[u8], w: &[i8], stride: usize, sums: &mut [i32; MAX_MICRO_ROWS]) {
        let n = a.len();
        let ap = a.as_ptr();
        let w0 = w.as_ptr();
        let w1 = w0.add(stride);
        let w2 = w0.add(2 * stride);
        let w3 = w0.add(3 * stride);
        let ones = _mm_set1_epi16(1);
        let mut acc0 = _mm_setzero_si128();
        let mut acc1 = _mm_setzero_si128();
        let mut acc2 = _mm_setzero_si128();
        let mut acc3 = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 16 <= n {
            let av = _mm_loadu_si128(ap.add(i) as *const __m128i);
            acc0 = fma_step_sse(acc0, av, _mm_loadu_si128(w0.add(i) as *const __m128i), ones);
            acc1 = fma_step_sse(acc1, av, _mm_loadu_si128(w1.add(i) as *const __m128i), ones);
            acc2 = fma_step_sse(acc2, av, _mm_loadu_si128(w2.add(i) as *const __m128i), ones);
            acc3 = fma_step_sse(acc3, av, _mm_loadu_si128(w3.add(i) as *const __m128i), ones);
            i += 16;
        }
        let mut s = [
            hsum_epi32_sse(acc0),
            hsum_epi32_sse(acc1),
            hsum_epi32_sse(acc2),
            hsum_epi32_sse(acc3),
        ];
        while i < n {
            let x = *ap.add(i) as i32;
            s[0] += x * *w0.add(i) as i32;
            s[1] += x * *w1.add(i) as i32;
            s[2] += x * *w2.add(i) as i32;
            s[3] += x * *w3.add(i) as i32;
            i += 1;
        }
        sums[..MICRO_ROWS].copy_from_slice(&s);
    }

    /// Single-row SSE dot (block remainders).
    #[target_feature(enable = "ssse3,sse4.1")]
    pub unsafe fn dot1_sse(a: &[u8], w: &[i8]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let ones = _mm_set1_epi16(1);
        let mut acc = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 16 <= n {
            let av = _mm_loadu_si128(ap.add(i) as *const __m128i);
            let wv = _mm_loadu_si128(wp.add(i) as *const __m128i);
            acc = fma_step_sse(acc, av, wv, ones);
            i += 16;
        }
        let mut s = hsum_epi32_sse(acc);
        while i < n {
            s += *ap.add(i) as i32 * *wp.add(i) as i32;
            i += 1;
        }
        s
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{MAX_MICRO_ROWS, MICRO_ROWS};
    use std::arch::aarch64::*;

    /// `NR`-row fused NEON `sdot` (instantiated at 6 and 8): aarch64's
    /// 32-register vector file takes 8 accumulators plus the activation
    /// vector without spilling. Same u8 -> i8 reinterpretation contract
    /// as [`dot4_neon`].
    #[target_feature(enable = "neon,dotprod")]
    pub unsafe fn dotn_neon<const NR: usize>(
        a: &[u8],
        w: &[i8],
        stride: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let n = a.len();
        let ap = a.as_ptr();
        let mut wp = [w.as_ptr(); NR];
        for (j, p) in wp.iter_mut().enumerate() {
            *p = p.add(j * stride);
        }
        let mut acc = [vdupq_n_s32(0); NR];
        let mut i = 0usize;
        while i + 16 <= n {
            let av = vreinterpretq_s8_u8(vld1q_u8(ap.add(i)));
            for j in 0..NR {
                acc[j] = vdotq_s32(acc[j], av, vld1q_s8(wp[j].add(i)));
            }
            i += 16;
        }
        let mut s = [0i32; NR];
        for j in 0..NR {
            s[j] = vaddvq_s32(acc[j]);
        }
        while i < n {
            let x = *ap.add(i) as i32;
            for j in 0..NR {
                s[j] += x * *wp[j].add(i) as i32;
            }
            i += 1;
        }
        sums[..NR].copy_from_slice(&s);
    }

    /// Any-height NEON block (tails and heights without a fused kernel):
    /// 4-row kernels over full quads, single-row dots for the rest.
    #[target_feature(enable = "neon,dotprod")]
    pub unsafe fn dot_any_neon(
        a: &[u8],
        w: &[i8],
        stride: usize,
        nr: usize,
        sums: &mut [i32; MAX_MICRO_ROWS],
    ) {
        let mut j = 0usize;
        while nr - j >= MICRO_ROWS {
            let mut quad = [0i32; MAX_MICRO_ROWS];
            dot4_neon(a, &w[j * stride..], stride, &mut quad);
            sums[j..j + MICRO_ROWS].copy_from_slice(&quad[..MICRO_ROWS]);
            j += MICRO_ROWS;
        }
        while j < nr {
            sums[j] = dot1_neon(a, &w[j * stride..j * stride + a.len()]);
            j += 1;
        }
    }

    /// Four-row fused NEON `sdot`: each instruction accumulates four
    /// 4-byte i8xi8 groups into the i32 lanes of `acc` — exact, like
    /// VNNI. The activation bytes are reinterpreted u8 -> i8, which is
    /// value-preserving because callers guarantee codes `<= 127` on
    /// this tier (see [`super::Isa::wide_code_tier`]).
    #[target_feature(enable = "neon,dotprod")]
    pub unsafe fn dot4_neon(a: &[u8], w: &[i8], stride: usize, sums: &mut [i32; MAX_MICRO_ROWS]) {
        let n = a.len();
        let ap = a.as_ptr();
        let w0 = w.as_ptr();
        let w1 = w0.add(stride);
        let w2 = w0.add(2 * stride);
        let w3 = w0.add(3 * stride);
        let mut acc0 = vdupq_n_s32(0);
        let mut acc1 = vdupq_n_s32(0);
        let mut acc2 = vdupq_n_s32(0);
        let mut acc3 = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 16 <= n {
            let av = vreinterpretq_s8_u8(vld1q_u8(ap.add(i)));
            acc0 = vdotq_s32(acc0, av, vld1q_s8(w0.add(i)));
            acc1 = vdotq_s32(acc1, av, vld1q_s8(w1.add(i)));
            acc2 = vdotq_s32(acc2, av, vld1q_s8(w2.add(i)));
            acc3 = vdotq_s32(acc3, av, vld1q_s8(w3.add(i)));
            i += 16;
        }
        let mut s = [
            vaddvq_s32(acc0),
            vaddvq_s32(acc1),
            vaddvq_s32(acc2),
            vaddvq_s32(acc3),
        ];
        while i < n {
            let x = *ap.add(i) as i32;
            s[0] += x * *w0.add(i) as i32;
            s[1] += x * *w1.add(i) as i32;
            s[2] += x * *w2.add(i) as i32;
            s[3] += x * *w3.add(i) as i32;
            i += 1;
        }
        sums[..MICRO_ROWS].copy_from_slice(&s);
    }

    /// Single-row NEON `sdot` (block remainders).
    #[target_feature(enable = "neon,dotprod")]
    pub unsafe fn dot1_neon(a: &[u8], w: &[i8]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let mut acc = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 16 <= n {
            let av = vreinterpretq_s8_u8(vld1q_u8(ap.add(i)));
            acc = vdotq_s32(acc, av, vld1q_s8(wp.add(i)));
            i += 16;
        }
        let mut s = vaddvq_s32(acc);
        while i < n {
            s += *ap.add(i) as i32 * *wp.add(i) as i32;
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Vec<u8>, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let a: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
        let w: Vec<i8> = (0..MAX_MICRO_ROWS * n)
            .map(|_| (rng.below(256) as i64 - 128) as i8)
            .collect();
        (a, w)
    }

    #[test]
    fn all_isas_agree_with_scalar_at_awkward_lengths() {
        // lengths straddling the 16-, 32-, and 64-lane widths, incl. 0;
        // every block height 1..=8 covers the fused 4/6/8-row kernels
        // and the composed tails between them
        for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 95, 97, 127, 129, 257] {
            let (a, w) = problem(n, 11 + n as u64);
            for nr in 1..=MAX_MICRO_ROWS {
                let mut want = [i32::MIN; MAX_MICRO_ROWS];
                dot_block_scalar(&a, &w, n, nr, &mut want);
                for isa in ISA_LADDER {
                    // hosts without a tier degrade it to the hardware's
                    // best — still a valid (and covered) tier
                    let isa = isa.validated();
                    let mut got = [i32::MIN; MAX_MICRO_ROWS];
                    dot_block(isa, &a, &w, n, nr, &mut got);
                    assert_eq!(got[..nr], want[..nr], "isa {isa:?} n {n} nr {nr}");
                    // lanes beyond nr stay untouched
                    assert!(got[nr..].iter().all(|&v| v == i32::MIN));
                }
            }
        }
    }

    #[test]
    fn saturation_boundary_codes_are_exact_on_every_tier() {
        // codes <= 127 never saturate the i16 intermediate: the extreme
        // pair 127*(-128) + 127*(-128) = -32512 fits i16. Every tier
        // must agree at the boundary — at each fused block height (4,
        // 6, 8 instantiate separate kernels per tier) and the
        // single-row remainder kernel.
        let heights: Vec<usize> =
            std::iter::once(1).chain(MICRO_ROWS_CANDIDATES).collect();
        for &nr in &heights {
            let a = vec![127u8; 34];
            let w = vec![-128i8; nr * 34];
            let mut want = [0i32; MAX_MICRO_ROWS];
            dot_block_scalar(&a, &w, 34, nr, &mut want);
            assert!(want[..nr].iter().all(|&v| v == 34 * 127 * -128));
            for isa in ISA_LADDER {
                let mut got = [0i32; MAX_MICRO_ROWS];
                dot_block(isa.validated(), &a, &w, 34, nr, &mut got);
                assert_eq!(got[..nr], want[..nr], "isa {isa:?} nr {nr}");
            }
        }
    }

    #[test]
    fn full_u8_codes_are_exact_on_wide_code_tiers() {
        // codes above 127 (8-bit activations) would saturate maddubs and
        // flip sign under sdot; the wide-code tiers (scalar, and VNNI
        // where the hardware has it) must be exact anyway — at every
        // block height, since the 6/8-row VNNI kernels share the same
        // vpdpbusd lane arithmetic. 255 * -128 pairs are the worst case.
        let mut rng = Rng::new(99);
        for n in [1usize, 16, 33, 64, 65, 257] {
            let a: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let w: Vec<i8> = (0..MAX_MICRO_ROWS * n)
                .map(|_| (rng.below(256) as i64 - 128) as i8)
                .collect();
            for nr in 1..=MAX_MICRO_ROWS {
                let mut want = [0i32; MAX_MICRO_ROWS];
                dot_block_scalar(&a, &w, n, nr, &mut want);
                for isa in ISA_LADDER {
                    let isa = isa.validated().for_wide_codes();
                    let mut got = [0i32; MAX_MICRO_ROWS];
                    dot_block(isa, &a, &w, n, nr, &mut got);
                    assert_eq!(got[..nr], want[..nr], "isa {isa:?} n {n} nr {nr}");
                }
            }
        }
    }

    #[test]
    fn wide_code_tier_keeps_vnni_and_scalar_only() {
        // the bits > 7 routing is pure and total: VNNI keeps its vector
        // path (i32-exact vpdpbusd), every narrower vector tier drops to
        // scalar
        assert_eq!(Isa::Avx512Vnni.wide_code_tier(), Isa::Avx512Vnni);
        assert_eq!(Isa::Scalar.wide_code_tier(), Isa::Scalar);
        assert_eq!(Isa::Avx2.wide_code_tier(), Isa::Scalar);
        assert_eq!(Isa::Sse41.wide_code_tier(), Isa::Scalar);
        assert_eq!(Isa::Neon.wide_code_tier(), Isa::Scalar);
    }

    #[test]
    fn available_clamps_to_hardware() {
        let hw = Isa::detect_cpu();
        assert_eq!(Isa::Scalar.available(), Isa::Scalar);
        assert!(Isa::Avx2.available().rank() <= hw.rank());
        assert!(Isa::Avx512Vnni.available().rank() <= hw.rank());
        assert_eq!(hw.available(), hw);
        // a cross-architecture request degrades to this machine's best,
        // never to an unsupported tier
        #[cfg(target_arch = "x86_64")]
        assert_eq!(Isa::Neon.available(), hw);
        #[cfg(target_arch = "aarch64")]
        assert_eq!(Isa::Avx512Vnni.available(), hw);
        // the validated token round-trips the clamp
        assert_eq!(Isa::Avx512Vnni.validated().get(), Isa::Avx512Vnni.available());
    }

    #[test]
    fn isa_names_round_trip() {
        for isa in ISA_LADDER {
            assert_eq!(Isa::parse(isa.name()), Some(isa), "{isa:?}");
        }
        assert_eq!(Isa::parse("AVX2"), Some(Isa::Avx2));
        assert_eq!(Isa::parse("nope"), None);
    }
}
