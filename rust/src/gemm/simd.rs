//! Runtime-dispatched SIMD micro-kernels for the integer GEMM cores.
//!
//! The unit of work is a **row block**: up to [`MICRO_ROWS`] weight rows
//! of one scheme class, dotted against one activation row per call. The
//! multi-row form is what makes the class-sorted layout pay off — one
//! 32-byte activation load feeds four weight rows, so the activation
//! bandwidth of the inner loop drops 4x versus the row-at-a-time kernel.
//!
//! Three implementations sit behind [`dot_block`]:
//!
//! * **AVX2** — `vpmaddubsw` + `vpmaddwd` over 32 u8xI8 lanes, four i32
//!   vector accumulators (one per row), horizontal sum per tile.
//! * **SSE (SSSE3/SSE4.1)** — the same shape over 16 lanes.
//! * **Scalar** — the portable fallback, and the oracle the property
//!   tests pin the SIMD paths against.
//!
//! All three accumulate the dot product exactly in i32, so they are
//! **bit-identical** for any vector width, remainder handling, or ISA —
//! integer addition is associative. The only numeric caveat is the
//! 16-bit intermediate of `maddubs`: a pair sum `a0*w0 + a1*w1` with
//! `a <= 2^bits - 1`, `|w| <= 128` saturates only for activation codes
//! above 127, so callers route `bits > 7` activations to the scalar
//! kernel (this repo quantizes activations to 4 bits; the headroom is
//! ~8.5x).
//!
//! ISA selection is runtime-only (`is_x86_feature_detected!`), never a
//! compile-time feature, so one binary serves every x86_64 machine and
//! non-x86 targets compile straight to the scalar kernel. Setting
//! `RMSMP_NO_SIMD=1` forces the scalar kernel everywhere — the CI leg
//! that keeps the portable fallback green uses exactly this override.

/// Weight rows per micro-kernel block. Four rows keep the AVX2 kernel at
/// four vector accumulators plus one activation register — comfortably
/// inside the 16 ymm registers — while quartering activation reloads.
pub const MICRO_ROWS: usize = 4;

/// Instruction-set choice for the integer dot kernels, resolved once per
/// [`crate::gemm::MixedGemm`] (see [`Isa::detect`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// 256-bit `vpmaddubsw`-based kernels (x86_64 with AVX2).
    Avx2,
    /// 128-bit kernels (x86_64 with SSSE3 + SSE4.1).
    Sse41,
    /// Portable scalar kernels — correct everywhere, and the bit-exact
    /// oracle for the vector paths.
    Scalar,
}

impl Isa {
    /// Pick the widest ISA this process should use: the `RMSMP_NO_SIMD`
    /// environment override (any non-empty value other than `"0"`) wins,
    /// then CPU feature detection, else scalar.
    pub fn detect() -> Isa {
        let disabled = std::env::var("RMSMP_NO_SIMD")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        if disabled {
            return Isa::Scalar;
        }
        Isa::detect_cpu()
    }

    /// CPU feature detection only (ignores the environment override).
    pub fn detect_cpu() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
            if is_x86_feature_detected!("ssse3") && is_x86_feature_detected!("sse4.1") {
                return Isa::Sse41;
            }
        }
        Isa::Scalar
    }

    /// Width rank for clamping (scalar < sse < avx2).
    fn rank(self) -> u8 {
        match self {
            Isa::Scalar => 0,
            Isa::Sse41 => 1,
            Isa::Avx2 => 2,
        }
    }

    /// `self`, clamped to what this CPU actually supports. Forcing a
    /// wider ISA than the hardware has degrades to the hardware's best —
    /// an [`crate::gemm::MixedGemm::set_isa`] caller can never reach an
    /// illegal-instruction fault.
    pub fn available(self) -> Isa {
        let hw = Isa::detect_cpu();
        if self.rank() <= hw.rank() {
            self
        } else {
            hw
        }
    }
}

/// `sums[j] = Σ_i a[i] * w[j * stride + i]` for `j in 0..nr` — the block
/// dot product at the bottom of every integer GEMM core. `a` holds
/// unsigned activation codes (callers guarantee `<= 127` on the SIMD
/// paths), `w` holds `nr` signed operand rows laid out `stride` apart
/// (`w[j * stride..j * stride + a.len()]` is row `j`). Entries of `sums`
/// beyond `nr` are left untouched.
///
/// Every ISA produces bit-identical results (i32 accumulation is exact);
/// the `isa` argument only selects speed.
#[inline]
pub fn dot_block(
    isa: Isa,
    a: &[u8],
    w: &[i8],
    stride: usize,
    nr: usize,
    sums: &mut [i32; MICRO_ROWS],
) {
    debug_assert!(nr >= 1 && nr <= MICRO_ROWS);
    debug_assert!(nr == 1 || stride >= a.len());
    debug_assert!(w.len() >= (nr - 1) * stride + a.len());
    // Clamp to the hardware so a caller-constructed Isa::Avx2 can never
    // execute AVX2 code on a CPU without it (std's feature detection is
    // cached, so this is an atomic load + bit test).
    let isa = isa.available();
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `available()` above clamped the variant to what the
        // runtime CPU feature check allows; slice bounds are asserted.
        Isa::Avx2 => unsafe {
            if nr == MICRO_ROWS {
                x86::dot4_avx2(a, w, stride, sums);
            } else {
                for (j, s) in sums.iter_mut().enumerate().take(nr) {
                    *s = x86::dot1_avx2(a, &w[j * stride..j * stride + a.len()]);
                }
            }
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — the clamp proved SSSE3/SSE4.1 are present.
        Isa::Sse41 => unsafe {
            if nr == MICRO_ROWS {
                x86::dot4_sse(a, w, stride, sums);
            } else {
                for (j, s) in sums.iter_mut().enumerate().take(nr) {
                    *s = x86::dot1_sse(a, &w[j * stride..j * stride + a.len()]);
                }
            }
        },
        _ => dot_block_scalar(a, w, stride, nr, sums),
    }
}

/// The portable kernel (also the oracle the SIMD property tests compare
/// against).
fn dot_block_scalar(a: &[u8], w: &[i8], stride: usize, nr: usize, sums: &mut [i32; MICRO_ROWS]) {
    for (j, s) in sums.iter_mut().enumerate().take(nr) {
        let wj = &w[j * stride..j * stride + a.len()];
        let mut t = 0i32;
        for (&x, &c) in a.iter().zip(wj) {
            t += x as i32 * c as i32;
        }
        *s = t;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::MICRO_ROWS;
    use std::arch::x86_64::*;

    /// Horizontal sum of the four i32 lanes of `v`. SSE2-only ops, which
    /// x86_64 guarantees statically.
    #[inline]
    unsafe fn hsum_epi32_sse(v: __m128i) -> i32 {
        let hi64 = _mm_unpackhi_epi64(v, v);
        let s = _mm_add_epi32(v, hi64);
        let hi32 = _mm_shuffle_epi32::<0x55>(s);
        _mm_cvtsi128_si32(_mm_add_epi32(s, hi32))
    }

    /// Horizontal sum of the eight i32 lanes of `v`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32_avx2(v: __m256i) -> i32 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        hsum_epi32_sse(_mm_add_epi32(lo, hi))
    }

    /// One 32-lane u8 x i8 dot-product step: widen-multiply adjacent
    /// pairs to i16 (`maddubs`), pair-sum to i32 (`madd` with ones), add
    /// into `acc`.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn fma_step_avx2(acc: __m256i, a: __m256i, w: __m256i, ones: __m256i) -> __m256i {
        _mm256_add_epi32(acc, _mm256_madd_epi16(_mm256_maddubs_epi16(a, w), ones))
    }

    /// Four-row fused AVX2 dot: one activation load per 32 bytes feeds
    /// all four weight rows.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot4_avx2(a: &[u8], w: &[i8], stride: usize, sums: &mut [i32; MICRO_ROWS]) {
        let n = a.len();
        let ap = a.as_ptr();
        let w0 = w.as_ptr();
        let w1 = w0.add(stride);
        let w2 = w0.add(2 * stride);
        let w3 = w0.add(3 * stride);
        let ones = _mm256_set1_epi16(1);
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            acc0 = fma_step_avx2(acc0, av, _mm256_loadu_si256(w0.add(i) as *const __m256i), ones);
            acc1 = fma_step_avx2(acc1, av, _mm256_loadu_si256(w1.add(i) as *const __m256i), ones);
            acc2 = fma_step_avx2(acc2, av, _mm256_loadu_si256(w2.add(i) as *const __m256i), ones);
            acc3 = fma_step_avx2(acc3, av, _mm256_loadu_si256(w3.add(i) as *const __m256i), ones);
            i += 32;
        }
        let mut s = [
            hsum_epi32_avx2(acc0),
            hsum_epi32_avx2(acc1),
            hsum_epi32_avx2(acc2),
            hsum_epi32_avx2(acc3),
        ];
        while i < n {
            let x = *ap.add(i) as i32;
            s[0] += x * *w0.add(i) as i32;
            s[1] += x * *w1.add(i) as i32;
            s[2] += x * *w2.add(i) as i32;
            s[3] += x * *w3.add(i) as i32;
            i += 1;
        }
        *sums = s;
    }

    /// Single-row AVX2 dot (block remainders).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot1_avx2(a: &[u8], w: &[i8]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let ones = _mm256_set1_epi16(1);
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let wv = _mm256_loadu_si256(wp.add(i) as *const __m256i);
            acc = fma_step_avx2(acc, av, wv, ones);
            i += 32;
        }
        let mut s = hsum_epi32_avx2(acc);
        while i < n {
            s += *ap.add(i) as i32 * *wp.add(i) as i32;
            i += 1;
        }
        s
    }

    /// One 16-lane u8 x i8 dot-product step (SSSE3 `maddubs` + SSE2
    /// `madd`).
    #[inline]
    #[target_feature(enable = "ssse3,sse4.1")]
    unsafe fn fma_step_sse(acc: __m128i, a: __m128i, w: __m128i, ones: __m128i) -> __m128i {
        _mm_add_epi32(acc, _mm_madd_epi16(_mm_maddubs_epi16(a, w), ones))
    }

    /// Four-row fused SSE dot.
    #[target_feature(enable = "ssse3,sse4.1")]
    pub unsafe fn dot4_sse(a: &[u8], w: &[i8], stride: usize, sums: &mut [i32; MICRO_ROWS]) {
        let n = a.len();
        let ap = a.as_ptr();
        let w0 = w.as_ptr();
        let w1 = w0.add(stride);
        let w2 = w0.add(2 * stride);
        let w3 = w0.add(3 * stride);
        let ones = _mm_set1_epi16(1);
        let mut acc0 = _mm_setzero_si128();
        let mut acc1 = _mm_setzero_si128();
        let mut acc2 = _mm_setzero_si128();
        let mut acc3 = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 16 <= n {
            let av = _mm_loadu_si128(ap.add(i) as *const __m128i);
            acc0 = fma_step_sse(acc0, av, _mm_loadu_si128(w0.add(i) as *const __m128i), ones);
            acc1 = fma_step_sse(acc1, av, _mm_loadu_si128(w1.add(i) as *const __m128i), ones);
            acc2 = fma_step_sse(acc2, av, _mm_loadu_si128(w2.add(i) as *const __m128i), ones);
            acc3 = fma_step_sse(acc3, av, _mm_loadu_si128(w3.add(i) as *const __m128i), ones);
            i += 16;
        }
        let mut s = [
            hsum_epi32_sse(acc0),
            hsum_epi32_sse(acc1),
            hsum_epi32_sse(acc2),
            hsum_epi32_sse(acc3),
        ];
        while i < n {
            let x = *ap.add(i) as i32;
            s[0] += x * *w0.add(i) as i32;
            s[1] += x * *w1.add(i) as i32;
            s[2] += x * *w2.add(i) as i32;
            s[3] += x * *w3.add(i) as i32;
            i += 1;
        }
        *sums = s;
    }

    /// Single-row SSE dot (block remainders).
    #[target_feature(enable = "ssse3,sse4.1")]
    pub unsafe fn dot1_sse(a: &[u8], w: &[i8]) -> i32 {
        let n = a.len();
        let ap = a.as_ptr();
        let wp = w.as_ptr();
        let ones = _mm_set1_epi16(1);
        let mut acc = _mm_setzero_si128();
        let mut i = 0usize;
        while i + 16 <= n {
            let av = _mm_loadu_si128(ap.add(i) as *const __m128i);
            let wv = _mm_loadu_si128(wp.add(i) as *const __m128i);
            acc = fma_step_sse(acc, av, wv, ones);
            i += 16;
        }
        let mut s = hsum_epi32_sse(acc);
        while i < n {
            s += *ap.add(i) as i32 * *wp.add(i) as i32;
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn problem(n: usize, seed: u64) -> (Vec<u8>, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let a: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
        let w: Vec<i8> = (0..MICRO_ROWS * n)
            .map(|_| (rng.below(256) as i64 - 128) as i8)
            .collect();
        (a, w)
    }

    #[test]
    fn all_isas_agree_with_scalar_at_awkward_lengths() {
        // lengths straddling the 16- and 32-lane widths, incl. 0
        for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 257] {
            let (a, w) = problem(n, 11 + n as u64);
            for nr in 1..=MICRO_ROWS {
                let mut want = [i32::MIN; MICRO_ROWS];
                dot_block_scalar(&a, &w, n, nr, &mut want);
                for isa in [Isa::Avx2, Isa::Sse41, Isa::Scalar] {
                    let isa = isa.available();
                    let mut got = [i32::MIN; MICRO_ROWS];
                    dot_block(isa, &a, &w, n, nr, &mut got);
                    assert_eq!(got[..nr], want[..nr], "isa {isa:?} n {n} nr {nr}");
                    // lanes beyond nr stay untouched
                    assert!(got[nr..].iter().all(|&v| v == i32::MIN));
                }
            }
        }
    }

    #[test]
    fn saturating_inputs_are_scalar_only_by_contract() {
        // codes <= 127 never saturate the i16 intermediate: the extreme
        // pair 127*(-128) + 127*(-128) = -32512 fits i16.
        let a = vec![127u8; 34];
        let w = vec![-128i8; 34];
        let mut want = [0i32; MICRO_ROWS];
        dot_block_scalar(&a, &w, 34, 1, &mut want);
        let mut got = [0i32; MICRO_ROWS];
        dot_block(Isa::detect_cpu(), &a, &w, 34, 1, &mut got);
        assert_eq!(got[0], want[0]);
        assert_eq!(want[0], 34 * 127 * -128);
    }

    #[test]
    fn available_clamps_to_hardware() {
        let hw = Isa::detect_cpu();
        assert_eq!(Isa::Scalar.available(), Isa::Scalar);
        assert!(Isa::Avx2.available().rank() <= hw.rank());
        assert_eq!(hw.available(), hw);
    }
}
