//! Grouped / depthwise convolution driver.
//!
//! A grouped conv is G independent small GEMMs: group `g`'s filters see
//! only input channels `g * ch_per_group ..`. Rather than materializing
//! per-group im2col buffers (the old explicit fallback), the driver runs
//! one implicit-GEMM dispatch per group: a [`PatchGeometry`] restricted
//! to the group's channel window streams column tiles straight from the
//! NCHW map (f32 or codes), and a per-group [`TaskChunk`] schedule —
//! compiled by the `depthwise` plan pass over the *full* class-sorted
//! layout — selects exactly the group's filter rows. All groups scatter
//! into one shared output through the full layout's permutation, each
//! call with `fill = false`: the group schedules partition the row space,
//! so their union writes every cell exactly once.
//!
//! Bit-exactness follows from the implicit kernel's own contract (same
//! per-cell arithmetic as explicit im2col + GEMM, for any panel width,
//! thread count, and ISA) plus the disjoint per-group row coverage.

use super::mixed::{
    GemmActs, GemmCall, GemmOut, GemmScratch, MixedGemm, OutLayout, QuantEpilogue, TaskChunk,
};
use super::panels::{ColTileSource, PatchGeometry};
use super::sorted::SortedWeights;
use crate::gemm::cores::Requant;
use crate::quant::Mat;

/// The NCHW activation map a depthwise conv reads: stored f32 (quantized
/// into panels on the fly) or the integer-resident code slot.
pub(crate) enum DwSource<'a> {
    F32(&'a [f32]),
    Codes(&'a [u8]),
}

/// Where the depthwise conv writes: the f32 staging matrix `(n*oh*ow,
/// out_ch)` (bias/ReLU/col2im applied by the caller), or activation
/// codes through the fused requantization epilogue.
pub(crate) enum DwOut<'a> {
    F32(&'a mut Mat),
    Quant {
        out: &'a mut [u8],
        bias: &'a [f32],
        rq: Requant,
        layout: OutLayout,
    },
}

/// One grouped conv, fully described — geometry, operands, and the
/// per-group schedules the `depthwise` plan pass compiled.
pub(crate) struct DwConv<'a> {
    pub src: DwSource<'a>,
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub ch_per_group: usize,
    /// Activation quantizer of the panel gather (the conv input's scale).
    pub alpha: f32,
    pub bits: u32,
    /// The layer's full class-sorted layout (all groups).
    pub weights: &'a SortedWeights,
    /// `group_chunks[g]` covers exactly group `g`'s sorted rows; the
    /// union over groups is a partition of `0..weights.rows`.
    pub group_chunks: &'a [Vec<TaskChunk>],
    pub panel_positions: usize,
    pub parallel: bool,
}

impl MixedGemm {
    /// Run a grouped/depthwise conv as per-group implicit dispatches
    /// (see module docs). No heap allocation once `scratch` has warmed
    /// up to the panel size.
    pub(crate) fn run_depthwise(
        &self,
        call: DwConv<'_>,
        scratch: &mut GemmScratch,
        mut out: DwOut<'_>,
    ) {
        for (g, chunks) in call.group_chunks.iter().enumerate() {
            let geo = PatchGeometry::new(
                call.n,
                call.c,
                call.h,
                call.w,
                g * call.ch_per_group,
                call.ch_per_group,
                call.k,
                call.stride,
                call.pad,
            );
            let src = match call.src {
                DwSource::F32(data) => {
                    ColTileSource::F32 { data, geo, alpha: call.alpha, bits: call.bits }
                }
                DwSource::Codes(data) => {
                    ColTileSource::Codes { data, geo, alpha: call.alpha, bits: call.bits }
                }
            };
            let gout = match &mut out {
                DwOut::F32(m) => GemmOut::F32(m),
                DwOut::Quant { out, bias, rq, layout } => GemmOut::Quant {
                    out,
                    epi: QuantEpilogue { bias, rq: *rq, layout: *layout, addend: None },
                },
            };
            self.dispatch(
                GemmCall {
                    acts: GemmActs::Tiles { src: &src, positions: call.panel_positions },
                    weights: call.weights,
                    chunks,
                    parallel: call.parallel,
                    // the group schedules partition the rows: no cell is
                    // left for a standalone fill to own
                    fill: false,
                    out: gout,
                },
                scratch,
            );
        }
    }
}
