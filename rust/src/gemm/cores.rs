//! The three GEMM cores (paper §4.1: GEMM_PoT-4, GEMM_Fixed-4, GEMM_Fixed-8).
//!
//! Each core computes `y[b][r] = scale_a * scale_w[r] * Σ_c A[b][c]·W[r][c]`
//! over integer codes for the row subset it owns. The Fixed cores MAC in
//! i32; the PoT core shift-adds (`acc += ±(a << (6 - shift))` in a fixed-
//! point frame), exactly mirroring the DSP-vs-LUT datapath split on the
//! FPGA.

use super::packed::{PackedActs, PackedWeights};
use crate::quant::apot::ApotQuantizer;
use crate::quant::{Mat, Scheme};

/// A GEMM core processes the rows of one scheme class.
pub trait GemmCore {
    /// The scheme class this core accepts.
    fn scheme(&self) -> Scheme;

    /// Compute output column `y[:, r]` for one weight row `r` into `out`
    /// (length = batch). `out[b] += dequantized dot(acts[b], w[r])`.
    fn run_row(&self, acts: &PackedActs, w: &PackedWeights, r: usize, out: &mut [f32]);

    /// Ops per MAC for the efficiency accounting (2 = mul+add).
    fn ops_per_mac(&self) -> f64 {
        2.0
    }
}

/// Integer multiply-accumulate core for Fixed-W4A4 rows (DSP PEs).
pub struct GemmFixed4;
/// Integer multiply-accumulate core for Fixed-W8A4 rows (DSP PEs, 8-bit).
pub struct GemmFixed8;
/// Shift-add core for PoT-W4A4 rows (LUT PEs): no multiplier anywhere.
pub struct GemmPoT4;
/// Shift-add (two-term) core for APoT-W4A4 baseline rows.
pub struct GemmApot4 {
    quant: ApotQuantizer,
}

impl Default for GemmApot4 {
    fn default() -> Self {
        GemmApot4 { quant: ApotQuantizer::new(4) }
    }
}

#[inline]
fn fixed_row_scale(acts: &PackedActs, w: &PackedWeights, r: usize, denom: f32) -> f32 {
    acts.scale() * w.alpha[r] / denom
}

impl GemmCore for GemmFixed4 {
    fn scheme(&self) -> Scheme {
        Scheme::FixedW4A4
    }

    fn run_row(&self, acts: &PackedActs, w: &PackedWeights, r: usize, out: &mut [f32]) {
        debug_assert_eq!(w.scheme[r], Scheme::FixedW4A4);
        let wr = w.row(r);
        let s = fixed_row_scale(acts, w, r, 7.0);
        for (b, o) in out.iter_mut().enumerate() {
            let ar = acts.row(b);
            let mut acc: i32 = 0;
            for (&a, &c) in ar.iter().zip(wr) {
                acc += a as i32 * c as i32;
            }
            *o += s * acc as f32;
        }
    }
}

impl GemmCore for GemmFixed8 {
    fn scheme(&self) -> Scheme {
        Scheme::FixedW8A4
    }

    fn run_row(&self, acts: &PackedActs, w: &PackedWeights, r: usize, out: &mut [f32]) {
        debug_assert_eq!(w.scheme[r], Scheme::FixedW8A4);
        let wr = w.row(r);
        let s = fixed_row_scale(acts, w, r, 127.0);
        for (b, o) in out.iter_mut().enumerate() {
            let ar = acts.row(b);
            let mut acc: i32 = 0;
            for (&a, &c) in ar.iter().zip(wr) {
                acc += a as i32 * c as i32;
            }
            *o += s * acc as f32;
        }
    }
}

/// Per-code fixed-point multipliers for the PoT shift-add core: code c
/// (pot_pack format) maps to `±2^(6-shift)` in the 2^6-scaled frame, so
/// `acc += a * POT_MULT[c]` is arithmetically identical to the shift-add
/// `acc ±= a << (6 - shift)`. The LUT is how we *simulate* the hardware's
/// shifter on a CPU without a per-element branch + variable shift; the
/// integer results are bit-identical.
#[allow(dead_code)] // consumed by the pot_mult cache validation test
static POT_MULT: [i32; 256] = build_pot_mult();

const fn build_pot_mult() -> [i32; 256] {
    let mut t = [0i32; 256];
    let mut code: i32 = -128;
    while code < 128 {
        let idx = (code as i8) as u8 as usize;
        if code != 0 {
            let sign = if code < 0 { -1 } else { 1 };
            let shift = if code < 0 { -code - 1 } else { code - 1 };
            if shift <= 6 {
                t[idx] = sign * (1 << (6 - shift));
            }
        }
        code += 1;
    }
    t
}

impl GemmCore for GemmPoT4 {
    fn scheme(&self) -> Scheme {
        Scheme::PotW4A4
    }

    /// Shift-add datapath: weights are `±2^-shift`, shift in 0..=6,
    /// accumulated in a 2^6-scaled integer frame (see [`POT_MULT`] for the
    /// branchless CPU realization). i32 accumulation is safe: |term| <=
    /// 15 * 64 = 960, so K up to ~2.2M columns fits i32.
    fn run_row(&self, acts: &PackedActs, w: &PackedWeights, r: usize, out: &mut [f32]) {
        debug_assert_eq!(w.scheme[r], Scheme::PotW4A4);
        // The precomputed multiplier row (`pot_mult`) is the decoded weight
        // register of the LUT PE: an i8 in ±2^(6-shift). The u8 x i8 -> i32
        // loop has the same shape as the Fixed cores and vectorizes.
        let mr = w.pot_mult_row(r);
        let s = acts.scale() * w.alpha[r] / 64.0;
        for (b, o) in out.iter_mut().enumerate() {
            let ar = acts.row(b);
            let mut acc: i32 = 0;
            for (&a, &m) in ar.iter().zip(mr) {
                acc += a as i32 * m as i32;
            }
            *o += s * acc as f32;
        }
    }

    fn ops_per_mac(&self) -> f64 {
        // shift + add; no multiply
        2.0
    }
}

impl GemmCore for GemmApot4 {
    fn scheme(&self) -> Scheme {
        Scheme::ApotW4A4
    }

    /// APoT = sum of two PoT terms -> two shift-adds per MAC. We go through
    /// the dequantized level table (the hardware equivalent: a 3-bit LUT
    /// into shift pairs).
    fn run_row(&self, acts: &PackedActs, w: &PackedWeights, r: usize, out: &mut [f32]) {
        debug_assert_eq!(w.scheme[r], Scheme::ApotW4A4);
        let wr = w.row(r);
        let lv = self.quant.levels();
        let sa = acts.scale();
        let aw = w.alpha[r];
        for (b, o) in out.iter_mut().enumerate() {
            let ar = acts.row(b);
            let mut acc = 0.0f32;
            for (&a, &c) in ar.iter().zip(wr) {
                let sign = if c < 0 { -1.0 } else { 1.0 };
                acc += a as f32 * sign * lv[c.unsigned_abs() as usize];
            }
            *o += sa * aw * acc;
        }
    }

    fn ops_per_mac(&self) -> f64 {
        3.0 // two shifts + adds
    }
}

/// Float reference GEMM over dequantized operands (oracle for the cores).
pub fn reference_gemm(acts: &PackedActs, w: &PackedWeights) -> Mat {
    let a = acts.dequant();
    let wd = w.dequant();
    a.matmul_nt(&wd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(scheme: Scheme, rows: usize, cols: usize, batch: usize)
        -> (PackedActs, PackedWeights) {
        let mut rng = Rng::new(42);
        let x = Mat::from_vec(batch, cols, (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect());
        let w = Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() * 0.4).collect());
        let alpha: Vec<f32> = (0..rows).map(|r| crate::quant::default_alpha(w.row(r))).collect();
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &vec![scheme; rows], &alpha);
        (acts, pw)
    }

    fn check_core(core: &dyn GemmCore) {
        let (acts, w) = setup(core.scheme(), 5, 33, 4);
        let want = reference_gemm(&acts, &w);
        let mut got = Mat::zeros(acts.rows, w.rows);
        for r in 0..w.rows {
            let mut col = vec![0.0f32; acts.rows];
            core.run_row(&acts, &w, r, &mut col);
            for b in 0..acts.rows {
                got.set(b, r, col[b]);
            }
        }
        let err = got.max_abs_err(&want);
        assert!(err < 1e-4, "{} core err {err}", core.scheme());
    }

    #[test]
    fn fixed4_matches_reference() {
        check_core(&GemmFixed4);
    }

    #[test]
    fn fixed8_matches_reference() {
        check_core(&GemmFixed8);
    }

    #[test]
    fn pot4_matches_reference() {
        check_core(&GemmPoT4);
    }

    #[test]
    fn apot4_matches_reference() {
        check_core(&GemmApot4::default());
    }

    #[test]
    fn pot_mult_cache_matches_code_table() {
        // the precomputed multiplier row must equal POT_MULT[code] per
        // element (i.e. caching never changes the arithmetic).
        let (_, w) = setup(Scheme::PotW4A4, 3, 97, 1);
        for r in 0..w.rows {
            for (c, m) in w.row(r).iter().zip(w.pot_mult_row(r)) {
                assert_eq!(*m as i32, POT_MULT[*c as u8 as usize], "code {c}");
            }
        }
    }

    #[test]
    fn pot_core_is_pure_integer() {
        // The PoT accumulation of max-magnitude operands must not overflow
        // i64 for realistic K: a=15, shift=0 -> term = 15<<6 = 960; K=1e6
        // -> ~1e9, far below i64::MAX.
        let (acts, w) = setup(Scheme::PotW4A4, 1, 64, 1);
        let mut out = vec![0.0f32];
        GemmPoT4.run_row(&acts, &w, 0, &mut out);
        assert!(out[0].is_finite());
    }
}
