//! The three GEMM cores (paper §4.1: GEMM_PoT-4, GEMM_Fixed-4, GEMM_Fixed-8).
//!
//! Each core computes `y[b][r] = scale_a * scale_w[r] * Σ_c A[b][c]·W[r][c]`
//! over integer codes for the row subset it owns. The Fixed cores MAC in
//! i32; the PoT core shift-adds (`acc += ±(a << (6 - shift))` in a fixed-
//! point frame), exactly mirroring the DSP-vs-LUT datapath split on the
//! FPGA.
//!
//! Two kernel shapes per core:
//!
//! * [`GemmCore::run_row_tiled`] — one weight row at a time over the
//!   model-order [`PackedWeights`] (the grouped-conv path and the
//!   row-at-a-time baseline the benches compare against).
//! * [`GemmCore::run_block_tiled`] — the hot path: up to
//!   [`MAX_MICRO_ROWS`] same-class rows of the class-sorted
//!   [`SortedWeights`] layout per call (the block height is the
//!   engine's — possibly per-layer-tuned — `micro_rows`), with the
//!   inner dot product dispatched to the runtime-selected SIMD kernel
//!   ([`super::simd::dot_block`]). One activation tile load feeds the
//!   whole row block.
//!
//! Both shapes block the column dimension at `tile_cols` codes so one
//! weight tile stays hot in L1 while it is swept across every batch row,
//! and the per-(batch, row) i32 accumulator survives across tiles so the
//! dequantizing multiply happens exactly once per output element.
//! Integer accumulation is associative, so any tile size, block size, or
//! kernel ISA produces bit-identical results for the three RMSMP cores;
//! the APoT baseline core accumulates in f32 and is deterministic for a
//! *fixed* tile size (which is all the parallel executor needs).

use super::packed::{code_map, ActsView, PackedActs, PackedWeights};
use super::simd::{self, KernelIsa, MAX_MICRO_ROWS};
use super::sorted::SortedWeights;
use crate::quant::apot::ApotQuantizer;
use crate::quant::{Mat, Scheme};

/// Fused requantization parameters for the integer-resident epilogue:
/// the affine map from a dequantized f32 output value to the *consumer
/// layer's* activation code. Built once per op at plan-compile time from
/// the consumer's clip scale and the global activation width.
///
/// `code(v)` is bit-identical to storing `v` to f32 and running
/// [`super::packed::PackedActs::quantize_slice_into`] over it at the top
/// of the next layer (same `n / alpha` division, same multiply, same
/// clamp, same `round_ties_even`). The clamp's lower bound of zero also
/// subsumes ReLU: `max(v, 0)` before the map cannot change the code, so
/// the integer-resident path gets ReLU for free — which is also what
/// lets the `epilogue_fusion` pass fold a residual `Add + ReLU` into a
/// quantizing epilogue: the fused addend joins `v` before `code(v)` and
/// the ReLU costs nothing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Requant {
    /// `n / alpha` — the consumer's code-domain scale.
    pub inv: f32,
    /// `(1 << bits) - 1` as f32 — the top of the code range.
    pub n: f32,
}

impl Requant {
    /// Epilogue for a consumer quantizing to `bits`-bit codes with clip
    /// scale `alpha`.
    pub fn new(alpha: f32, bits: u32) -> Requant {
        let n = ((1u32 << bits) - 1) as f32;
        Requant { inv: n / alpha, n }
    }

    /// The consumer's activation code of output value `v` — the shared
    /// hoisted-constant [`code_map`], so the epilogue and the activation
    /// quantizer agree bit for bit.
    #[inline]
    pub fn code(self, v: f32) -> u8 {
        code_map(v, self.inv, self.n)
    }
}

/// Block epilogue of the integer-resident pipeline: map one micro-kernel
/// block of dequantized outputs (`nr` rows x `batch`, as produced by
/// [`GemmCore::run_block_tiled`]) to the consumer's activation codes —
/// `codes[j * batch + b] = rq.code(col[j * batch + b] + bias[j])`.
///
/// The bias add here is the same f32 add the f32-resident path performs
/// on its staging matrix, so the codes are bit-exact vs
/// dequant-store-requantize; ReLU needs no term (see [`Requant`]).
pub fn requant_block(
    col: &[f32],
    nr: usize,
    batch: usize,
    bias: &[f32; MAX_MICRO_ROWS],
    rq: Requant,
    codes: &mut [u8],
) {
    debug_assert!(nr <= MAX_MICRO_ROWS);
    debug_assert!(col.len() >= nr * batch && codes.len() >= nr * batch);
    for j in 0..nr {
        requant_row(
            &col[j * batch..(j + 1) * batch],
            bias[j],
            rq,
            &mut codes[j * batch..(j + 1) * batch],
        );
    }
}

/// Row epilogue of the integer-resident pipeline (the grouped-conv
/// path): requantize one weight row's dequantized outputs, all sharing
/// one bias, into consumer activation codes.
pub fn requant_row(col: &[f32], bias: f32, rq: Requant, codes: &mut [u8]) {
    debug_assert_eq!(col.len(), codes.len());
    for (d, &v) in codes.iter_mut().zip(col) {
        *d = rq.code(v + bias);
    }
}

/// A GEMM core processes the rows of one scheme class.
///
/// Cores are `Sync`: the parallel mixed GEMM shares one core instance
/// across all worker tasks of its class.
pub trait GemmCore: Sync {
    /// The scheme class this core accepts.
    fn scheme(&self) -> Scheme;

    /// Compute `out[b] += dequant(dot(acts[b], w[r]))` for one weight row
    /// `r`, with the column loop blocked at `tile_cols` (0 = untiled).
    /// `acc` is caller-provided i32 scratch; both slices have length =
    /// batch. The scratch is zeroed here, so callers only reset `out`.
    fn run_row_tiled(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        tile_cols: usize,
        acc: &mut [i32],
        out: &mut [f32],
    );

    /// Micro-kernel block over the class-sorted layout: compute `nr`
    /// (1..=[`MAX_MICRO_ROWS`]) sorted rows `r0..r0 + nr` — all of this
    /// core's class — against every batch row of the activation view
    /// (the full matrix, or one implicit-GEMM panel), writing
    /// `out[j * batch + b] = dequant(dot(acts[b], sorted row r0 + j))`
    /// (overwrite, not accumulate). `acc` is i32 scratch; both slices
    /// must hold at least `nr * batch` elements. The integer cores
    /// dispatch the inner dot to `isa` — a pre-validated token (see
    /// [`KernelIsa`]), so no per-call hardware re-check happens here;
    /// every ISA is bit-exact vs the scalar
    /// [`GemmCore::run_row_tiled`] path at the same `tile_cols`.
    fn run_block_tiled(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        r0: usize,
        nr: usize,
        tile_cols: usize,
        isa: KernelIsa,
        acc: &mut [i32],
        out: &mut [f32],
    );

    /// Untiled convenience wrapper (tests and one-off rows); allocates the
    /// scratch internally.
    fn run_row(&self, acts: &PackedActs, w: &PackedWeights, r: usize, out: &mut [f32]) {
        let mut acc = vec![0i32; out.len()];
        self.run_row_tiled(acts, w, r, 0, &mut acc, out);
    }

    /// Ops per MAC for the efficiency accounting (2 = mul+add).
    fn ops_per_mac(&self) -> f64 {
        2.0
    }
}

/// Integer multiply-accumulate core for Fixed-W4A4 rows (DSP PEs).
pub struct GemmFixed4;
/// Integer multiply-accumulate core for Fixed-W8A4 rows (DSP PEs, 8-bit).
pub struct GemmFixed8;
/// Shift-add core for PoT-W4A4 rows (LUT PEs): no multiplier anywhere.
pub struct GemmPoT4;

/// Shift-add (two-term) core for APoT-W4A4 baseline rows.
pub struct GemmApot4 {
    /// Signed dequantized level per stored code byte, indexed by the i8
    /// code reinterpreted as u8: `slev[c as u8] = sign(c) * level[|c|]`.
    /// Precomputing the sign into the table drops the per-element sign
    /// branch and the `levels()` bounds-checked indirection from the
    /// inner loop (the hardware equivalent: the decoded shift-pair
    /// register of the APoT PE).
    slev: [f32; 256],
}

impl Default for GemmApot4 {
    fn default() -> Self {
        let lv = ApotQuantizer::new(4).levels().to_vec();
        let mut slev = [0.0f32; 256];
        for code in -128i32..128 {
            let idx = (code as i8) as u8 as usize;
            let mag = code.unsigned_abs() as usize;
            if mag < lv.len() {
                // multiplying by the exact ±1 sign preserves bit-exactness
                // vs the branchy `sign * level` form
                slev[idx] = if code < 0 { -lv[mag] } else { lv[mag] };
            }
        }
        GemmApot4 { slev }
    }
}

#[inline]
fn fixed_row_scale(acts: &PackedActs, w: &PackedWeights, r: usize, denom: f32) -> f32 {
    acts.scale() * w.alpha[r] / denom
}

/// Shared tiled u8 x i8 -> i32 MAC kernel: accumulate the full row in i32
/// (exact), then apply the dequantizing multiply once per batch element.
/// `wr` is the weight-code (or PoT-multiplier) row; tile = 0 means one
/// tile spanning all columns.
#[inline]
fn mac_i32_tiled(
    acts: &PackedActs,
    wr: &[i8],
    scale: f32,
    tile_cols: usize,
    acc: &mut [i32],
    out: &mut [f32],
) {
    let batch = acts.rows;
    let cols = acts.cols;
    debug_assert_eq!(acc.len(), batch);
    debug_assert_eq!(out.len(), batch);
    acc.fill(0);
    let tile = if tile_cols == 0 { cols } else { tile_cols };
    let mut start = 0usize;
    while start < cols {
        let end = cols.min(start.saturating_add(tile));
        let wt = &wr[start..end];
        for (b, a) in acc.iter_mut().enumerate() {
            let at = &acts.row(b)[start..end];
            let mut t = 0i32;
            for (&x, &c) in at.iter().zip(wt) {
                t += x as i32 * c as i32;
            }
            *a += t;
        }
        start = end;
    }
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o += scale * a as f32;
    }
}

/// Shared block kernel of the three integer cores: `nr` sorted operand
/// rows x the whole batch, i32 accumulation through the runtime-selected
/// SIMD dot ([`simd::dot_block`]), one dequantizing multiply per output
/// cell with the same `(act_scale * alpha) / denom` expression as the
/// row kernels — hence bit-exact vs [`mac_i32_tiled`] for every ISA.
fn mac_block_i32(
    acts: ActsView<'_>,
    sw: &SortedWeights,
    r0: usize,
    nr: usize,
    denom: f32,
    tile_cols: usize,
    isa: KernelIsa,
    acc: &mut [i32],
    out: &mut [f32],
) {
    let batch = acts.rows;
    let cols = acts.cols;
    debug_assert!(nr >= 1 && nr <= MAX_MICRO_ROWS);
    debug_assert!(acc.len() >= nr * batch);
    debug_assert!(out.len() >= nr * batch);
    let acc = &mut acc[..nr * batch];
    acc.fill(0);
    // Activation codes above 127 would saturate the 16-bit intermediate
    // of the maddubs-based tiers and flip sign under NEON sdot; this repo
    // quantizes activations to 4 bits, but the dispatch stays correct for
    // any width: AVX-512 VNNI accumulates u8 codes exactly and keeps its
    // vector path, every other vector tier degrades to scalar.
    let isa = if acts.bits > 7 { isa.for_wide_codes() } else { isa };
    let wblock = sw.op_rows(r0, nr);
    let tile = if tile_cols == 0 { cols } else { tile_cols };
    let mut start = 0usize;
    while start < cols {
        let end = cols.min(start.saturating_add(tile));
        let wt = &wblock[start..];
        let mut sums = [0i32; MAX_MICRO_ROWS];
        for b in 0..batch {
            let at = &acts.row(b)[start..end];
            simd::dot_block(isa, at, wt, cols, nr, &mut sums);
            for (j, &s) in sums.iter().enumerate().take(nr) {
                acc[j * batch + b] += s;
            }
        }
        start = end;
    }
    let ascale = acts.scale();
    for j in 0..nr {
        // same expression shape as `fixed_row_scale` so block == row
        // bit-exactly
        let s = ascale * sw.alpha[r0 + j] / denom;
        let accj = &acc[j * batch..(j + 1) * batch];
        for (o, &a) in out[j * batch..(j + 1) * batch].iter_mut().zip(accj) {
            *o = s * a as f32;
        }
    }
}

impl GemmCore for GemmFixed4 {
    fn scheme(&self) -> Scheme {
        Scheme::FixedW4A4
    }

    fn run_row_tiled(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        tile_cols: usize,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(w.scheme[r], Scheme::FixedW4A4);
        let s = fixed_row_scale(acts, w, r, 7.0);
        mac_i32_tiled(acts, w.row(r), s, tile_cols, acc, out);
    }

    fn run_block_tiled(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        r0: usize,
        nr: usize,
        tile_cols: usize,
        isa: KernelIsa,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(sw.scheme_of(r0), Scheme::FixedW4A4);
        mac_block_i32(acts, sw, r0, nr, 7.0, tile_cols, isa, acc, out);
    }
}

impl GemmCore for GemmFixed8 {
    fn scheme(&self) -> Scheme {
        Scheme::FixedW8A4
    }

    fn run_row_tiled(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        tile_cols: usize,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(w.scheme[r], Scheme::FixedW8A4);
        let s = fixed_row_scale(acts, w, r, 127.0);
        mac_i32_tiled(acts, w.row(r), s, tile_cols, acc, out);
    }

    fn run_block_tiled(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        r0: usize,
        nr: usize,
        tile_cols: usize,
        isa: KernelIsa,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(sw.scheme_of(r0), Scheme::FixedW8A4);
        mac_block_i32(acts, sw, r0, nr, 127.0, tile_cols, isa, acc, out);
    }
}

/// Per-code fixed-point multipliers for the PoT shift-add core: code c
/// (pot_pack format) maps to `±2^(6-shift)` in the 2^6-scaled frame, so
/// `acc += a * POT_MULT[c]` is arithmetically identical to the shift-add
/// `acc ±= a << (6 - shift)`. The LUT is how we *simulate* the hardware's
/// shifter on a CPU without a per-element branch + variable shift; the
/// integer results are bit-identical.
#[allow(dead_code)] // consumed by the pot_mult cache validation test
static POT_MULT: [i32; 256] = build_pot_mult();

const fn build_pot_mult() -> [i32; 256] {
    let mut t = [0i32; 256];
    let mut code: i32 = -128;
    while code < 128 {
        let idx = (code as i8) as u8 as usize;
        if code != 0 {
            let sign = if code < 0 { -1 } else { 1 };
            let shift = if code < 0 { -code - 1 } else { code - 1 };
            if shift <= 6 {
                t[idx] = sign * (1 << (6 - shift));
            }
        }
        code += 1;
    }
    t
}

impl GemmCore for GemmPoT4 {
    fn scheme(&self) -> Scheme {
        Scheme::PotW4A4
    }

    /// Shift-add datapath: weights are `±2^-shift`, shift in 0..=6,
    /// accumulated in a 2^6-scaled integer frame (see [`POT_MULT`] for the
    /// branchless CPU realization). i32 accumulation is safe: |term| <=
    /// 15 * 64 = 960, so K up to ~2.2M columns fits i32.
    fn run_row_tiled(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        tile_cols: usize,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(w.scheme[r], Scheme::PotW4A4);
        // The precomputed multiplier row (`pot_mult`) is the decoded weight
        // register of the LUT PE: an i8 in ±2^(6-shift). The u8 x i8 -> i32
        // loop has the same shape as the Fixed cores and vectorizes.
        let s = acts.scale() * w.alpha[r] / 64.0;
        mac_i32_tiled(acts, w.pot_mult_row(r), s, tile_cols, acc, out);
    }

    /// The sorted layout stores PoT rows pre-decoded to their
    /// `±2^(6-shift)` multipliers, so the block kernel is the same u8 x
    /// i8 SIMD MAC as the Fixed cores, in the 2^6-scaled frame.
    fn run_block_tiled(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        r0: usize,
        nr: usize,
        tile_cols: usize,
        isa: KernelIsa,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(sw.scheme_of(r0), Scheme::PotW4A4);
        mac_block_i32(acts, sw, r0, nr, 64.0, tile_cols, isa, acc, out);
    }

    fn ops_per_mac(&self) -> f64 {
        // shift + add; no multiply
        2.0
    }
}

impl GemmApot4 {
    /// The tiled APoT inner loop shared by the row and block shapes:
    /// `out[b] += s * Σ tile`, f32 per-tile accumulation over the signed
    /// level table — deterministic (and row/block bit-identical) for a
    /// fixed `tile_cols`.
    fn apot_row_tiled(
        &self,
        acts: ActsView<'_>,
        wr: &[i8],
        s: f32,
        tile_cols: usize,
        out: &mut [f32],
    ) {
        let cols = acts.cols;
        let tile = if tile_cols == 0 { cols } else { tile_cols };
        let mut start = 0usize;
        while start < cols {
            let end = cols.min(start.saturating_add(tile));
            let wt = &wr[start..end];
            for (b, o) in out.iter_mut().enumerate() {
                let at = &acts.row(b)[start..end];
                let mut t = 0.0f32;
                for (&a, &c) in at.iter().zip(wt) {
                    t += a as f32 * self.slev[c as u8 as usize];
                }
                *o += s * t;
            }
            start = end;
        }
    }
}

impl GemmCore for GemmApot4 {
    fn scheme(&self) -> Scheme {
        Scheme::ApotW4A4
    }

    /// APoT = sum of two PoT terms -> two shift-adds per MAC. The signed
    /// level table (`slev`) is the hardware equivalent of a 3-bit LUT
    /// into shift pairs. The level grid is not dyadic, so accumulation is
    /// f32 per tile; results are deterministic for a fixed tile size.
    fn run_row_tiled(
        &self,
        acts: &PackedActs,
        w: &PackedWeights,
        r: usize,
        tile_cols: usize,
        _acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(w.scheme[r], Scheme::ApotW4A4);
        let s = acts.scale() * w.alpha[r];
        self.apot_row_tiled(acts.view(), w.row(r), s, tile_cols, out);
    }

    /// Row-at-a-time over the sorted codes (the APoT baseline core gets
    /// no SIMD path — it is not one of the paper's hardware classes);
    /// identical tile walk as [`GemmCore::run_row_tiled`], so block ==
    /// row bit-exactly for a fixed `tile_cols`.
    fn run_block_tiled(
        &self,
        acts: ActsView<'_>,
        sw: &SortedWeights,
        r0: usize,
        nr: usize,
        tile_cols: usize,
        _isa: KernelIsa,
        _acc: &mut [i32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(sw.scheme_of(r0), Scheme::ApotW4A4);
        let batch = acts.rows;
        debug_assert!(out.len() >= nr * batch);
        for j in 0..nr {
            let r = r0 + j;
            let s = acts.scale() * sw.alpha[r];
            let outj = &mut out[j * batch..(j + 1) * batch];
            outj.fill(0.0);
            self.apot_row_tiled(acts, sw.op_row(r), s, tile_cols, outj);
        }
    }

    fn ops_per_mac(&self) -> f64 {
        3.0 // two shifts + adds
    }
}

/// Float reference GEMM over dequantized operands (oracle for the cores).
pub fn reference_gemm(acts: &PackedActs, w: &PackedWeights) -> Mat {
    let a = acts.dequant();
    let wd = w.dequant();
    a.matmul_nt(&wd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(
        scheme: Scheme,
        rows: usize,
        cols: usize,
        batch: usize,
    ) -> (PackedActs, PackedWeights) {
        let mut rng = Rng::new(42);
        let xd: Vec<f32> = (0..batch * cols).map(|_| rng.uniform(0.0, 1.0)).collect();
        let x = Mat::from_vec(batch, cols, xd);
        let w = Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 0.4));
        let alpha: Vec<f32> = (0..rows).map(|r| crate::quant::default_alpha(w.row(r))).collect();
        let schemes = vec![scheme; rows];
        let acts = PackedActs::quantize(&x, 1.0, 4);
        let pw = PackedWeights::quantize(&w, &schemes, &alpha);
        (acts, pw)
    }

    fn check_core(core: &dyn GemmCore) {
        let (acts, w) = setup(core.scheme(), 5, 33, 4);
        let want = reference_gemm(&acts, &w);
        let mut got = Mat::zeros(acts.rows, w.rows);
        for r in 0..w.rows {
            let mut col = vec![0.0f32; acts.rows];
            core.run_row(&acts, &w, r, &mut col);
            for b in 0..acts.rows {
                got.set(b, r, col[b]);
            }
        }
        let err = got.max_abs_err(&want);
        assert!(err < 1e-4, "{} core err {err}", core.scheme());
    }

    #[test]
    fn fixed4_matches_reference() {
        check_core(&GemmFixed4);
    }

    #[test]
    fn fixed8_matches_reference() {
        check_core(&GemmFixed8);
    }

    #[test]
    fn pot4_matches_reference() {
        check_core(&GemmPoT4);
    }

    #[test]
    fn apot4_matches_reference() {
        check_core(&GemmApot4::default());
    }

    #[test]
    fn tiling_is_exact_for_integer_cores() {
        // i32 accumulation is associative: every tile size must produce
        // bit-identical output for the three RMSMP cores.
        for scheme in [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4] {
            let (acts, w) = setup(scheme, 4, 97, 3);
            let core: &dyn GemmCore = match scheme {
                Scheme::PotW4A4 => &GemmPoT4,
                Scheme::FixedW4A4 => &GemmFixed4,
                _ => &GemmFixed8,
            };
            let mut want = vec![0.0f32; acts.rows];
            core.run_row(&acts, &w, 1, &mut want);
            for tile in [1usize, 7, 16, 96, 97, 1000] {
                let mut acc = vec![0i32; acts.rows];
                let mut got = vec![0.0f32; acts.rows];
                core.run_row_tiled(&acts, &w, 1, tile, &mut acc, &mut got);
                assert_eq!(got, want, "{scheme} tile {tile}");
            }
        }
    }

    #[test]
    fn block_kernel_matches_row_kernel_per_scheme() {
        // single-scheme layers: the sorted layout is the identity, so the
        // block kernel must reproduce run_row_tiled cell for cell, for
        // every ISA, block size (incl. the fused 6/8-row kernels and
        // their odd tails), and tile size.
        let apot = GemmApot4::default();
        for scheme in [
            Scheme::PotW4A4,
            Scheme::FixedW4A4,
            Scheme::FixedW8A4,
            Scheme::ApotW4A4,
        ] {
            let (acts, w) = setup(scheme, 9, 70, 3);
            let sw = SortedWeights::from_packed(&w);
            let core: &dyn GemmCore = match scheme {
                Scheme::PotW4A4 => &GemmPoT4,
                Scheme::FixedW4A4 => &GemmFixed4,
                Scheme::FixedW8A4 => &GemmFixed8,
                _ => &apot,
            };
            let batch = acts.rows;
            for tile in [0usize, 7, 33, 70] {
                for (r0, nr) in [
                    (0usize, 1usize),
                    (0, 4),
                    (2, 4),
                    (4, 2),
                    (5, 1),
                    (0, 6),
                    (1, 6),
                    (0, 8),
                    (1, 8),
                    (2, 7),
                    (3, 5),
                ] {
                    let mut acc = vec![0i32; MAX_MICRO_ROWS * batch];
                    let mut block = vec![f32::NAN; MAX_MICRO_ROWS * batch];
                    for isa in simd::ISA_LADDER {
                        core.run_block_tiled(
                            acts.view(),
                            &sw,
                            r0,
                            nr,
                            tile,
                            isa.validated(),
                            &mut acc,
                            &mut block,
                        );
                        for j in 0..nr {
                            let mut racc = vec![0i32; batch];
                            let mut want = vec![0.0f32; batch];
                            let orig = sw.perm[r0 + j];
                            core.run_row_tiled(&acts, &w, orig, tile, &mut racc, &mut want);
                            assert_eq!(
                                &block[j * batch..(j + 1) * batch],
                                &want[..],
                                "{scheme} isa {isa:?} tile {tile} r0 {r0} nr {nr} j {j}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn apot_tiling_is_deterministic() {
        let (acts, w) = setup(Scheme::ApotW4A4, 3, 64, 2);
        let core = GemmApot4::default();
        for tile in [1usize, 8, 33] {
            let mut acc = vec![0i32; acts.rows];
            let mut a = vec![0.0f32; acts.rows];
            let mut b = vec![0.0f32; acts.rows];
            core.run_row_tiled(&acts, &w, 0, tile, &mut acc, &mut a);
            core.run_row_tiled(&acts, &w, 0, tile, &mut acc, &mut b);
            assert_eq!(a, b, "tile {tile}");
        }
    }

    #[test]
    fn apot_signed_level_table_matches_levels() {
        let core = GemmApot4::default();
        let q = ApotQuantizer::new(4);
        let lv = q.levels();
        for code in -7i32..=7 {
            let idx = (code as i8) as u8 as usize;
            let want = if code < 0 {
                -lv[(-code) as usize]
            } else {
                lv[code as usize]
            };
            assert_eq!(core.slev[idx], want, "code {code}");
        }
    }

    #[test]
    fn pot_mult_cache_matches_code_table() {
        // the precomputed multiplier row must equal POT_MULT[code] per
        // element (i.e. caching never changes the arithmetic).
        let (_, w) = setup(Scheme::PotW4A4, 3, 97, 1);
        for r in 0..w.rows {
            for (c, m) in w.row(r).iter().zip(w.pot_mult_row(r)) {
                assert_eq!(*m as i32, POT_MULT[*c as u8 as usize], "code {c}");
            }
        }
    }

    #[test]
    fn requant_code_matches_activation_quantizer() {
        // the fused epilogue must reproduce PackedActs::quantize (and
        // thus quant::act_code) bit for bit, including the free ReLU:
        // max(v, 0) before the map never changes the code.
        let mut rng = Rng::new(11);
        for &(alpha, bits) in &[(1.0f32, 4u32), (0.73, 4), (1.9, 8)] {
            let rq = Requant::new(alpha, bits);
            let vals: Vec<f32> = (0..257)
                .map(|i| match i {
                    0 => 0.0,
                    1 => -0.0,
                    2 => alpha,
                    3 => -alpha,
                    _ => rng.uniform(-1.5 * alpha, 1.5 * alpha),
                })
                .collect();
            let x = Mat::from_vec(1, vals.len(), vals.clone());
            let want = PackedActs::quantize(&x, alpha, bits);
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(rq.code(v), want.codes[i], "alpha {alpha} v {v}");
                let relu = if v < 0.0 { 0.0 } else { v };
                assert_eq!(rq.code(relu), want.codes[i], "relu changed code of {v}");
            }
        }
    }

    #[test]
    fn requant_block_and_row_agree() {
        let mut rng = Rng::new(13);
        let (nr, batch) = (6usize, 5usize);
        let col: Vec<f32> = (0..MAX_MICRO_ROWS * batch).map(|_| rng.normal()).collect();
        let bias = [0.1f32, -0.2, 0.0, 0.3, -0.4, 0.25, 0.0, -0.1];
        let rq = Requant::new(0.9, 4);
        let mut block = vec![0xffu8; MAX_MICRO_ROWS * batch];
        requant_block(&col, nr, batch, &bias, rq, &mut block);
        for j in 0..nr {
            let mut row = vec![0u8; batch];
            requant_row(&col[j * batch..(j + 1) * batch], bias[j], rq, &mut row);
            assert_eq!(&block[j * batch..(j + 1) * batch], &row[..], "row {j}");
        }
        // rows beyond nr untouched
        assert!(block[nr * batch..].iter().all(|&c| c == 0xff));
    }

    #[test]
    fn pot_core_is_pure_integer() {
        // The PoT accumulation of max-magnitude operands must not overflow
        // i64 for realistic K: a=15, shift=0 -> term = 15<<6 = 960; K=1e6
        // -> ~1e9, far below i64::MAX.
        let (acts, w) = setup(Scheme::PotW4A4, 1, 64, 1);
        let mut out = vec![0.0f32];
        GemmPoT4.run_row(&acts, &w, 0, &mut out);
        assert!(out[0].is_finite());
    }
}
