//! Quantized operand containers: integer codes + scales.
//!
//! `PackedWeights` stores one layer's weight matrix in code form, row-major,
//! with per-row (scheme, alpha). Fixed rows hold i8 codes; PoT rows hold
//! (sign, shift) pairs packed as i8 `sign * (shift + 1)` with 0 = zero
//! weight — i.e. the 4-bit field a real LUT core would consume.

use crate::ensure;
use crate::quant::{self, Mat, Scheme};
use crate::util::error::Result;
use crate::util::mmap::Plane;

/// The activation quantizer's per-element code map with its constants
/// hoisted: `inv` is the precomputed `n / alpha` reciprocal and `top`
/// the code ceiling `(1 << bits) - 1`, so the inner loops of every
/// caller do one multiply and one clamp per element — never a divide,
/// never a bound recomputation. Shared by the full-matrix quantize
/// ([`PackedActs::quantize_slice_into`]), the fused panel gather
/// (`super::panels::pack_quant_patch_rows`), and the requantization
/// epilogue (`super::cores::Requant::code`), which is what keeps all
/// three bit-identical by construction.
#[inline(always)]
pub(crate) fn code_map(v: f32, inv: f32, top: f32) -> u8 {
    (v * inv).clamp(0.0, top).round_ties_even() as u8
}

/// A borrowed view of quantized activations — what the block
/// micro-kernels actually consume. A [`PackedActs`] views as its full
/// matrix ([`PackedActs::view`]); the implicit-GEMM dispatch views one
/// packed column-tile panel at a time, so the kernels never know whether
/// the operand was materialized or streamed.
#[derive(Clone, Copy, Debug)]
pub struct ActsView<'a> {
    /// u8 codes, row-major (`rows` x `cols`).
    pub codes: &'a [u8],
    pub rows: usize,
    pub cols: usize,
    pub alpha: f32,
    pub bits: u32,
}

impl<'a> ActsView<'a> {
    #[inline]
    pub fn row(&self, r: usize) -> &'a [u8] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    /// Dequantized float value of one code step — the same expression as
    /// [`PackedActs::scale`], so view-based kernels dequantize
    /// bit-identically to the packed path.
    #[inline]
    pub fn scale(&self) -> f32 {
        self.alpha / ((1u32 << self.bits) - 1) as f32
    }
}

/// Activations quantized to unsigned m-bit codes with a shared scale.
#[derive(Clone, Debug)]
pub struct PackedActs {
    pub rows: usize,
    pub cols: usize,
    /// u8 codes (0..=2^bits-1), row-major.
    pub codes: Vec<u8>,
    pub alpha: f32,
    pub bits: u32,
}

impl PackedActs {
    /// Quantize a float activation matrix (batch x cols).
    ///
    /// Hot path (runs on every layer's im2col output): one multiply by the
    /// precomputed `n/alpha` instead of a divide per element, clamp in the
    /// code domain. Bit-identical to `quant::act_code` (same rounding, and
    /// clamping before/after the affine map commutes for alpha > 0).
    pub fn quantize(x: &Mat, alpha: f32, bits: u32) -> PackedActs {
        let mut out = PackedActs::empty();
        PackedActs::quantize_into(x, alpha, bits, &mut out);
        out
    }

    /// An empty container suitable as a [`PackedActs::quantize_into`]
    /// target. `with_capacity` preallocates the code buffer so repeated
    /// `quantize_into` calls up to `cap` elements never allocate.
    pub fn empty() -> PackedActs {
        PackedActs::with_capacity(0)
    }

    /// See [`PackedActs::empty`].
    pub fn with_capacity(cap: usize) -> PackedActs {
        PackedActs { rows: 0, cols: 0, codes: Vec::with_capacity(cap), alpha: 1.0, bits: 4 }
    }

    /// Allocation-free variant of [`PackedActs::quantize`]: writes into
    /// `out`, reusing its code buffer (grows it only when the capacity is
    /// insufficient). Bit-identical to `quantize`.
    pub fn quantize_into(x: &Mat, alpha: f32, bits: u32, out: &mut PackedActs) {
        PackedActs::quantize_slice_into(&x.data, x.rows, x.cols, alpha, bits, out);
    }

    /// [`PackedActs::quantize_into`] over a raw row-major slice — the
    /// workspace slots store activations as flat `Vec<f32>` buffers.
    pub fn quantize_slice_into(
        data: &[f32],
        rows: usize,
        cols: usize,
        alpha: f32,
        bits: u32,
        out: &mut PackedActs,
    ) {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        // reciprocal and clamp ceiling hoisted once per call (see
        // `code_map`) — the inner loop is multiply + clamp + round only
        let top = ((1u32 << bits) - 1) as f32;
        let inv = top / alpha;
        out.rows = rows;
        out.cols = cols;
        out.alpha = alpha;
        out.bits = bits;
        out.codes.clear();
        out.codes.extend(data.iter().map(|&v| code_map(v, inv, top)));
    }

    /// Stamp shape + quantization metadata after the code buffer has
    /// been filled externally (the integer-resident path writes codes
    /// straight into `codes` — u8 im2col from a code slot, or a plain
    /// copy for linear inputs — instead of quantizing floats).
    pub fn set_meta(&mut self, rows: usize, cols: usize, alpha: f32, bits: u32) {
        debug_assert_eq!(self.codes.len(), rows * cols, "codes/shape mismatch");
        self.rows = rows;
        self.cols = cols;
        self.alpha = alpha;
        self.bits = bits;
    }

    /// Fill from an existing code buffer (reusing `self.codes`'
    /// capacity): the integer-resident linear path, where the producing
    /// GEMM already wrote the consumer's codes row-major.
    pub fn copy_codes_into(
        codes: &[u8],
        rows: usize,
        cols: usize,
        alpha: f32,
        bits: u32,
        out: &mut PackedActs,
    ) {
        assert_eq!(codes.len(), rows * cols, "shape/code mismatch");
        out.codes.clear();
        out.codes.extend_from_slice(codes);
        out.set_meta(rows, cols, alpha, bits);
    }

    /// Dequantized float value of code `c`.
    #[inline]
    pub fn scale(&self) -> f32 {
        self.alpha / ((1u32 << self.bits) - 1) as f32
    }

    /// The kernel-facing view of the whole matrix (see [`ActsView`]).
    #[inline]
    pub fn view(&self) -> ActsView<'_> {
        ActsView {
            codes: &self.codes,
            rows: self.rows,
            cols: self.cols,
            alpha: self.alpha,
            bits: self.bits,
        }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    /// Dequantize back to float (for testing).
    pub fn dequant(&self) -> Mat {
        let s = self.scale();
        Mat::from_vec(
            self.rows,
            self.cols,
            self.codes.iter().map(|&c| c as f32 * s).collect(),
        )
    }
}

/// PoT weight code: `0` encodes zero; otherwise `sign * (shift + 1)` where
/// `shift = -exponent` in `0..=6` for 4-bit PoT. Fits in an i8 (and in the
/// 4-bit sign-magnitude field of the hardware).
#[inline]
pub fn pot_pack(sign: i32, exp: i32) -> i8 {
    if sign == 0 {
        0
    } else {
        (sign * (-exp + 1)) as i8
    }
}

/// Inverse of [`pot_pack`]: returns (sign, shift).
#[inline]
pub fn pot_unpack(code: i8) -> (i32, i32) {
    if code == 0 {
        (0, 0)
    } else {
        let sign = if code < 0 { -1 } else { 1 };
        (sign, code.unsigned_abs() as i32 - 1)
    }
}

/// One layer's weights in integer-code form with per-row metadata.
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub rows: usize,
    pub cols: usize,
    /// Row-major codes: Fixed rows hold the signed level index; PoT rows
    /// hold [`pot_pack`] codes. A [`Plane`]: owned on the quantize path,
    /// an aliased artifact section on the mapped load path.
    pub codes: Plane,
    /// PoT rows only: the per-weight shift realized as an i8 multiplier in
    /// the 2^6-scaled frame (`±2^(6-shift)`, in −64..=64). This is the
    /// weight register a LUT PE would hold after decoding its 4-bit code;
    /// precomputing it keeps the CPU inner loop branch-free and
    /// vectorizable. Zero-filled for non-PoT rows, and **empty** when the
    /// layer has no PoT rows at all — all-Fixed layers pay zero extra
    /// weight memory for it ([`PackedWeights::pot_mult_row`] must only be
    /// called for PoT rows).
    pub pot_mult: Plane,
    pub scheme: Vec<Scheme>,
    pub alpha: Vec<f32>,
}

impl PackedWeights {
    /// Quantize a float weight matrix given per-row scheme/alpha.
    pub fn quantize(w: &Mat, scheme: &[Scheme], alpha: &[f32]) -> PackedWeights {
        assert_eq!(w.rows, scheme.len());
        assert_eq!(w.rows, alpha.len());
        let mut codes = vec![0i8; w.rows * w.cols];
        // the multiplier plane only exists when some row needs it — an
        // all-Fixed layer would otherwise double its weight memory
        let mut pot_mult = if scheme.contains(&Scheme::PotW4A4) {
            vec![0i8; w.rows * w.cols]
        } else {
            Vec::new()
        };
        for r in 0..w.rows {
            let (a, s) = (alpha[r], scheme[r]);
            let src = w.row(r);
            let dst = &mut codes[r * w.cols..(r + 1) * w.cols];
            match s {
                Scheme::PotW4A4 => {
                    let mdst = &mut pot_mult[r * w.cols..(r + 1) * w.cols];
                    for ((d, m), &v) in dst.iter_mut().zip(mdst).zip(src) {
                        let (sg, e) = quant::pot_code(v, a, 4);
                        *d = pot_pack(sg, e);
                        // ±2^(6 - shift) with shift = -e in 0..=6
                        *m = (sg << (6 + e)) as i8;
                    }
                }
                Scheme::FixedW4A4 => {
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = quant::fixed_code(v, a, 4) as i8;
                    }
                }
                Scheme::FixedW8A4 => {
                    for (d, &v) in dst.iter_mut().zip(src) {
                        *d = quant::fixed_code(v, a, 8) as i8;
                    }
                }
                Scheme::ApotW4A4 => {
                    // Baseline scheme: stored as an 8-bit fixed *code* of the
                    // APoT-projected value (the APoT level grid is a subset
                    // of no uniform grid, so codes are synthesized via the
                    // dequant table in `mixed`). Here we store the level
                    // index with sign.
                    let q = quant::apot::ApotQuantizer::new(4);
                    for (d, &v) in dst.iter_mut().zip(src) {
                        let (sg, idx) = q.code(v, a);
                        *d = (sg * idx as i32) as i8;
                    }
                }
            }
        }
        PackedWeights {
            rows: w.rows,
            cols: w.cols,
            codes: Plane::owned(codes),
            pot_mult: Plane::owned(pot_mult),
            scheme: scheme.to_vec(),
            alpha: alpha.to_vec(),
        }
    }

    /// Assemble from already-quantized sections — the artifact load path,
    /// where `codes`/`pot_mult` alias mapped file ranges. Validates the
    /// section lengths against the shape so every later row slice is in
    /// bounds.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        codes: Plane,
        pot_mult: Plane,
        scheme: Vec<Scheme>,
        alpha: Vec<f32>,
    ) -> Result<PackedWeights> {
        let elems = rows
            .checked_mul(cols)
            .ok_or_else(|| crate::err!("weight shape {rows}x{cols} overflows"))?;
        ensure!(codes.len() == elems, "codes section holds {} of {elems} elements", codes.len());
        ensure!(scheme.len() == rows, "scheme holds {} of {rows} rows", scheme.len());
        ensure!(alpha.len() == rows, "alpha holds {} of {rows} rows", alpha.len());
        let want_mult = if scheme.contains(&Scheme::PotW4A4) { elems } else { 0 };
        ensure!(
            pot_mult.len() == want_mult,
            "pot_mult section holds {} of {want_mult} elements",
            pot_mult.len()
        );
        Ok(PackedWeights { rows, cols, codes, pot_mult, scheme, alpha })
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.codes[r * self.cols..(r + 1) * self.cols]
    }

    /// PoT multiplier row (see `pot_mult`). Panics if the layer has no
    /// PoT rows (the plane is not allocated then).
    #[inline]
    pub fn pot_mult_row(&self, r: usize) -> &[i8] {
        debug_assert_eq!(self.scheme[r], Scheme::PotW4A4, "pot_mult_row of a non-PoT row");
        &self.pot_mult[r * self.cols..(r + 1) * self.cols]
    }

    /// Dequantize row `r` to floats (testing / reference path).
    pub fn dequant_row(&self, r: usize) -> Vec<f32> {
        let a = self.alpha[r];
        match self.scheme[r] {
            Scheme::PotW4A4 => self
                .row(r)
                .iter()
                .map(|&c| {
                    let (s, shift) = pot_unpack(c);
                    a * s as f32 * (2.0f32).powi(-shift)
                })
                .collect(),
            Scheme::FixedW4A4 => self.row(r).iter().map(|&c| a * c as f32 / 7.0).collect(),
            Scheme::FixedW8A4 => self.row(r).iter().map(|&c| a * c as f32 / 127.0).collect(),
            Scheme::ApotW4A4 => {
                let q = quant::apot::ApotQuantizer::new(4);
                let lv = q.levels();
                self.row(r)
                    .iter()
                    .map(|&c| {
                        let sign = if c < 0 { -1.0 } else { 1.0 };
                        a * sign * lv[c.unsigned_abs() as usize]
                    })
                    .collect()
            }
        }
    }

    /// Full dequantized matrix (testing).
    pub fn dequant(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.dequant_row(r));
        }
        out
    }

    /// Total weight storage in bits (4b for PoT/Fixed4/APoT rows, 8b for
    /// Fixed8 rows) — the model-size numbers in EXPERIMENTS.md.
    pub fn storage_bits(&self) -> usize {
        self.scheme
            .iter()
            .map(|s| s.weight_bits() as usize * self.cols)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pot_pack_roundtrip() {
        for sign in [-1i32, 1] {
            for e in -6i32..=0 {
                let c = pot_pack(sign, e);
                let (s2, shift) = pot_unpack(c);
                assert_eq!(s2, sign);
                assert_eq!(shift, -e);
            }
        }
        assert_eq!(pot_unpack(pot_pack(0, 0)), (0, 0));
    }

    #[test]
    fn acts_dequant_error_bounded() {
        let x = Mat::from_vec(2, 3, vec![0.0, 0.3, 0.61, 0.99, 1.5, -0.2]);
        let p = PackedActs::quantize(&x, 1.0, 4);
        let d = p.dequant();
        for (orig, deq) in x.data.iter().zip(&d.data) {
            let clipped = orig.clamp(0.0, 1.0);
            assert!((clipped - deq).abs() <= 0.5 / 15.0 + 1e-6);
        }
    }

    #[test]
    fn packed_weights_match_fake_quant() {
        let w = Mat::from_rows(&[
            vec![0.9, -0.4, 0.1, 0.02],
            vec![0.9, -0.4, 0.1, 0.02],
            vec![0.9, -0.4, 0.1, 0.02],
        ]);
        let schemes = [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];
        let alpha = [1.0f32, 1.0, 1.0];
        let p = PackedWeights::quantize(&w, &schemes, &alpha);
        let fake = crate::quant::rowwise_quant(&w, &alpha, &schemes);
        assert!(p.dequant().max_abs_err(&fake) < 1e-6);
    }

    #[test]
    fn pot_mult_plane_only_allocated_when_pot_rows_exist() {
        let w = Mat::from_vec(2, 3, vec![0.5, -0.25, 1.0, 0.7, 0.0, -1.0]);
        let all_fixed =
            PackedWeights::quantize(&w, &[Scheme::FixedW4A4, Scheme::FixedW8A4], &[1.0; 2]);
        assert!(all_fixed.pot_mult.is_empty(), "all-Fixed layer allocated pot_mult");
        let mixed =
            PackedWeights::quantize(&w, &[Scheme::PotW4A4, Scheme::FixedW4A4], &[1.0; 2]);
        assert_eq!(mixed.pot_mult.len(), 2 * 3);
        assert!(mixed.pot_mult_row(0).iter().any(|&m| m != 0));
    }

    #[test]
    fn storage_accounting() {
        let w = Mat::zeros(4, 10);
        let schemes = [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4, Scheme::PotW4A4];
        let p = PackedWeights::quantize(&w, &schemes, &[1.0; 4]);
        assert_eq!(p.storage_bits(), 10 * (4 + 4 + 8 + 4));
    }
}
