//! Minimal dense row-major matrix/tensor types used across the crate.
//!
//! Built in-repo (offline build, no ndarray): just enough structure for the
//! quantizers, GEMM cores, im2col, and the executor — contiguous `Vec`
//! storage, explicit strides, zero-copy row views.

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Reshape in place, reusing the existing allocation whenever the
    /// capacity allows (the workspace path sizes matrices once and then
    /// `resize`s them per layer without touching the allocator). Newly
    /// exposed elements are zero; surviving elements keep their old
    /// values — callers are expected to overwrite every cell.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |v| v.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// `self @ other^T` — the natural layout for row-major weights.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "inner dims");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a = self.row(i);
            for j in 0..other.rows {
                let b = other.row(j);
                let mut s = 0.0f32;
                for k in 0..self.cols {
                    s += a[k] * b[k];
                }
                out.set(i, j, s);
            }
        }
        out
    }

    /// Per-row variance (population), used by the assignment engine.
    pub fn row_variances(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let n = row.len() as f32;
                let mean = row.iter().sum::<f32>() / n;
                row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n
            })
            .collect()
    }

    /// Per-row L2 norms (sensitivity proxy when no Hessian is available).
    pub fn row_norms(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|v| v * v).sum::<f32>().sqrt())
            .collect()
    }

    pub fn max_abs_err(&self, other: &Mat) -> f32 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

/// Dense i32 matrix (integer codes / accumulators).
#[derive(Clone, Debug, PartialEq)]
pub struct MatI32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl MatI32 {
    pub fn zeros(rows: usize, cols: usize) -> MatI32 {
        MatI32 { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i32>) -> MatI32 {
        assert_eq!(data.len(), rows * cols);
        MatI32 { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [i32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }
}

/// NCHW f32 tensor for the conv path.
#[derive(Clone, Debug)]
pub struct Tensor4 {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor4 {
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> Tensor4 {
        Tensor4 { n, c, h, w, data: vec![0.0; n * c * h * w] }
    }

    #[inline]
    pub fn idx(&self, n: usize, c: usize, y: usize, x: usize) -> usize {
        ((n * self.c + c) * self.h + y) * self.w + x
    }

    #[inline]
    pub fn at(&self, n: usize, c: usize, y: usize, x: usize) -> f32 {
        self.data[self.idx(n, c, y, x)]
    }

    #[inline]
    pub fn set(&mut self, n: usize, c: usize, y: usize, x: usize, v: f32) {
        let i = self.idx(n, c, y, x);
        self.data[i] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_roundtrip() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.at(0, 1), 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn matmul_nt_small() {
        // a = [[1,2],[3,4]], b = [[1,0],[0,1]] -> a @ b^T = a
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        assert_eq!(a.matmul_nt(&b), a);
    }

    #[test]
    fn row_variance_basic() {
        let m = Mat::from_rows(&[vec![1.0, 1.0, 1.0], vec![0.0, 3.0, 0.0]]);
        let v = m.row_variances();
        assert_eq!(v[0], 0.0);
        assert!(v[1] > 1.0);
    }

    #[test]
    fn tensor4_indexing() {
        let mut t = Tensor4::zeros(1, 2, 3, 3);
        t.set(0, 1, 2, 2, 5.0);
        assert_eq!(t.at(0, 1, 2, 2), 5.0);
        assert_eq!(t.data.iter().filter(|&&v| v != 0.0).count(), 1);
    }
}
