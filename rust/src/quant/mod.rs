//! Bit-exact quantizers (paper §3.1.1-§3.1.2, Eq. 1-5).
//!
//! Every function here mirrors a pure-jnp oracle in
//! `python/compile/kernels/ref.py`; the cross-language agreement is pinned
//! by the shared test vectors under `artifacts/testvec/` (see
//! `rust/tests/test_testvec.rs` and `python -m compile.testvec`).

pub mod apot;
pub mod fixed;
pub mod pot;
pub mod scheme;
pub mod tensor;

pub use apot::{apot_levels, apot_quant};
pub use fixed::{act_code, act_quant, fixed_code, fixed_quant};
pub use pot::{pot_code, pot_quant};
pub use scheme::{Ratio, Scheme};
pub use tensor::Mat;

/// Clip `w` into `[-1, 1]` in units of `alpha` (Eq. 3).
#[inline]
pub fn clip_scale(w: f32, alpha: f32) -> f32 {
    (w / alpha).clamp(-1.0, 1.0)
}

/// Per-row scaling factor: `max |w|` over the row (floored away from zero).
pub fn default_alpha(row: &[f32]) -> f32 {
    row.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8)
}

/// Row-wise mixed-scheme fake quantization of a row-major `(rows, cols)`
/// weight matrix — the Rust twin of `ref.rowwise_quant`.
pub fn rowwise_quant(w: &Mat, alpha: &[f32], scheme: &[Scheme]) -> Mat {
    assert_eq!(w.rows, alpha.len());
    assert_eq!(w.rows, scheme.len());
    let mut out = Mat::zeros(w.rows, w.cols);
    for r in 0..w.rows {
        let (a, s) = (alpha[r], scheme[r]);
        let src = w.row(r);
        let dst = out.row_mut(r);
        match s {
            Scheme::PotW4A4 => {
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = pot_quant(v, a, 4);
                }
            }
            Scheme::FixedW4A4 => {
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = fixed_quant(v, a, 4);
                }
            }
            Scheme::FixedW8A4 => {
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = fixed_quant(v, a, 8);
                }
            }
            Scheme::ApotW4A4 => {
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d = apot_quant(v, a, 4);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scale_bounds() {
        assert_eq!(clip_scale(10.0, 1.0), 1.0);
        assert_eq!(clip_scale(-10.0, 1.0), -1.0);
        assert_eq!(clip_scale(0.5, 1.0), 0.5);
        assert_eq!(clip_scale(0.5, 2.0), 0.25);
    }

    #[test]
    fn default_alpha_floor() {
        assert!(default_alpha(&[0.0, 0.0]) >= 1e-8);
        assert_eq!(default_alpha(&[-3.0, 2.0]), 3.0);
    }

    #[test]
    fn rowwise_dispatches_per_row() {
        let w = Mat::from_rows(&[vec![0.7, -0.3], vec![0.7, -0.3]]);
        let alpha = [1.0, 1.0];
        let q = rowwise_quant(&w, &alpha, &[Scheme::PotW4A4, Scheme::FixedW4A4]);
        // PoT rounds 0.7 -> 0.5 or 1.0 (log2 space); Fixed-4 -> 5/7.
        assert_eq!(q.row(0)[0], pot_quant(0.7, 1.0, 4));
        assert_eq!(q.row(1)[0], fixed_quant(0.7, 1.0, 4));
        assert_ne!(q.row(0)[0], q.row(1)[0]);
    }
}
