//! Scheme codes and the layer-wise-uniform ratio (paper §3.2).

use std::fmt;

/// Quantization scheme + precision of one weight row.
///
/// Codes 0-2 are the RMSMP classes executed by the heterogeneous GEMM
/// cores; code 3 (APoT) exists for the baseline methods of Tables 1/6.
/// The numeric values are shared with the Python side
/// (`compile/kernels/ref.py`) and the AOT manifest.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
#[repr(u8)]
pub enum Scheme {
    /// Power-of-Two weights, 4-bit; activations 4-bit Fixed. Multiplies
    /// become shifts (LUT fabric on the FPGA).
    PotW4A4 = 0,
    /// Fixed-point 4-bit weights/activations (DSP multipliers).
    FixedW4A4 = 1,
    /// Fixed-point 8-bit weights, 4-bit activations — the higher-precision
    /// class that absorbs the most sensitive 5% of rows.
    FixedW8A4 = 2,
    /// Additive-Power-of-Two 4-bit (baseline schemes only).
    ApotW4A4 = 3,
}

impl Scheme {
    /// All RMSMP classes (the ones the hardware kernel implements).
    pub const RMSMP: [Scheme; 3] = [Scheme::PotW4A4, Scheme::FixedW4A4, Scheme::FixedW8A4];

    /// Parse the shared numeric code.
    pub fn from_code(c: u8) -> Option<Scheme> {
        match c {
            0 => Some(Scheme::PotW4A4),
            1 => Some(Scheme::FixedW4A4),
            2 => Some(Scheme::FixedW8A4),
            3 => Some(Scheme::ApotW4A4),
            _ => None,
        }
    }

    /// Weight bit-width of this class.
    pub fn weight_bits(self) -> u32 {
        match self {
            Scheme::FixedW8A4 => 8,
            _ => 4,
        }
    }

    /// Whether the class multiplies via shift-add (no DSP multiplier).
    pub fn is_shift_based(self) -> bool {
        matches!(self, Scheme::PotW4A4 | Scheme::ApotW4A4)
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Scheme::PotW4A4 => "PoT-W4A4",
            Scheme::FixedW4A4 => "Fixed-W4A4",
            Scheme::FixedW8A4 => "Fixed-W8A4",
            Scheme::ApotW4A4 => "APoT-W4A4",
        };
        f.write_str(s)
    }
}

/// The offline-determined scheme ratio `PoT-4 : Fixed-4 : Fixed-8 = A:B:C`
/// (A+B+C = 100), identical across layers (layer-wise uniformality).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ratio {
    pub pot4: u32,
    pub fixed4: u32,
    pub fixed8: u32,
}

impl Ratio {
    /// The paper's optimal ratios: 60:35:5 on XC7Z020 (RMSMP-1) and
    /// 65:30:5 on XC7Z045 (RMSMP-2).
    pub const RMSMP1: Ratio = Ratio { pot4: 60, fixed4: 35, fixed8: 5 };
    pub const RMSMP2: Ratio = Ratio { pot4: 65, fixed4: 30, fixed8: 5 };

    pub fn new(pot4: u32, fixed4: u32, fixed8: u32) -> Ratio {
        assert_eq!(pot4 + fixed4 + fixed8, 100, "ratio must sum to 100");
        Ratio { pot4, fixed4, fixed8 }
    }

    /// Largest-remainder split of `rows` into exact per-class counts —
    /// must match `assignment.ratio_counts` on the Python side.
    pub fn counts(&self, rows: usize) -> (usize, usize, usize) {
        let shares = [self.pot4 as f64, self.fixed4 as f64, self.fixed8 as f64];
        let exact: Vec<f64> = shares.iter().map(|s| rows as f64 * s / 100.0).collect();
        let mut base: Vec<usize> = exact.iter().map(|e| e.floor() as usize).collect();
        let mut rem = rows - base.iter().sum::<usize>();
        let mut order: Vec<usize> = (0..3).collect();
        order.sort_by(|&i, &j| {
            (exact[j] - base[j] as f64)
                .partial_cmp(&(exact[i] - base[i] as f64))
                .unwrap()
        });
        for &i in &order {
            if rem == 0 {
                break;
            }
            base[i] += 1;
            rem -= 1;
        }
        (base[0], base[1], base[2])
    }

    /// Parse `"65:30:5"`.
    pub fn parse(s: &str) -> crate::util::error::Result<Ratio> {
        let parts: Vec<u32> = s
            .split(':')
            .map(|p| p.trim().parse::<u32>())
            .collect::<Result<_, _>>()
            .map_err(|e| crate::err!("bad ratio {s:?}: {e}"))?;
        crate::ensure!(parts.len() == 3, "ratio needs 3 parts, got {s:?}");
        crate::ensure!(parts.iter().sum::<u32>() == 100, "ratio must sum to 100");
        Ok(Ratio::new(parts[0], parts[1], parts[2]))
    }
}

impl fmt::Display for Ratio {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}", self.pot4, self.fixed4, self.fixed8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_sum_to_rows() {
        for rows in [1usize, 7, 20, 64, 100, 317] {
            let (a, b, c) = Ratio::RMSMP2.counts(rows);
            assert_eq!(a + b + c, rows, "rows={rows}");
        }
    }

    #[test]
    fn counts_exact_at_100() {
        assert_eq!(Ratio::RMSMP2.counts(100), (65, 30, 5));
        assert_eq!(Ratio::RMSMP1.counts(100), (60, 35, 5));
        assert_eq!(Ratio::new(50, 50, 0).counts(10), (5, 5, 0));
    }

    #[test]
    fn parse_roundtrip() {
        let r = Ratio::parse("65:30:5").unwrap();
        assert_eq!(r, Ratio::RMSMP2);
        assert_eq!(r.to_string(), "65:30:5");
        assert!(Ratio::parse("60:30:5").is_err());
        assert!(Ratio::parse("banana").is_err());
    }

    #[test]
    fn scheme_codes_shared_with_python() {
        assert_eq!(Scheme::from_code(0), Some(Scheme::PotW4A4));
        assert_eq!(Scheme::from_code(1), Some(Scheme::FixedW4A4));
        assert_eq!(Scheme::from_code(2), Some(Scheme::FixedW8A4));
        assert_eq!(Scheme::from_code(3), Some(Scheme::ApotW4A4));
        assert_eq!(Scheme::from_code(4), None);
    }

    #[test]
    fn scheme_properties() {
        assert!(Scheme::PotW4A4.is_shift_based());
        assert!(!Scheme::FixedW8A4.is_shift_based());
        assert_eq!(Scheme::FixedW8A4.weight_bits(), 8);
        assert_eq!(Scheme::PotW4A4.weight_bits(), 4);
    }
}
