//! Fixed-point quantizer (paper Eq. 1-3) — bit-exact with `ref.fixed_quant`.

use super::clip_scale;

/// Round-half-away-from-zero, matching `jnp.round`'s behaviour on the
/// grid values produced here (IEEE round-half-even differs only on exact
/// .5 ties; numpy rounds .5 to even as well, so we use the same rule).
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    // f32::round_ties_even is stable since 1.77
    x.round_ties_even()
}

/// Project `w` onto `Q^Fixed(m, alpha)` (Eq. 1-3): symmetric m-bit grid.
#[inline]
pub fn fixed_quant(w: f32, alpha: f32, m: u32) -> f32 {
    let n = ((1i64 << (m - 1)) - 1) as f32;
    let t = clip_scale(w, alpha);
    alpha * round_ties_even(t * n) / n
}

/// Integer weight code in `[-(2^{m-1}-1), +(2^{m-1}-1)]`.
#[inline]
pub fn fixed_code(w: f32, alpha: f32, m: u32) -> i32 {
    let n = ((1i64 << (m - 1)) - 1) as f32;
    round_ties_even(clip_scale(w, alpha) * n) as i32
}

/// Unsigned activation quantizer: m-bit Fixed over `[0, alpha]`.
#[inline]
pub fn act_quant(x: f32, alpha: f32, m: u32) -> f32 {
    let n = ((1i64 << m) - 1) as f32;
    let t = (x / alpha).clamp(0.0, 1.0);
    alpha * round_ties_even(t * n) / n
}

/// Unsigned activation code in `[0, 2^m - 1]`.
#[inline]
pub fn act_code(x: f32, alpha: f32, m: u32) -> i32 {
    let n = ((1i64 << m) - 1) as f32;
    round_ties_even((x / alpha).clamp(0.0, 1.0) * n) as i32
}

/// Signed activation code (transformer path): `[-(2^{m-1}-1), 2^{m-1}-1]`.
#[inline]
pub fn act_code_signed(x: f32, alpha: f32, m: u32) -> i32 {
    fixed_code(x, alpha, m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_endpoints() {
        assert_eq!(fixed_quant(1.0, 1.0, 4), 1.0);
        assert_eq!(fixed_quant(-1.0, 1.0, 4), -1.0);
        assert_eq!(fixed_quant(0.0, 1.0, 4), 0.0);
        assert_eq!(fixed_quant(5.0, 1.0, 4), 1.0); // clipped
    }

    #[test]
    fn four_bit_levels() {
        // 4-bit symmetric grid: k/7 for k in -7..=7
        for k in -7i32..=7 {
            let v = k as f32 / 7.0;
            assert!((fixed_quant(v, 1.0, 4) - v).abs() < 1e-7);
            assert_eq!(fixed_code(v, 1.0, 4), k);
        }
    }

    #[test]
    fn error_bound_half_step() {
        let step = 1.0 / 7.0;
        for i in 0..1000 {
            let w = -1.0 + 2.0 * (i as f32) / 999.0;
            let q = fixed_quant(w, 1.0, 4);
            assert!((w - q).abs() <= step / 2.0 + 1e-6, "w={w} q={q}");
        }
    }

    #[test]
    fn code_roundtrip() {
        for i in 0..100 {
            let w = -1.5 + 3.0 * (i as f32) / 99.0;
            let c = fixed_code(w, 1.2, 8);
            let q = fixed_quant(w, 1.2, 8);
            assert!((1.2 * c as f32 / 127.0 - q).abs() < 1e-6);
        }
    }

    #[test]
    fn act_unsigned_range() {
        assert_eq!(act_quant(-0.5, 1.0, 4), 0.0);
        assert_eq!(act_quant(2.0, 1.0, 4), 1.0);
        assert_eq!(act_code(2.0, 1.0, 4), 15);
        assert_eq!(act_code(-1.0, 1.0, 4), 0);
    }

    #[test]
    fn scale_equivariance() {
        for i in 0..50 {
            let w = -1.0 + 2.0 * (i as f32) / 49.0;
            let a = fixed_quant(2.0 * w, 2.0 * 1.1, 4);
            let b = 2.0 * fixed_quant(w, 1.1, 4);
            assert!((a - b).abs() < 1e-6);
        }
    }
}
