//! Additive-Power-of-Two quantizer (Li et al. 2020) — baseline scheme.
//!
//! Used by the Table 1 / Table 6 baseline methods (APoT-W4A4 and the
//! MSQ-style APoT+Fixed mixes); mirrors `ref.apot_quant`.

use super::clip_scale;

/// Nonnegative APoT levels for m bits, max-normalized (mirrors
/// `ref.apot_levels`). For m = 4: 2-bit term {0, 1, 2^-2, 2^-4} + 1-bit
/// term {0, 2^-1} -> 8 distinct sums.
pub fn apot_levels(m: u32) -> Vec<f32> {
    if m <= 2 {
        return vec![0.0, 1.0];
    }
    let (p0, p1): (Vec<f32>, Vec<f32>) = if m == 4 {
        (
            vec![0.0, 1.0, 0.25, 0.0625],
            vec![0.0, 0.5],
        )
    } else {
        let b0 = m / 2; // == (m-1+1)/2
        let b1 = (m - 1) - b0;
        let mut g0 = vec![0.0f32];
        for i in 0..(1u32 << b0) - 1 {
            g0.push((2.0f32).powi(-(2 * i as i32)));
        }
        let mut g1 = vec![0.0f32];
        for i in 0..(1u32 << b1) - 1 {
            g1.push((2.0f32).powi(-(2 * i as i32 + 1)));
        }
        (g0, g1)
    };
    let mut lv: Vec<f32> = p0
        .iter()
        .flat_map(|a| p1.iter().map(move |b| a + b))
        .collect();
    lv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lv.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
    let max = *lv.last().unwrap();
    lv.iter().map(|v| v / max).collect()
}

/// Project onto the nearest of `±alpha * levels`.
pub fn project_levels(w: f32, alpha: f32, levels: &[f32]) -> f32 {
    let t = clip_scale(w, alpha);
    let mag = t.abs();
    let mut best = levels[0];
    let mut err = (mag - best).abs();
    for &lv in &levels[1..] {
        let e = (mag - lv).abs();
        if e < err {
            err = e;
            best = lv;
        }
    }
    alpha * t.signum() * best
}

/// APoT fake quant (allocates the level table per call; use
/// [`ApotQuantizer`] in hot loops).
pub fn apot_quant(w: f32, alpha: f32, m: u32) -> f32 {
    project_levels(w, alpha, &apot_levels(m))
}

/// Reusable APoT quantizer with a precomputed level table.
pub struct ApotQuantizer {
    levels: Vec<f32>,
}

impl ApotQuantizer {
    pub fn new(m: u32) -> ApotQuantizer {
        ApotQuantizer { levels: apot_levels(m) }
    }

    #[inline]
    pub fn quant(&self, w: f32, alpha: f32) -> f32 {
        project_levels(w, alpha, &self.levels)
    }

    /// Level index code (sign stored separately by the caller).
    pub fn code(&self, w: f32, alpha: f32) -> (i32, usize) {
        let t = clip_scale(w, alpha);
        let mag = t.abs();
        let mut best = 0usize;
        let mut err = f32::MAX;
        for (i, &lv) in self.levels.iter().enumerate() {
            let e = (mag - lv).abs();
            if e < err {
                err = e;
                best = i;
            }
        }
        (t.signum() as i32, best)
    }

    pub fn levels(&self) -> &[f32] {
        &self.levels
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_bit_levels_count_and_range() {
        let lv = apot_levels(4);
        assert_eq!(lv.len(), 8);
        assert_eq!(lv[0], 0.0);
        assert_eq!(*lv.last().unwrap(), 1.0);
        for w in lv.windows(2) {
            assert!(w[0] < w[1], "levels must be strictly increasing");
        }
    }

    #[test]
    fn denser_than_pot_at_tail() {
        // second-largest APoT level > second-largest PoT level (0.5)
        let lv = apot_levels(4);
        assert!(lv[lv.len() - 2] > 0.5);
    }

    #[test]
    fn idempotent() {
        let q = ApotQuantizer::new(4);
        for i in 0..200 {
            let w = -1.0 + 2.0 * (i as f32) / 199.0;
            let q1 = q.quant(w, 1.0);
            assert!((q.quant(q1, 1.0) - q1).abs() < 1e-7);
        }
    }

    #[test]
    fn projection_is_nearest() {
        let q = ApotQuantizer::new(4);
        let lv = q.levels().to_vec();
        // midpoint between two levels must go to one of them
        let w = (lv[3] + lv[4]) / 2.0 + 1e-4;
        assert_eq!(q.quant(w, 1.0), lv[4]);
    }

    #[test]
    fn code_identifies_level() {
        let q = ApotQuantizer::new(4);
        let (s, i) = q.code(-0.6, 1.0);
        assert_eq!(s, -1);
        assert!((q.levels()[i] - q.quant(-0.6, 1.0).abs()).abs() < 1e-6);
    }
}
