//! Power-of-Two quantizer (paper Eq. 4-5) — bit-exact with `ref.pot_quant`.

use super::clip_scale;
use super::fixed::round_ties_even;

/// Smallest exponent magnitude for m-bit PoT: `k = 2^{m-1} - 2`.
#[inline]
pub fn pot_min_exp(m: u32) -> i32 {
    (1i32 << (m - 1)) - 2
}

/// Project `w` onto `Q^PoT(m, alpha)` (Eq. 4-5): nearest power of two in
/// log2 space; magnitudes below half the smallest level snap to 0.
#[inline]
pub fn pot_quant(w: f32, alpha: f32, m: u32) -> f32 {
    let k = pot_min_exp(m);
    let t = clip_scale(w, alpha);
    let mag = t.abs();
    let min_level = (2.0f32).powi(-k);
    if mag < min_level / 2.0 {
        return 0.0;
    }
    let safe = mag.max((2.0f32).powi(-k - 4));
    let e = round_ties_even(safe.log2()).clamp(-(k as f32), 0.0);
    alpha * t.signum() * (2.0f32).powf(e)
}

/// `(sign, exponent)` code: sign in {-1, 0, +1}, exponent in `[-k, 0]`.
/// Hardware stores the sign bit plus the shift amount `s = -e`.
#[inline]
pub fn pot_code(w: f32, alpha: f32, m: u32) -> (i32, i32) {
    let k = pot_min_exp(m);
    let t = clip_scale(w, alpha);
    let mag = t.abs();
    let min_level = (2.0f32).powi(-k);
    if mag < min_level / 2.0 {
        return (0, 0);
    }
    let safe = mag.max((2.0f32).powi(-k - 4));
    let e = round_ties_even(safe.log2()).clamp(-(k as f32), 0.0) as i32;
    (t.signum() as i32, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_exp_values() {
        assert_eq!(pot_min_exp(4), 6); // levels 2^-6 .. 2^0
        assert_eq!(pot_min_exp(3), 2);
    }

    #[test]
    fn levels_are_powers_of_two() {
        for i in 0..2000 {
            let w = -1.0 + 2.0 * (i as f32) / 1999.0;
            let q = pot_quant(w, 1.0, 4);
            if q != 0.0 {
                let e = q.abs().log2();
                assert!((e - e.round()).abs() < 1e-6, "q={q} not PoT");
                assert!((-6.0..=0.0).contains(&e));
            }
        }
    }

    #[test]
    fn zero_basin() {
        // below half of 2^-6 -> 0
        assert_eq!(pot_quant(2.0f32.powi(-6) * 0.49, 1.0, 4), 0.0);
        assert_ne!(pot_quant(2.0f32.powi(-6) * 0.51, 1.0, 4), 0.0);
    }

    #[test]
    fn rigid_resolution() {
        // 0.75 rounds to 2^0 at every bit-width (the paper's §2.1.2 point):
        // log2(0.75) = -0.415 -> rounds to 0 -> level 1.0.
        assert_eq!(pot_quant(0.75, 1.0, 4), 1.0);
        assert_eq!(pot_quant(0.75, 1.0, 8), 1.0);
    }

    #[test]
    fn code_roundtrip() {
        for i in 0..500 {
            let w = -1.2 + 2.4 * (i as f32) / 499.0;
            let (s, e) = pot_code(w, 0.8, 4);
            let recon = 0.8 * s as f32 * (2.0f32).powi(e);
            assert!((recon - pot_quant(w, 0.8, 4)).abs() < 1e-6);
        }
    }

    #[test]
    fn idempotent() {
        for i in 0..200 {
            let w = -1.0 + 2.0 * (i as f32) / 199.0;
            let q1 = pot_quant(w, 1.0, 4);
            let q2 = pot_quant(q1, 1.0, 4);
            assert!((q1 - q2).abs() < 1e-7);
        }
    }
}
