//! Row-wise scheme/precision assignment engine (paper Alg. 1, lines 2-14).
//!
//! The Rust twin of `python/compile/assignment.py`: given per-row
//! sensitivity scores (Hessian eigenvalue/trace estimates from the L2
//! artifacts, or the weight-norm proxy) and the layer's weight rows, it
//! produces scheme codes honouring the layer-wise-uniform A:B:C ratio
//! exactly. Used at artifact-load time to re-derive / validate the
//! manifest's assignment, and by `rmsmp assign` to re-quantize weights
//! under a different ratio without touching Python.

use crate::quant::{Mat, Ratio, Scheme};

/// Sensitivity source for the Fixed-W8A4 (top-C%) selection.
#[derive(Clone, Debug)]
pub enum Sensitivity<'a> {
    /// Per-row Hessian max-eigenvalue / block-trace estimates (from L2).
    Hessian(&'a [f32]),
    /// Zeroth-order proxy: per-row weight L2 norm.
    WeightNorm,
}

/// Assign schemes for one layer.
///
/// 1. top-C% rows by sensitivity -> Fixed-W8A4
/// 2. of the rest, the A/(A+B) lowest-variance rows -> `nonlinear`
/// 3. remainder -> Fixed-W4A4
pub fn assign_layer(
    w: &Mat,
    ratio: Ratio,
    sens: Sensitivity<'_>,
    nonlinear: Scheme,
) -> Vec<Scheme> {
    let rows = w.rows;
    let (na, _nb, nc) = ratio.counts(rows);

    let scores: Vec<f32> = match sens {
        Sensitivity::Hessian(s) => {
            assert_eq!(s.len(), rows, "sensitivity length");
            s.to_vec()
        }
        Sensitivity::WeightNorm => w.row_norms(),
    };

    let mut scheme = vec![Scheme::FixedW4A4; rows];

    // 1. top-C% most sensitive rows — stable sort descending.
    let mut by_sens: Vec<usize> = (0..rows).collect();
    by_sens.sort_by(|&i, &j| scores[j].partial_cmp(&scores[i]).unwrap().then(i.cmp(&j)));
    let hi: Vec<usize> = by_sens[..nc].to_vec();
    for &r in &hi {
        scheme[r] = Scheme::FixedW8A4;
    }

    // 2. remaining rows by ascending variance -> nonlinear class.
    let var = w.row_variances();
    let mut rest: Vec<usize> = (0..rows).filter(|r| !hi.contains(r)).collect();
    rest.sort_by(|&i, &j| var[i].partial_cmp(&var[j]).unwrap().then(i.cmp(&j)));
    for &r in rest.iter().take(na) {
        scheme[r] = nonlinear;
    }
    scheme
}

/// Verify a scheme vector matches the ratio exactly (layer-wise
/// uniformality check used at artifact load).
pub fn validate_ratio(schemes: &[Scheme], ratio: Ratio) -> Result<(), String> {
    let (na, nb, nc) = ratio.counts(schemes.len());
    let a = schemes.iter().filter(|s| s.is_shift_based()).count();
    let b = schemes.iter().filter(|&&s| s == Scheme::FixedW4A4).count();
    let c = schemes.iter().filter(|&&s| s == Scheme::FixedW8A4).count();
    if (a, b, c) != (na, nb, nc) {
        return Err(format!(
            "scheme counts ({a},{b},{c}) != ratio {ratio} counts ({na},{nb},{nc}) for {} rows",
            schemes.len()
        ));
    }
    Ok(())
}

/// Equivalent weight precision (bits/weight) of an assignment — the
/// paper's "W4A4*" accounting.
pub fn equivalent_bits(schemes: &[Scheme], cols: usize) -> f64 {
    if schemes.is_empty() {
        return 0.0;
    }
    let bits: usize = schemes
        .iter()
        .map(|s| s.weight_bits() as usize * cols)
        .sum();
    bits as f64 / (schemes.len() * cols) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal() * 0.5).collect())
    }

    #[test]
    fn ratio_exact() {
        let w = rand_mat(100, 32, 1);
        let s = assign_layer(&w, Ratio::RMSMP2, Sensitivity::WeightNorm, Scheme::PotW4A4);
        assert!(validate_ratio(&s, Ratio::RMSMP2).is_ok());
        assert_eq!(s.iter().filter(|&&x| x == Scheme::FixedW8A4).count(), 5);
        assert_eq!(s.iter().filter(|&&x| x == Scheme::PotW4A4).count(), 65);
    }

    #[test]
    fn hessian_rows_get_high_precision() {
        let w = rand_mat(20, 8, 2);
        let mut sens = vec![0.0f32; 20];
        sens[3] = 10.0; // most sensitive row
        let s = assign_layer(&w, Ratio::RMSMP2, Sensitivity::Hessian(&sens), Scheme::PotW4A4);
        assert_eq!(s[3], Scheme::FixedW8A4);
        assert!(validate_ratio(&s, Ratio::RMSMP2).is_ok());
    }

    #[test]
    fn low_variance_rows_become_pot() {
        // Row 0 constant (variance 0) must land in the PoT class.
        let mut w = rand_mat(10, 16, 3);
        for v in w.row_mut(0) {
            *v = 0.2;
        }
        let s = assign_layer(&w, Ratio::new(50, 50, 0), Sensitivity::WeightNorm, Scheme::PotW4A4);
        assert_eq!(s[0], Scheme::PotW4A4);
    }

    #[test]
    fn nonlinear_class_is_configurable() {
        let w = rand_mat(10, 8, 4);
        let s = assign_layer(&w, Ratio::new(60, 40, 0), Sensitivity::WeightNorm, Scheme::ApotW4A4);
        assert_eq!(s.iter().filter(|&&x| x == Scheme::ApotW4A4).count(), 6);
    }

    #[test]
    fn validate_rejects_wrong_mix() {
        let schemes = vec![Scheme::FixedW4A4; 10];
        assert!(validate_ratio(&schemes, Ratio::RMSMP2).is_err());
        assert!(validate_ratio(&schemes, Ratio::new(0, 100, 0)).is_ok());
    }

    #[test]
    fn equivalent_bits_accounting() {
        let s = vec![
            Scheme::PotW4A4,
            Scheme::FixedW4A4,
            Scheme::FixedW8A4,
            Scheme::FixedW4A4,
        ];
        // (4+4+8+4)/4 = 5 bits
        assert!((equivalent_bits(&s, 16) - 5.0).abs() < 1e-12);
    }
}
