//! Minimal error type + context plumbing (offline build — no `anyhow`).
//!
//! The crate is zero-dependency, so the ergonomic error surface the code
//! was written against (`err!`, `bail!`, `ensure!`, `.context(..)`) is
//! provided here: a single string-backed [`Error`], a [`Result`] alias
//! with a defaulted error parameter, and a [`Context`] extension trait
//! for both `Result` and `Option`.

use std::fmt;

/// String-backed error. Context wraps are joined as `outer: inner`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }

    /// Wrap with an outer context message.
    pub fn context(self, outer: impl fmt::Display) -> Error {
        Error { msg: format!("{outer}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug mirrors Display so `fn main() -> Result<()>` prints readable
// messages through the `Termination` impl.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like `anyhow::Error`, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps this blanket conversion coherent
// (no overlap with the reflexive `From<Error> for Error`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result alias; the error parameter defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;

    /// Wrap the error with a lazily-built message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad layer {name}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 7);
    }

    #[test]
    fn macros_build_messages() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
        let e: Error = err!("x = {}", 1);
        assert_eq!(e.to_string(), "x = 1");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            Ok(n)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(check(30).unwrap_err().to_string(), "n too big: 30");
    }

    #[test]
    fn context_wraps_results_and_options() {
        let r: Result<(), std::num::ParseIntError> = "x".parse::<i32>().map(|_| ());
        let e = r.context("parsing flag").unwrap_err();
        assert!(e.to_string().starts_with("parsing flag: "), "{e}");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn from_std_errors() {
        fn io_fail() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/path")?)
        }
        assert!(io_fail().is_err());
    }
}
