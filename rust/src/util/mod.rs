//! In-repo substrates (the build is fully offline, so everything a crate
//! would normally pull in is implemented here):
//!
//! * [`rng`] — deterministic SplitMix64/xoshiro256** PRNG with normal /
//!   uniform helpers (no `rand`).
//! * [`json`] — a small recursive-descent JSON parser + writer for the AOT
//!   manifest and experiment outputs (no `serde`).
//! * [`cli`] — flag parsing for the `rmsmp` binary (no `clap`).
//! * [`stats`] — streaming mean/percentile accumulators for metrics.
//! * [`bench`] — the measurement harness behind `cargo bench`
//!   (no `criterion`): warmup, adaptive iteration, median/MAD reporting,
//!   JSON emission for the CI bench-regression artifacts.
//! * [`prop`] — a property-testing mini-framework (no `proptest`):
//!   seeded generators + failure-case reporting.
//! * [`pool`] — a fixed-size thread pool for the coordinator workers and
//!   the scoped parallel-for that drives the parallel mixed GEMM.
//! * [`error`] — string-backed error type + `err!`/`bail!`/`ensure!`
//!   macros and a `Context` trait (no `anyhow`).
//! * [`mmap`] — raw-syscall `mmap(2)` file mapping (aligned-read
//!   fallback) and the owned-or-mapped [`mmap::Plane`] i8 sections the
//!   artifact loader aliases into (no `memmap2`).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod mmap;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
