//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Offline build — no `rand` crate. The sequences are stable across
//! platforms, which the property tests and workload generators rely on.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal deviate (Box-Muller produces pairs)
    spare: Option<f32>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn unit(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.unit()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift reduction (bias negligible for our n)
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.unit();
            let v = self.unit();
            if u <= f32::EPSILON {
                continue;
            }
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f32::consts::PI * v;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a vec of standard normals.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential with the given rate (Poisson inter-arrivals for the
    /// serving workload generator).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = (self.unit() as f64).max(1e-12);
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn unit_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.unit();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.02, "mean {m}");
    }
}
