//! Minimal JSON parser + writer (offline build — no serde).
//!
//! Supports the full JSON grammar minus exotic escapes; enough for the AOT
//! manifest (`artifacts/manifest.json`), the shared test vectors, and the
//! experiment result files. Numbers parse to f64; helpers extract typed
//! fields with contextual errors.
//!
//! Two parsing fronts share the grammar:
//!
//! * [`Json::parse`] builds a full tree — right for config files read
//!   once at load time.
//! * The `lazy_*` scanners extract individual top-level fields straight
//!   from the byte stream without building a tree — right for the HTTP
//!   request hot path, where a body is dominated by one large `input`
//!   array and allocating a `Json::Num` per element (plus a `BTreeMap`
//!   node per key) costs far more than the scan itself.
//!   [`lazy_f32_array`] parses the array directly into a caller-owned
//!   `Vec<f32>`; [`lazy_str`] / [`lazy_f64`] skip unrelated values
//!   (strings, nested containers) byte-wise with no allocation.
//!   Lazy scanning validates only what it walks over: bytes after the
//!   last extracted field are never touched, so a body malformed *past*
//!   every requested key can still be accepted — the tradeoff that
//!   makes partial extraction cheap.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::bail;
use crate::err;
use crate::util::error::{Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| err!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// `[1, 2, 3]` -> Vec<f32>.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|n| n as f32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn load(path: &std::path::Path) -> Result<Json> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&src).with_context(|| format!("parsing {}", path.display()))
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| err!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // continue multibyte UTF-8 sequences verbatim
                    let len = utf8_len(c);
                    out.push_str(std::str::from_utf8(&self.b[self.i - 1..self.i - 1 + len])?);
                    self.i += len - 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.i, c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Convenience constructors for building result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr_f32(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

// ---- lazy field scanning (no tree) -------------------------------------

/// Byte-wise value skipper for the lazy scanners: moves over one JSON
/// value (string, number, literal, or arbitrarily nested container)
/// without decoding it.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scan<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| err!("unexpected end of input at byte {}", self.i))
    }

    /// Raw bytes between the quotes of a string (escapes left encoded).
    fn raw_string(&mut self) -> Result<&'a [u8]> {
        if self.peek()? != b'"' {
            bail!("expected string at byte {}", self.i);
        }
        self.i += 1;
        let start = self.i;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'\\' => self.i += 2,
                b'"' => {
                    let raw = &self.b[start..self.i];
                    self.i += 1;
                    return Ok(raw);
                }
                _ => self.i += 1,
            }
        }
        bail!("unterminated string at byte {start}")
    }

    fn skip_value(&mut self) -> Result<()> {
        match self.peek()? {
            b'"' => {
                self.raw_string()?;
            }
            b'{' | b'[' => self.skip_container()?,
            b'0'..=b'9' | b'-' | b'+' | b'.' => {
                while self.i < self.b.len()
                    && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    self.i += 1;
                }
            }
            b't' | b'f' | b'n' => {
                while self.i < self.b.len() && self.b[self.i].is_ascii_alphabetic() {
                    self.i += 1;
                }
            }
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
        Ok(())
    }

    /// Skip a container by depth counting; strings inside are skipped
    /// whole so braces in string data cannot unbalance the count.
    fn skip_container(&mut self) -> Result<()> {
        let mut depth = 0usize;
        loop {
            match self.peek()? {
                b'"' => {
                    self.raw_string()?;
                }
                b'{' | b'[' => {
                    depth += 1;
                    self.i += 1;
                }
                b'}' | b']' => {
                    depth -= 1;
                    self.i += 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                _ => self.i += 1,
            }
        }
    }
}

/// Scan a top-level JSON object for `key` and return its raw value bytes
/// (still encoded). `Ok(None)` = well-formed object without the key.
/// Values before the key are skipped byte-wise, values after it are
/// never visited.
pub fn lazy_find<'a>(body: &'a [u8], key: &str) -> Result<Option<&'a [u8]>> {
    let mut s = Scan { b: body, i: 0 };
    s.ws();
    if s.peek()? != b'{' {
        bail!("not a JSON object");
    }
    s.i += 1;
    s.ws();
    if s.peek()? == b'}' {
        return Ok(None);
    }
    loop {
        s.ws();
        let raw_key = s.raw_string()?;
        s.ws();
        if s.peek()? != b':' {
            bail!("expected ':' at byte {}", s.i);
        }
        s.i += 1;
        s.ws();
        let start = s.i;
        s.skip_value()?;
        // escaped keys never match (request field names are plain ASCII)
        if raw_key == key.as_bytes() {
            return Ok(Some(&body[start..s.i]));
        }
        s.ws();
        match s.peek()? {
            b',' => s.i += 1,
            b'}' => return Ok(None),
            c => bail!("expected ',' or '}}' at byte {}, found {:?}", s.i, c as char),
        }
    }
}

/// Extract a top-level string field without parsing the rest of the body.
pub fn lazy_str(body: &[u8], key: &str) -> Result<Option<String>> {
    let Some(raw) = lazy_find(body, key)? else {
        return Ok(None);
    };
    if raw == b"null" {
        return Ok(None);
    }
    let mut p = Parser { b: raw, i: 0 };
    let s = p.string().with_context(|| format!("field {key:?}"))?;
    Ok(Some(s))
}

/// Extract a top-level numeric field without parsing the rest of the body.
pub fn lazy_f64(body: &[u8], key: &str) -> Result<Option<f64>> {
    let Some(raw) = lazy_find(body, key)? else {
        return Ok(None);
    };
    if raw == b"null" {
        return Ok(None);
    }
    let s = std::str::from_utf8(raw)?;
    Ok(Some(
        s.parse::<f64>().with_context(|| format!("field {key:?}: bad number {s:?}"))?,
    ))
}

/// Parse a top-level numeric-array field straight into `out` (cleared
/// first, capacity reused) — no per-element tree nodes. Returns `false`
/// if the key is absent.
pub fn lazy_f32_array(body: &[u8], key: &str, out: &mut Vec<f32>) -> Result<bool> {
    out.clear();
    let Some(raw) = lazy_find(body, key)? else {
        return Ok(false);
    };
    let mut s = Scan { b: raw, i: 0 };
    if s.peek()? != b'[' {
        bail!("field {key:?}: not an array");
    }
    s.i += 1;
    s.ws();
    if s.peek()? == b']' {
        return Ok(true);
    }
    loop {
        s.ws();
        let start = s.i;
        while s.i < s.b.len()
            && matches!(s.b[s.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            s.i += 1;
        }
        let num = std::str::from_utf8(&s.b[start..s.i])?;
        out.push(
            num.parse::<f32>()
                .with_context(|| format!("field {key:?}[{}]: bad number {num:?}", out.len()))?,
        );
        s.ws();
        match s.peek()? {
            b',' => s.i += 1,
            b']' => return Ok(true),
            c => bail!(
                "field {key:?}: expected ',' or ']' at byte {}, found {:?}",
                s.i,
                c as char
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":"resnet18","layers":[{"rows":16,"cols":27}],"ratio":[65,30,5],"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j, Json::Str("café ☕".into()));
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "v": [1.5, 2.5]}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("v").unwrap().as_f32_vec().unwrap(), vec![1.5, 2.5]);
        assert!(j.get("missing").is_err());
        assert!(j.get("n").unwrap().as_str().is_err());
    }

    #[test]
    fn lazy_extracts_fields_without_tree() {
        let body = br#"{ "model": "resnet18", "deadline_ms": 12.5,
                         "input": [0.25, -1.5, 3e2], "extra": {"input": [9]} }"#;
        assert_eq!(lazy_str(body, "model").unwrap().unwrap(), "resnet18");
        assert_eq!(lazy_f64(body, "deadline_ms").unwrap().unwrap(), 12.5);
        let mut v = Vec::new();
        assert!(lazy_f32_array(body, "input", &mut v).unwrap());
        assert_eq!(v, vec![0.25, -1.5, 300.0]);
        assert!(lazy_str(body, "missing").unwrap().is_none());
        assert!(lazy_f64(body, "missing").unwrap().is_none());
        assert!(!lazy_f32_array(body, "missing", &mut v).unwrap());
        assert!(v.is_empty(), "absent key clears the output");
    }

    #[test]
    fn lazy_matches_top_level_only() {
        // a nested "model" must not shadow (or be shadowed by) top level
        let body = br#"{"a": {"model": "inner"}, "model": "outer", "b": [{"model": 1}]}"#;
        assert_eq!(lazy_str(body, "model").unwrap().unwrap(), "outer");
        // braces inside string data must not unbalance the skipper
        let tricky = br#"{"a": "s}{ll\" }", "n": 7}"#;
        assert_eq!(lazy_f64(tricky, "n").unwrap().unwrap(), 7.0);
    }

    #[test]
    fn lazy_agrees_with_tree_parser() {
        let body = br#"{"model":"m\n1","deadline_ms":3,"input":[1,2.5,-0.125,1e-3]}"#;
        let tree = Json::parse(std::str::from_utf8(body).unwrap()).unwrap();
        assert_eq!(
            lazy_str(body, "model").unwrap().unwrap(),
            tree.get("model").unwrap().as_str().unwrap()
        );
        let mut v = Vec::new();
        lazy_f32_array(body, "input", &mut v).unwrap();
        assert_eq!(v, tree.get("input").unwrap().as_f32_vec().unwrap());
    }

    #[test]
    fn lazy_rejects_malformed() {
        assert!(lazy_find(b"[1,2]", "k").is_err(), "not an object");
        assert!(lazy_find(b"{\"a\": ", "a").is_err(), "truncated value");
        assert!(lazy_find(br#"{"a": [1,2"#, "b").is_err(), "unclosed array");
        let mut v = Vec::new();
        assert!(lazy_f32_array(br#"{"x": [1, "s"]}"#, "x", &mut v).is_err());
        assert!(lazy_f32_array(br#"{"x": 3}"#, "x", &mut v).is_err());
        // null-valued optional fields read as absent
        assert!(lazy_str(br#"{"model": null}"#, "model").unwrap().is_none());
        assert!(lazy_f64(br#"{"deadline_ms": null}"#, "deadline_ms").unwrap().is_none());
    }
}
