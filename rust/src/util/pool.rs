//! Fixed-size thread pool (offline build — no tokio/rayon).
//!
//! The coordinator's worker threads and the batch executor run on this.
//! Jobs are boxed closures over an MPMC channel built from
//! `Mutex<VecDeque>` + `Condvar`; shutdown drains gracefully.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rmsmp-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Enqueue a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        assert!(
            !self.shared.shutdown.load(Ordering::SeqCst),
            "pool is shut down"
        );
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of jobs and wait for all of them (scoped join).
    pub fn scoped<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        let n = jobs.len();
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for job in jobs {
            let done = Arc::clone(&done);
            self.execute(move || {
                job();
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (lock, cv) = &*done;
        let mut g = lock.lock().unwrap();
        while *g < n {
            g = cv.wait(g).unwrap();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.scoped(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_drains_gracefully() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop here: must finish queued work before joining
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let c = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&c);
        pool.scoped(vec![move || {
            c2.fetch_add(7, Ordering::SeqCst);
        }]);
        assert_eq!(c.load(Ordering::SeqCst), 7);
    }
}
