//! Fixed-size thread pool + scoped parallel-for (offline build — no
//! tokio/rayon).
//!
//! Two layers:
//!
//! * [`ThreadPool::execute`] / [`ThreadPool::scoped`] — boxed `'static`
//!   jobs over an MPMC channel built from `Mutex<VecDeque>` + `Condvar`;
//!   the coordinator's worker threads run on this. Shutdown drains
//!   gracefully.
//! * [`ThreadPool::scoped_for`] / [`ThreadPool::parallel_chunks`] — a
//!   scoped parallel-for over an index space for *borrowed* closures (the
//!   parallel mixed GEMM's substrate). Tasks are pulled from a shared
//!   atomic cursor, so fast workers steal the remaining tail from slow
//!   ones instead of convoying on a static split; the calling thread
//!   participates in the drain, and the call does not return until every
//!   enqueued helper has finished (which is what makes the borrow sound).
//!
//! `scoped_for` must not be called from inside a pool job: a job that
//! blocks on the pool it runs on can deadlock once all workers block.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rmsmp-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Enqueue a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        assert!(
            !self.shared.shutdown.load(Ordering::SeqCst),
            "pool is shut down"
        );
        self.shared.queue.lock().unwrap().push_back(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of jobs and wait for all of them (scoped join).
    pub fn scoped<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        let n = jobs.len();
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for job in jobs {
            let done = Arc::clone(&done);
            self.execute(move || {
                job();
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (lock, cv) = &*done;
        let mut g = lock.lock().unwrap();
        while *g < n {
            g = cv.wait(g).unwrap();
        }
    }

    /// Scoped parallel-for: run `f(i)` for every `i in 0..n_tasks`, with
    /// dynamic load balancing over the pool's workers plus the calling
    /// thread. `f` may borrow from the caller's stack — the call blocks
    /// until every task (and every helper job) has finished. Panics in
    /// tasks are captured and re-raised here after the join.
    pub fn scoped_for<F>(&self, n_tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.scoped_for_indexed(n_tasks, |i, _lane| f(i));
    }

    /// [`Self::scoped_for`] that additionally hands each task the *lane*
    /// of its executing drain loop: lane 0 is the calling thread, lanes
    /// `1..=helpers` are the enqueued helper jobs (`helpers <=
    /// self.threads()`). Two tasks can observe the same lane only
    /// sequentially, never concurrently — which makes the lane a sound
    /// index into caller-preallocated per-lane scratch buffers (the
    /// zero-allocation GEMM dispatch relies on exactly this).
    pub fn scoped_for_indexed<F>(&self, n_tasks: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n_tasks == 0 {
            return;
        }

        struct Ctx<'a, F> {
            f: &'a F,
            next: AtomicUsize,
            n: usize,
            panicked: AtomicBool,
        }

        fn drain<F: Fn(usize, usize) + Sync>(ctx: &Ctx<'_, F>, lane: usize) {
            loop {
                let i = ctx.next.fetch_add(1, Ordering::Relaxed);
                if i >= ctx.n {
                    return;
                }
                if catch_unwind(AssertUnwindSafe(|| (ctx.f)(i, lane))).is_err() {
                    ctx.panicked.store(true, Ordering::SeqCst);
                }
            }
        }

        let ctx = Ctx {
            f: &f,
            next: AtomicUsize::new(0),
            n: n_tasks,
            panicked: AtomicBool::new(false),
        };

        // The caller drains too, so tasks complete even on a busy pool;
        // n_tasks - 1 helpers is therefore always enough.
        let helpers = self.threads().min(n_tasks - 1);
        let task: &(dyn Fn(usize) + Sync) = &|lane| drain(&ctx, lane);
        // SAFETY: the join barrier below keeps `task` (and everything it
        // borrows) alive until every helper job has returned.
        let task = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(task)
        };
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        for lane in 1..=helpers {
            let done = Arc::clone(&done);
            self.execute(move || {
                task(lane);
                let (lock, cv) = &*done;
                *lock.lock().unwrap() += 1;
                cv.notify_all();
            });
        }

        drain(&ctx, 0);

        let (lock, cv) = &*done;
        let mut g = lock.lock().unwrap();
        while *g < helpers {
            g = cv.wait(g).unwrap();
        }
        drop(g);

        if ctx.panicked.load(Ordering::SeqCst) {
            panic!("ThreadPool::scoped_for: a task panicked");
        }
    }

    /// Chunked parallel-for over `0..total`: `f` receives half-open index
    /// ranges of at most `chunk` elements. Built on [`Self::scoped_for`],
    /// so the same borrow/join rules apply.
    pub fn parallel_chunks<F>(&self, total: usize, chunk: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Sync,
    {
        let chunk = chunk.max(1);
        self.scoped_for(total.div_ceil(chunk), |i| {
            let start = i * chunk;
            f(start..total.min(start + chunk));
        });
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.scoped(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_drains_gracefully() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..20 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop here: must finish queued work before joining
        }
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let c = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&c);
        pool.scoped(vec![move || {
            c2.fetch_add(7, Ordering::SeqCst);
        }]);
        assert_eq!(c.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn scoped_for_visits_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.scoped_for(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scoped_for_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let input: Vec<u64> = (0..1000).collect();
        let total = AtomicUsize::new(0);
        pool.scoped_for(input.len(), |i| {
            total.fetch_add(input[i] as usize, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::SeqCst), 999 * 1000 / 2);
    }

    #[test]
    fn parallel_chunks_cover_range_exactly() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..103).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_chunks(hits.len(), 8, |range| {
            assert!(range.len() <= 8);
            for i in range {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scoped_for_empty_and_single() {
        let pool = ThreadPool::new(2);
        pool.scoped_for(0, |_| panic!("must not run"));
        let c = AtomicUsize::new(0);
        pool.scoped_for(1, |i| {
            c.fetch_add(i + 1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[should_panic(expected = "a task panicked")]
    fn scoped_for_propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.scoped_for(8, |i| {
            if i == 3 {
                panic!("inner failure");
            }
        });
    }

    #[test]
    fn scoped_for_reusable_after_panic() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scoped_for(4, |_| panic!("boom"));
        }));
        assert!(caught.is_err());
        let c = AtomicUsize::new(0);
        pool.scoped_for(16, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 16);
    }
}
