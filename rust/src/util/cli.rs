//! Tiny CLI argument parser (offline build — no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args, and generated help text.

use std::collections::BTreeMap;

use crate::bail;
use crate::err;
use crate::util::error::Result;

/// Declarative flag spec for help text.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, specs: &[FlagSpec]) -> Result<Args> {
        let takes: BTreeMap<&str, bool> =
            specs.iter().map(|s| (s.name, s.takes_value)).collect();
        let mut out = Args::default();
        let mut it = argv.into_iter();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                match takes.get(name.as_str()) {
                    None => bail!("unknown flag --{name} (try --help)"),
                    Some(false) => {
                        if inline.is_some() {
                            bail!("flag --{name} takes no value");
                        }
                        out.flags.insert(name, "true".to_string());
                    }
                    Some(true) => {
                        let v = match inline {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| err!("--{name} needs a value"))?,
                        };
                        out.flags.insert(name, v);
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| err!("--{name}={v}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| err!("--{name}={v}: {e}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

/// Render help text for a subcommand.
pub fn help(cmd: &str, about: &str, specs: &[FlagSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\nFlags:\n");
    for f in specs {
        let val = if f.takes_value { " <value>" } else { "" };
        let def = f
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\n      {}{def}\n", f.name, f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "ratio", help: "", default: Some("65:30:5"), takes_value: true },
            FlagSpec { name: "verbose", help: "", default: None, takes_value: false },
            FlagSpec { name: "n", help: "", default: Some("4"), takes_value: true },
        ]
    }

    fn parse(args: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), &specs())
    }

    #[test]
    fn parses_values_and_positionals() {
        let a = parse(&["serve", "--ratio", "60:35:5", "--verbose", "x"]).unwrap();
        assert_eq!(a.positional, vec!["serve", "x"]);
        assert_eq!(a.get("ratio"), Some("60:35:5"));
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--ratio=50:45:5"]).unwrap();
        assert_eq!(a.get("ratio"), Some("50:45:5"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "9"]).unwrap();
        assert_eq!(a.get_usize("n", 4).unwrap(), 9);
        assert_eq!(a.get_usize("missing", 4).unwrap(), 4);
        assert!(parse(&["--n", "x"]).unwrap().get_usize("n", 0).is_err());
    }

    #[test]
    fn rejects_unknown() {
        assert!(parse(&["--bogus"]).is_err());
        assert!(parse(&["--verbose=1"]).is_err());
        assert!(parse(&["--ratio"]).is_err()); // missing value
    }
}
