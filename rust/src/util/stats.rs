//! Streaming statistics: mean/variance (Welford), percentiles, histograms.
//! Used by the coordinator's latency metrics and the bench harness.

/// Online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Reservoir of samples for exact percentiles (bounded memory).
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng_state: u64,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        Reservoir { cap, seen: 0, samples: Vec::with_capacity(cap), rng_state: 0x9E3779B9 }
    }

    fn next_u64(&mut self) -> u64 {
        // splitmix64 step (self-contained; no dependency on util::rng)
        self.rng_state = self.rng_state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.rng_state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Percentile in [0, 100] (linear interpolation over the reservoir).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&s, p)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Percentile of an already-sorted slice, linear interpolation.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median + median-absolute-deviation of a sample (robust bench summary).
pub fn median_mad(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = percentile_sorted(&s, 50.0);
    let mut dev: Vec<f64> = s.iter().map(|x| (x - med).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (med, percentile_sorted(&dev, 50.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-9);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
    }

    #[test]
    fn reservoir_exact_under_cap() {
        let mut r = Reservoir::new(100);
        for i in 0..50 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 50);
        assert!((r.percentile(50.0) - 24.5).abs() < 1e-9);
    }

    #[test]
    fn reservoir_bounded_over_cap() {
        let mut r = Reservoir::new(64);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 64);
        let p50 = r.percentile(50.0);
        assert!(p50 > 2000.0 && p50 < 8000.0, "p50 {p50}");
    }

    #[test]
    fn median_mad_basic() {
        let (m, mad) = median_mad(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(m, 3.0);
        assert_eq!(mad, 1.0); // deviations 2,1,0,1,97 -> median 1
    }
}
