//! Property-testing mini-framework (offline build — no proptest).
//!
//! Seeded generators over a deterministic [`Rng`], N cases per property,
//! and on failure a report of the failing case index + seed so the case
//! reproduces exactly. Shrinking is intentionally simple: we re-run the
//! failing generator at decreasing size parameters and report the smallest
//! size that still fails.
//!
//! ```ignore
//! prop(|g| {
//!     let v = g.vec_f32(1..=64, -2.0, 2.0);
//!     let q: Vec<f32> = v.iter().map(|&x| fixed_quant(x, 1.0, 4)).collect();
//!     prop_assert!(q.iter().all(|x| x.abs() <= 1.0));
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Generator context handed to each property case.
pub struct Gen {
    pub rng: Rng,
    /// Size knob in [0.0, 1.0]; generators scale ranges by it during shrink.
    pub size: f64,
}

impl Gen {
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        let span = hi_inclusive - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + self.rng.below(scaled as u64 + 1) as usize
    }

    pub fn vec_f32(&mut self, lo_len: usize, hi_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(lo_len, hi_len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, lo_len: usize, hi_len: usize, scale: f32) -> Vec<f32> {
        let n = self.usize_in(lo_len, hi_len);
        (0..n).map(|_| self.rng.normal() * scale).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Failure report.
#[derive(Debug)]
pub struct PropFailure {
    pub case: usize,
    pub seed: u64,
    pub size: f64,
    pub message: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed {}, size {:.2}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `n` cases of `prop`; panic with a reproducible report on failure.
pub fn check<F>(name: &str, n: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = env_seed().unwrap_or(0xC0FFEE);
    for case in 0..n {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
        if let Err(msg) = prop(&mut g) {
            // shrink: decrease size until it passes; report smallest failure
            let mut smallest = PropFailure { case, seed, size: 1.0, message: msg };
            let mut size = 0.5;
            while size > 0.05 {
                let mut g = Gen { rng: Rng::new(seed), size };
                match prop(&mut g) {
                    Err(m) => {
                        smallest = PropFailure { case, seed, size, message: m };
                        size *= 0.5;
                    }
                    Ok(()) => break,
                }
            }
            panic!("[{name}] {smallest}");
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("RMSMP_PROP_SEED").ok()?.parse().ok()
}

/// Assert inside a property, returning Err for `check` to handle.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            count += 1;
            let v = g.vec_f32(1, 8, 0.0, 1.0);
            prop_assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |g| {
            let x = g.f32_in(0.0, 1.0);
            prop_assert!(x < 0.0, "x = {x}");
            Ok(())
        });
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        std::env::remove_var("RMSMP_PROP_SEED");
        let mut a = Vec::new();
        check("collect-a", 3, |g| {
            a.push(g.f32_in(0.0, 1.0));
            Ok(())
        });
        let mut b = Vec::new();
        check("collect-b", 3, |g| {
            b.push(g.f32_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(a, b);
    }
}
