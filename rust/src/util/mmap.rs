//! Zero-dependency file mapping for weight artifacts.
//!
//! The artifact loader (`model::artifact`) wants the file bytes resident
//! at a stable address for the lifetime of the model so quantized-row
//! sections can be aliased instead of copied. Two providers, one type:
//!
//! * **mmap(2)** — on Linux (x86-64 / aarch64) the file is mapped
//!   `PROT_READ`/`MAP_PRIVATE` through a raw syscall (no libc binding;
//!   the build is offline and dependency-free). Load cost is a page-table
//!   operation, and every process mapping the same artifact shares the
//!   page cache — N servers hold one copy of the weights.
//! * **aligned read** — everywhere else (or if the syscall fails) the
//!   file is read once into a 64-byte-aligned heap buffer. Same
//!   alignment contract, no page sharing.
//!
//! [`Plane`] is the aliasing handle the GEMM-side containers store: a
//! quantized i8 section that is either `Owned` (legacy parse path — the
//! oracle) or `Mapped` (a range of a shared [`MappedFile`]). It derefs
//! to `&[i8]`, so the kernels cannot tell the difference.

use std::fs::File;
use std::io::Read;
use std::sync::Arc;

use crate::util::error::{Context, Result};
use crate::{bail, ensure};

/// Alignment every artifact section is placed on (one cache line; also
/// divides the page size, so mapped sections keep it automatically).
pub const SECTION_ALIGN: usize = 64;

/// A read-only file resident in memory: `mmap(2)` when available, an
/// aligned heap copy otherwise. The bytes live until the last clone of
/// the owning `Arc<MappedFile>` drops.
pub struct MappedFile {
    ptr: *const u8,
    len: usize,
    /// True when `ptr` came from mmap (drop = munmap); false when it is
    /// a heap buffer (drop = dealloc with the 64-byte-aligned layout).
    mapped: bool,
}

// The mapping is immutable and private for the lifetime of the value;
// sharing &[u8] views across threads is safe.
unsafe impl Send for MappedFile {}
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map (or read) `path`. Empty files are valid and hold no pages.
    pub fn open(path: &str) -> Result<MappedFile> {
        let mut f = File::open(path).with_context(|| format!("opening artifact {path}"))?;
        let len = f.metadata().context("artifact metadata")?.len();
        ensure!(
            usize::try_from(len).is_ok(),
            "artifact too large for address space: {len} bytes"
        );
        let len = len as usize;
        if len == 0 {
            return Ok(MappedFile { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0, mapped: false });
        }
        #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            use std::os::unix::io::AsRawFd;
            let addr = unsafe { sys_mmap(len, f.as_raw_fd()) };
            // Linux returns a small negative errno on failure.
            if !(-4095..0).contains(&addr) {
                return Ok(MappedFile { ptr: addr as usize as *const u8, len, mapped: true });
            }
            // fall through to the read path (e.g. fd on a no-mmap fs)
        }
        Self::aligned_read(&mut f, len)
    }

    /// Fallback provider: one 64-byte-aligned heap buffer holding the file.
    fn aligned_read(f: &mut File, len: usize) -> Result<MappedFile> {
        let layout = std::alloc::Layout::from_size_align(len, SECTION_ALIGN)
            .map_err(|_| crate::err!("bad artifact buffer layout ({len} bytes)"))?;
        let ptr = unsafe { std::alloc::alloc(layout) };
        if ptr.is_null() {
            bail!("artifact buffer allocation failed ({len} bytes)");
        }
        let slice = unsafe { std::slice::from_raw_parts_mut(ptr, len) };
        if let Err(e) = f.read_exact(slice) {
            unsafe { std::alloc::dealloc(ptr, layout) };
            return Err(crate::err!("reading artifact: {e}"));
        }
        Ok(MappedFile { ptr, len, mapped: false })
    }

    /// The whole file.
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the bytes are a true `mmap` (page-cache-shared) or the
    /// aligned-read fallback copy.
    #[inline]
    pub fn is_mmapped(&self) -> bool {
        self.mapped
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        if self.len == 0 {
            return;
        }
        if self.mapped {
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            unsafe {
                sys_munmap(self.ptr as usize, self.len);
            }
        } else {
            let layout = std::alloc::Layout::from_size_align(self.len, SECTION_ALIGN)
                .expect("layout validated at construction");
            unsafe { std::alloc::dealloc(self.ptr as *mut u8, layout) };
        }
    }
}

impl std::fmt::Debug for MappedFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MappedFile({} bytes, {})",
            self.len,
            if self.mapped { "mmap" } else { "aligned read" }
        )
    }
}

// ---- raw syscalls (Linux only; no libc) ---------------------------------
//
// mmap(NULL, len, PROT_READ, MAP_PRIVATE, fd, 0) / munmap(addr, len).
// Syscall numbers differ per arch; both return a negative errno in the
// result register on failure.

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sys_mmap(len: usize, fd: i32) -> isize {
    const SYS_MMAP: isize = 9;
    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;
    let ret: isize;
    std::arch::asm!(
        "syscall",
        inlateout("rax") SYS_MMAP => ret,
        in("rdi") 0usize,
        in("rsi") len,
        in("rdx") PROT_READ,
        in("r10") MAP_PRIVATE,
        in("r8") fd as isize,
        in("r9") 0usize,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack)
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sys_munmap(addr: usize, len: usize) -> isize {
    const SYS_MUNMAP: isize = 11;
    let ret: isize;
    std::arch::asm!(
        "syscall",
        inlateout("rax") SYS_MUNMAP => ret,
        in("rdi") addr,
        in("rsi") len,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack)
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sys_mmap(len: usize, fd: i32) -> isize {
    const SYS_MMAP: isize = 222;
    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;
    let ret: isize;
    std::arch::asm!(
        "svc 0",
        in("x8") SYS_MMAP,
        inlateout("x0") 0isize => ret,
        in("x1") len,
        in("x2") PROT_READ,
        in("x3") MAP_PRIVATE,
        in("x4") fd as isize,
        in("x5") 0usize,
        options(nostack)
    );
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sys_munmap(addr: usize, len: usize) -> isize {
    const SYS_MUNMAP: isize = 215;
    let ret: isize;
    std::arch::asm!(
        "svc 0",
        in("x8") SYS_MUNMAP,
        inlateout("x0") addr as isize => ret,
        in("x1") len,
        options(nostack)
    );
    ret
}

// ---- Plane: owned-or-mapped i8 section ----------------------------------

/// A quantized i8 section: either crate-built (`Owned`, the legacy parse
/// path) or a borrowed range of a shared artifact mapping (`Mapped`).
/// Derefs to `&[i8]`; the GEMM kernels never see the difference.
#[derive(Clone)]
pub enum Plane {
    Owned(Vec<i8>),
    Mapped {
        map: Arc<MappedFile>,
        off: usize,
        len: usize,
    },
}

impl Plane {
    /// An empty owned section (e.g. a layer with no PoT rows).
    pub fn empty() -> Plane {
        Plane::Owned(Vec::new())
    }

    pub fn owned(v: Vec<i8>) -> Plane {
        Plane::Owned(v)
    }

    /// Alias `map[off..off + len]` as i8. Bounds are validated here so
    /// `deref` stays check-free on the hot path.
    pub fn mapped(map: Arc<MappedFile>, off: usize, len: usize) -> Result<Plane> {
        let end = off.checked_add(len).ok_or_else(|| crate::err!("section range overflows"))?;
        ensure!(
            end <= map.len(),
            "section [{off}, {end}) out of bounds of {} mapped bytes",
            map.len()
        );
        Ok(Plane::Mapped { map, off, len })
    }

    #[inline]
    pub fn as_slice(&self) -> &[i8] {
        match self {
            Plane::Owned(v) => v,
            Plane::Mapped { map, off, len } => {
                // Bounds were validated in `mapped`; i8 and u8 share layout.
                unsafe {
                    std::slice::from_raw_parts(map.bytes().as_ptr().add(*off) as *const i8, *len)
                }
            }
        }
    }

    #[inline]
    pub fn is_mapped(&self) -> bool {
        matches!(self, Plane::Mapped { .. })
    }
}

impl std::ops::Deref for Plane {
    type Target = [i8];

    #[inline]
    fn deref(&self) -> &[i8] {
        self.as_slice()
    }
}

impl From<Vec<i8>> for Plane {
    fn from(v: Vec<i8>) -> Plane {
        Plane::Owned(v)
    }
}

impl PartialEq for Plane {
    fn eq(&self, other: &Plane) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for Plane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Plane::Owned(v) => write!(f, "Plane::Owned({} bytes)", v.len()),
            Plane::Mapped { off, len, .. } => {
                write!(f, "Plane::Mapped({len} bytes at {off})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmp_path(name: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("rmsmp-mmap-{}-{}", std::process::id(), name));
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn maps_file_bytes_exactly() {
        let path = tmp_path("basic");
        let data: Vec<u8> = (0..=255u8).cycle().take(5000).collect();
        std::fs::File::create(&path).unwrap().write_all(&data).unwrap();
        let m = MappedFile::open(&path).unwrap();
        assert_eq!(m.bytes(), &data[..]);
        assert_eq!(m.len(), data.len());
        drop(m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_is_valid() {
        let path = tmp_path("empty");
        std::fs::File::create(&path).unwrap();
        let m = MappedFile::open(&path).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_a_clean_error() {
        assert!(MappedFile::open("/nonexistent/rmsmp-artifact").is_err());
    }

    #[test]
    fn aligned_read_fallback_matches() {
        let path = tmp_path("fallback");
        let data = vec![7u8; 777];
        std::fs::File::create(&path).unwrap().write_all(&data).unwrap();
        let mut f = File::open(&path).unwrap();
        let m = MappedFile::aligned_read(&mut f, data.len()).unwrap();
        assert!(!m.is_mmapped());
        assert_eq!(m.bytes(), &data[..]);
        assert_eq!(m.bytes().as_ptr() as usize % SECTION_ALIGN, 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn plane_owned_and_mapped_agree() {
        let path = tmp_path("plane");
        let data: Vec<u8> = (0..128u8).collect();
        std::fs::File::create(&path).unwrap().write_all(&data).unwrap();
        let m = Arc::new(MappedFile::open(&path).unwrap());
        let p = Plane::mapped(m.clone(), 64, 32).unwrap();
        let o = Plane::owned((64..96).map(|v| v as i8).collect());
        assert_eq!(p, o);
        assert_eq!(p.len(), 32);
        assert!(p.is_mapped() && !o.is_mapped());
        assert!(Plane::mapped(m, 100, 64).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
