//! FPGA resource + cycle simulator (paper §4.3, Table 6).
//!
//! The paper implements three heterogeneous GEMM cores on Zynq boards:
//! GEMM_PoT-4 built from LUT shift-add PEs, GEMM_Fixed-4 / GEMM_Fixed-8
//! from DSP-slice MAC PEs, all at 100 MHz. We have no FPGA, so this module
//! reproduces the *architecture model* (DESIGN.md §3 substitution):
//!
//! * [`boards`]  — resource budgets of XC7Z020 (53.2K LUT / 220 DSP) and
//!   XC7Z045 (218.6K LUT / 900 DSP).
//! * [`design`]  — the allocator: sizes the PE arrays so the per-layer
//!   makespan is balanced across cores at the configured scheme ratio
//!   (the paper's "adjusting the ratio among the PE array sizes"), under
//!   the LUT/DSP budgets; reports utilization.
//! * [`sim`]     — the cycle model: per layer, each core processes its row
//!   class; the layer takes the max over cores (layer-wise uniformality
//!   means no cross-layer reconfiguration), plus pipeline fill/drain and
//!   DMA setup; aggregates GOP/s and per-image latency.
//!
//! Cost constants are calibrated once against the paper's measured
//! single-scheme rows ((2) Fixed-W4A4 and (4) PoT-W4A4 in Table 6) and
//! then *predict* the mixed rows; see `EXPERIMENTS.md` §Table-6 for the
//! paper-vs-simulated comparison.

pub mod boards;
pub mod design;
pub mod sim;

pub use boards::Board;
pub use design::{CoreCosts, Design, QuantConfig};
pub use sim::{simulate, LayerShape, SimResult};
