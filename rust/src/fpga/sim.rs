//! Cycle model: layer-by-layer execution of a quantized CNN on a [`Design`].
//!
//! Per layer: each core processes its row class's MACs in parallel; the
//! layer finishes when the slowest core does (layer-wise uniformality means
//! the split is identical in every layer, so no core re-balancing between
//! layers). Pipeline fill/drain and DMA setup are charged per layer. The
//! first/last-8-bit variant routes those two layers entirely through the
//! Fixed-8 core (paper rows (1)(3)(5)(7)(8)).

use super::design::Design;

/// Shape of one GEMM-lowered layer (from the AOT manifest).
#[derive(Clone, Debug)]
pub struct LayerShape {
    pub name: String,
    /// Output filters (= weight-matrix rows).
    pub rows: usize,
    /// Inner dimension (in_ch * kh * kw for conv; in_dim for linear).
    pub cols: usize,
    /// GEMM batch: output spatial positions per image (out_h*out_w), or 1.
    pub positions: usize,
}

impl LayerShape {
    /// MACs per image for this layer.
    pub fn macs(&self) -> f64 {
        self.rows as f64 * self.cols as f64 * self.positions as f64
    }
}

/// Simulation output for one (design, model, batch).
#[derive(Clone, Debug)]
pub struct SimResult {
    pub total_cycles: f64,
    pub latency_ms: f64,
    /// End-to-end throughput, counting 2 ops per MAC (the paper's GOP/s).
    pub gops: f64,
    pub total_gop: f64,
    pub per_layer_cycles: Vec<(String, f64)>,
    pub lut_util: f64,
    pub dsp_util: f64,
}

/// Simulate one image (batch = 1, as in the paper's latency column).
pub fn simulate(design: &Design, layers: &[LayerShape]) -> SimResult {
    simulate_batch(design, layers, 1)
}

/// Simulate a batch of images executed back-to-back (weights stay
/// resident; per-layer setup is amortized across the batch).
pub fn simulate_batch(design: &Design, layers: &[LayerShape], batch: usize) -> SimResult {
    let c = &design.costs;
    let r = design.cfg.ratio;
    let (a, b, f8) = (
        r.pot4 as f64 / 100.0,
        r.fixed4 as f64 / 100.0,
        r.fixed8 as f64 / 100.0,
    );
    let n = layers.len();
    let mut total_cycles = 0.0;
    let mut per_layer = Vec::with_capacity(n);
    let mut total_macs = 0.0;

    for (i, l) in layers.iter().enumerate() {
        let macs = l.macs() * batch as f64;
        total_macs += macs;
        let first_or_last = i == 0 || i == n - 1;

        let eff_nl = if design.cfg.apot {
            c.eff_apot
        } else {
            c.eff_pot
        };
        let compute = if design.cfg.first_last_8bit && first_or_last {
            // entire layer in W8A8 on the DSP block (all DSPs repurposed
            // for these two layers; layer-wise uniformality is broken here,
            // which is exactly the overhead the paper's ✓ rows avoid).
            let pes8 = (design.board.dsps as f64 / c.dsp_per_fixed8).max(1.0);
            macs / (pes8 * c.eff_fixed * c.w8a8_rate)
        } else {
            // row classes in parallel; makespan = slowest core.
            let mut t: f64 = 0.0;
            if a > 0.0 {
                t = t.max(macs * a / (design.pot_pes * eff_nl).max(1e-9));
            }
            if b > 0.0 {
                t = t.max(macs * b / (design.fixed4_pes * c.eff_fixed).max(1e-9));
            }
            if f8 > 0.0 {
                t = t.max(macs * f8 / (design.fixed8_pes * c.eff_fixed).max(1e-9));
            }
            t
        };
        let cycles = compute + c.setup_cycles;
        per_layer.push((l.name.clone(), cycles));
        total_cycles += cycles;
    }

    let secs = total_cycles / design.board.freq_hz;
    let total_gop = 2.0 * total_macs / 1e9;
    SimResult {
        total_cycles,
        latency_ms: secs * 1e3 / batch as f64,
        gops: total_gop / secs,
        total_gop,
        per_layer_cycles: per_layer,
        lut_util: design.lut_util(),
        dsp_util: design.dsp_util(),
    }
}

/// The paper's benchmark model: ResNet-18 on ImageNet (224x224), the layer
/// table used for every Table 6 row. (Our end-to-end integer executor runs
/// the CIFAR-scale model from the manifest; this table reproduces the
/// paper's workload for the hardware comparison.)
pub fn resnet18_imagenet_layers() -> Vec<LayerShape> {
    let mut v = Vec::new();
    let mut push = |name: &str, rows: usize, in_ch: usize, k: usize, out_hw: usize| {
        v.push(LayerShape {
            name: name.to_string(),
            rows,
            cols: in_ch * k * k,
            positions: out_hw * out_hw,
        });
    };
    push("conv1", 64, 3, 7, 112);
    for blk in 0..2 {
        push(&format!("s1b{blk}.conv1"), 64, 64, 3, 56);
        push(&format!("s1b{blk}.conv2"), 64, 64, 3, 56);
    }
    push("s2b0.conv1", 128, 64, 3, 28);
    push("s2b0.conv2", 128, 128, 3, 28);
    push("s2b0.down", 128, 64, 1, 28);
    push("s2b1.conv1", 128, 128, 3, 28);
    push("s2b1.conv2", 128, 128, 3, 28);
    push("s3b0.conv1", 256, 128, 3, 14);
    push("s3b0.conv2", 256, 256, 3, 14);
    push("s3b0.down", 256, 128, 1, 14);
    push("s3b1.conv1", 256, 256, 3, 14);
    push("s3b1.conv2", 256, 256, 3, 14);
    push("s4b0.conv1", 512, 256, 3, 7);
    push("s4b0.conv2", 512, 512, 3, 7);
    push("s4b0.down", 512, 256, 1, 7);
    push("s4b1.conv1", 512, 512, 3, 7);
    push("s4b1.conv2", 512, 512, 3, 7);
    push("fc", 1000, 512, 1, 1);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::design::{CoreCosts, QuantConfig};
    use crate::fpga::Board;
    use crate::quant::Ratio;

    fn design(ratio: Ratio, first_last_8bit: bool) -> Design {
        Design::allocate(
            Board::XC7Z045,
            QuantConfig { ratio, first_last_8bit, apot: false },
            CoreCosts::default(),
        )
    }

    #[test]
    fn resnet18_total_ops_near_paper() {
        // ResNet-18/224 is ~1.82 GMAC = 3.6 GOP; Table 6's latency x GOP/s
        // products sit at ~3.6 GOP too.
        let layers = resnet18_imagenet_layers();
        let total: f64 = layers.iter().map(|l| l.macs()).sum();
        let gop = 2.0 * total / 1e9;
        assert!((3.0..4.2).contains(&gop), "GOP {gop}");
    }

    #[test]
    fn rmsmp_beats_fixed_only() {
        let layers = resnet18_imagenet_layers();
        let fixed = simulate(&design(Ratio::new(0, 100, 0), true), &layers);
        let rmsmp = simulate(&design(Ratio::RMSMP2, false), &layers);
        let speedup = fixed.latency_ms / rmsmp.latency_ms;
        // paper: 3.65x on XC7Z045 (row (1) vs RMSMP-2)
        assert!(speedup > 2.5 && speedup < 5.0, "speedup {speedup}");
    }

    #[test]
    fn first_last_8bit_slows_down() {
        let layers = resnet18_imagenet_layers();
        let relaxed = simulate(&design(Ratio::new(0, 100, 0), true), &layers);
        let uniform = simulate(&design(Ratio::new(0, 100, 0), false), &layers);
        assert!(relaxed.latency_ms > uniform.latency_ms);
    }

    #[test]
    fn batch_amortizes_setup() {
        let layers = resnet18_imagenet_layers();
        let d = design(Ratio::RMSMP2, false);
        let one = simulate_batch(&d, &layers, 1);
        let eight = simulate_batch(&d, &layers, 8);
        assert!(eight.latency_ms < one.latency_ms);
        assert!(eight.gops > one.gops);
    }

    #[test]
    fn gops_consistent_with_latency() {
        let layers = resnet18_imagenet_layers();
        let d = design(Ratio::RMSMP2, false);
        let r = simulate(&d, &layers);
        let recomputed = r.total_gop / (r.latency_ms / 1e3);
        assert!((recomputed - r.gops).abs() / r.gops < 1e-9);
    }
}
