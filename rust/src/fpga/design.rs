//! PE-array allocation: turn (board, quant config) into a concrete design.
//!
//! The paper: "Different ratios of quantization schemes/precisions are
//! realized by adjusting the ratio among the processing element (PE) array
//! sizes in the GEMM cores." This allocator does exactly that:
//!
//! 1. All DSPs go to the Fixed cores (the paper keeps DSP utilization at
//!    100% whenever any Fixed rows exist), split between Fixed-4 and
//!    Fixed-8 in proportion to their MAC share x per-MAC DSP cost.
//! 2. The PoT (or APoT) core gets LUT PEs sized to *balance the makespan*
//!    with the Fixed cores at the configured row ratio — more LUT PEs than
//!    balance would idle, fewer would bottleneck — capped by the LUT
//!    budget after glue/control overhead.

use super::boards::Board;
use crate::quant::Ratio;

/// Calibrated per-PE resource costs and sustained efficiencies.
///
/// Calibration (EXPERIMENTS.md §Table-6): `eff_fixed` from Table 6 row (2)
/// (900 DSPs -> 142.7 GOP/s => 0.79), `pot_fabric_frac` + `eff_pot` from
/// row (4) (43% LUT, 352.6 GOP/s), `w8a8_rate` from the (1)/(2) gap,
/// `eff_apot` from the MSQ rows. The mixed rows (RMSMP-1/2) are then
/// *predictions* of the model, not fits.
#[derive(Clone, Copy, Debug)]
pub struct CoreCosts {
    /// DSP slices per Fixed-W4A4 MAC/cycle.
    pub dsp_per_fixed4: f64,
    /// DSP slices per Fixed-W8A4 MAC/cycle (8x4 product still fits one DSP48).
    pub dsp_per_fixed8: f64,
    /// Glue LUTs accompanying each DSP PE (operand mux, accumulator tail).
    pub lut_per_fixed_pe: f64,
    /// LUTs per PoT shift-add PE.
    pub lut_per_pot_pe: f64,
    /// LUTs per APoT PE: two shift-add terms per weight => ~2x the PoT PE.
    pub lut_per_apot_pe: f64,
    /// Fixed control/DMA/BRAM-interface overhead (fraction of board LUTs).
    pub control_lut_frac: f64,
    /// Fraction of the board's LUTs routable as PoT/APoT PE array at
    /// 100 MHz (timing closure limit; from row (4)'s 43% utilization).
    pub pot_fabric_frac: f64,
    /// Sustained efficiency of the DSP (Fixed) cores.
    pub eff_fixed: f64,
    /// Sustained efficiency of the LUT shift-add (PoT) core.
    pub eff_pot: f64,
    /// Sustained efficiency of the APoT core (two serialized shift terms).
    pub eff_apot: f64,
    /// Rate factor for whole layers in W8A8 (first/last-8bit variant):
    /// doubled activation bandwidth halves the sustained MAC rate.
    pub w8a8_rate: f64,
    /// Per-layer setup cycles (weight DMA; no core reconfiguration thanks
    /// to layer-wise uniformality).
    pub setup_cycles: f64,
}

impl Default for CoreCosts {
    fn default() -> CoreCosts {
        CoreCosts {
            dsp_per_fixed4: 1.0,
            dsp_per_fixed8: 1.0,
            lut_per_fixed_pe: 36.0,
            lut_per_pot_pe: 48.0,
            lut_per_apot_pe: 96.0,
            control_lut_frac: 0.045,
            pot_fabric_frac: 0.45,
            eff_fixed: 0.80,
            eff_pot: 0.95,
            eff_apot: 0.95,
            w8a8_rate: 0.25,
            setup_cycles: 3_000.0,
        }
    }
}

/// A quantization configuration to implement (one Table 6 row).
#[derive(Clone, Copy, Debug)]
pub struct QuantConfig {
    /// Scheme ratio PoT4 : Fixed4 : Fixed8 (the nonlinear share goes to
    /// APoT PEs when `apot` is set — the MSQ baseline rows).
    pub ratio: Ratio,
    /// First/last layers in 8-bit Fixed (rows (1)(3)(5)(7)(8)) instead of
    /// quantized like the rest (✓ rows).
    pub first_last_8bit: bool,
    /// Use APoT instead of PoT for the nonlinear class (MSQ rows).
    pub apot: bool,
}

/// A concrete allocation of PE arrays on a board.
#[derive(Clone, Debug)]
pub struct Design {
    pub board: Board,
    pub cfg: QuantConfig,
    pub costs: CoreCosts,
    /// MAC/cycle capacity of each core.
    pub pot_pes: f64,
    pub fixed4_pes: f64,
    pub fixed8_pes: f64,
    pub lut_used: f64,
    pub dsp_used: f64,
}

impl Design {
    /// Allocate PE arrays for `cfg` on `board`.
    pub fn allocate(board: Board, cfg: QuantConfig, costs: CoreCosts) -> Design {
        let Ratio { pot4, fixed4, fixed8 } = cfg.ratio;
        let (a, b, c) = (pot4 as f64 / 100.0, fixed4 as f64 / 100.0, fixed8 as f64 / 100.0);
        let lut_pot = if cfg.apot {
            costs.lut_per_apot_pe
        } else {
            costs.lut_per_pot_pe
        };

        let control = costs.control_lut_frac * board.luts as f64;
        let lut_budget = board.luts as f64 - control;

        // --- DSPs: all to the Fixed cores, split by cost-weighted share.
        let fixed_share = b * costs.dsp_per_fixed4 + c * costs.dsp_per_fixed8;
        let (fixed4_pes, fixed8_pes, dsp_used_raw) = if fixed_share > 0.0 {
            let dsps = board.dsps as f64;
            // PEs proportional to MAC share so both Fixed cores finish
            // together: pe4/pe8 = b/c.
            let denom = b * costs.dsp_per_fixed4 + c * costs.dsp_per_fixed8;
            let unit = dsps / denom; // PEs per unit share
            (unit * b, unit * c, dsps)
        } else {
            (0.0, 0.0, 0.0)
        };
        let fixed_glue = (fixed4_pes + fixed8_pes) * costs.lut_per_fixed_pe;

        // --- PoT core: balance the makespan with the Fixed cores, capped
        // by the routable fabric fraction AND the remaining LUT budget.
        let lut_for_pot = (lut_budget - fixed_glue)
            .min(costs.pot_fabric_frac * board.luts as f64)
            .max(0.0);
        let pot_cap = lut_for_pot / lut_pot;
        let eff_nl = if cfg.apot {
            costs.eff_apot
        } else {
            costs.eff_pot
        };
        let pot_pes = if a <= 0.0 {
            0.0
        } else if fixed_share <= 0.0 {
            pot_cap // PoT-only design: fill the routable fabric
        } else {
            // balance finish times: a/(pot_pes*eff_nl) == b/(fixed4_pes*eff_fixed)
            let balanced = a * fixed4_pes * costs.eff_fixed / (b.max(1e-9) * eff_nl);
            balanced.min(pot_cap)
        };

        let lut_used = control + fixed_glue + pot_pes * lut_pot;
        // A PoT-only design keeps a token DSP block for the first/last
        // 8-bit path when configured (matches row (3) vs (4) in Table 6).
        let dsp_used = if fixed_share <= 0.0 {
            if cfg.first_last_8bit {
                board.dsps as f64 // row (3): 8-bit first/last on DSPs
            } else {
                0.03 * board.dsps as f64 // row (4): residual scalar units
            }
        } else {
            dsp_used_raw
        };

        Design {
            board,
            cfg,
            costs,
            pot_pes,
            fixed4_pes,
            fixed8_pes,
            lut_used: lut_used.min(board.luts as f64),
            dsp_used,
        }
    }

    pub fn lut_util(&self) -> f64 {
        self.lut_used / self.board.luts as f64
    }

    pub fn dsp_util(&self) -> f64 {
        self.dsp_used / self.board.dsps as f64
    }

    /// Total MAC/cycle at full occupancy (upper bound; the sim applies the
    /// per-layer makespan and pipeline efficiency).
    pub fn peak_macs_per_cycle(&self) -> f64 {
        self.pot_pes + self.fixed4_pes + self.fixed8_pes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(ratio: Ratio) -> QuantConfig {
        QuantConfig { ratio, first_last_8bit: false, apot: false }
    }

    #[test]
    fn fixed_only_uses_all_dsps_no_pot() {
        let d = Design::allocate(Board::XC7Z045, cfg(Ratio::new(0, 100, 0)), CoreCosts::default());
        assert_eq!(d.pot_pes, 0.0);
        assert!((d.fixed4_pes - 900.0).abs() < 1e-6);
        assert!((d.dsp_util() - 1.0).abs() < 1e-9);
        assert!(d.lut_util() < 0.30, "lut util {}", d.lut_util());
    }

    #[test]
    fn pot_only_fills_routable_fabric() {
        let d = Design::allocate(Board::XC7Z045, cfg(Ratio::new(100, 0, 0)), CoreCosts::default());
        let c = CoreCosts::default();
        assert!(d.pot_pes > 1000.0);
        assert_eq!(d.fixed4_pes, 0.0);
        assert!(d.dsp_util() < 0.1);
        // fabric cap + control overhead (paper row (4): 43% LUT)
        let expect = c.pot_fabric_frac + c.control_lut_frac;
        assert!((d.lut_util() - expect).abs() < 0.02, "lut {}", d.lut_util());
    }

    #[test]
    fn rmsmp_balances_and_fits() {
        let c = CoreCosts::default();
        let d = Design::allocate(Board::XC7Z045, cfg(Ratio::RMSMP2), c);
        assert!((d.dsp_util() - 1.0).abs() < 1e-9, "100% DSP (paper)");
        assert!(d.lut_util() > 0.4 && d.lut_util() <= 1.0, "lut {}", d.lut_util());
        // makespan balance (with per-core efficiencies): pot ~= fixed4
        let t_pot = 0.65 / (d.pot_pes * c.eff_pot);
        let t_fix = 0.30 / (d.fixed4_pes * c.eff_fixed);
        assert!(
            (t_pot / t_fix - 1.0).abs() < 0.05 || d.lut_util() > 0.99,
            "t_pot/t_fix = {}",
            t_pot / t_fix
        );
    }

    #[test]
    fn apot_pes_cost_more_luts() {
        let costs = CoreCosts::default();
        let pot = Design::allocate(Board::XC7Z020, cfg(Ratio::new(60, 40, 0)), costs);
        let mut qc = cfg(Ratio::new(60, 40, 0));
        qc.apot = true;
        let apot = Design::allocate(Board::XC7Z020, qc, CoreCosts::default());
        assert!(apot.lut_used > pot.lut_used);
    }

    #[test]
    fn small_board_caps_pot_at_budget() {
        let d = Design::allocate(Board::XC7Z020, cfg(Ratio::new(90, 10, 0)), CoreCosts::default());
        assert!(d.lut_util() <= 1.0 + 1e-9);
    }
}
