//! Board presets (paper §4.1/§4.3).

/// An FPGA board's relevant resource budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Board {
    pub name: &'static str,
    /// Look-up tables (the GEMM_PoT fabric + glue logic).
    pub luts: u64,
    /// DSP slices (the GEMM_Fixed multipliers).
    pub dsps: u64,
    /// Clock frequency (the paper fixes 100 MHz for all implementations).
    pub freq_hz: f64,
}

impl Board {
    /// Zynq XC7Z020: 53.2K LUTs, 220 DSPs (Table 6 caption).
    pub const XC7Z020: Board = Board {
        name: "XC7Z020",
        luts: 53_200,
        dsps: 220,
        freq_hz: 100e6,
    };

    /// Zynq XC7Z045: 218.6K LUTs, 900 DSPs (Table 6 caption).
    pub const XC7Z045: Board = Board {
        name: "XC7Z045",
        luts: 218_600,
        dsps: 900,
        freq_hz: 100e6,
    };

    pub fn by_name(name: &str) -> Option<Board> {
        match name.to_ascii_uppercase().as_str() {
            "XC7Z020" | "Z020" | "7Z020" => Some(Board::XC7Z020),
            "XC7Z045" | "Z045" | "7Z045" => Some(Board::XC7Z045),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_caption() {
        assert_eq!(Board::XC7Z020.luts, 53_200);
        assert_eq!(Board::XC7Z020.dsps, 220);
        assert_eq!(Board::XC7Z045.luts, 218_600);
        assert_eq!(Board::XC7Z045.dsps, 900);
        assert_eq!(Board::XC7Z045.freq_hz, 100e6);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Board::by_name("xc7z020"), Some(Board::XC7Z020));
        assert_eq!(Board::by_name("Z045"), Some(Board::XC7Z045));
        assert_eq!(Board::by_name("virtex"), None);
    }
}
