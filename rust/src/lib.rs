//! # RMSMP — Row-wise Mixed-Scheme Multi-Precision quantization
//!
//! Rust reproduction of the RMSMP system (Chang et al., 2021): a DNN
//! quantization framework that assigns a quantization *scheme*
//! (Power-of-Two vs Fixed-point) and a *precision* (W4A4 vs W8A4) to each
//! row of every weight matrix, with a layer-wise-uniform ratio so the
//! heterogeneous GEMM cores of the inference hardware see the same workload
//! split in every layer.
//!
//! This crate is Layer 3 of the three-layer stack (see DESIGN.md): the
//! Python/JAX/Pallas layers author and AOT-lower the model; this crate owns
//! everything on the request path:
//!
//! * [`quant`] — bit-exact integer quantizers (Fixed, PoT, APoT) matching
//!   the JAX oracles.
//! * [`assign`] — the row-wise scheme/precision assignment engine
//!   (variance split + sensitivity top-K, Alg. 1).
//! * [`gemm`] — integer GEMM cores: `GemmFixed4`, `GemmFixed8` (i8 MAC)
//!   and `GemmPoT4` (shift-add), plus the row-partitioned mixed GEMM.
//! * [`model`] — the layer-graph representation loaded from the AOT
//!   manifest, im2col, and the integer layer-by-layer executor.
//! * [`fpga`] — the FPGA resource/cycle simulator that reproduces Table 6
//!   (Zynq XC7Z020 / XC7Z045 presets).
//! * [`runtime`] — PJRT wrapper: loads `artifacts/*.hlo.txt`, compiles on
//!   the CPU client, executes the float reference paths.
//! * [`coordinator`] — the serving layer: request router, dynamic batcher,
//!   worker pool, metrics.
//! * [`util`] — substrates built in-repo because the build is offline:
//!   deterministic PRNG, CLI parsing, JSON, stats, a thread pool, and the
//!   bench/property-test harnesses.

pub mod assign;
pub mod coordinator;
pub mod fpga;
pub mod gemm;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

pub use quant::scheme::Scheme;
