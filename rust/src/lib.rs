//! # RMSMP — Row-wise Mixed-Scheme Multi-Precision quantization
//!
//! Rust reproduction of the RMSMP system (Chang et al., 2021): a DNN
//! quantization framework that assigns a quantization *scheme*
//! (Power-of-Two vs Fixed-point) and a *precision* (W4A4 vs W8A4) to each
//! row of every weight matrix, with a layer-wise-uniform ratio so the
//! heterogeneous GEMM cores of the inference hardware see the same workload
//! split in every layer.
//!
//! This crate is Layer 3 of the three-layer stack (see DESIGN.md): the
//! Python/JAX/Pallas layers author and AOT-lower the model; this crate owns
//! everything on the request path:
//!
//! * [`quant`] — bit-exact integer quantizers (Fixed, PoT, APoT) matching
//!   the JAX oracles.
//! * [`assign`] — the row-wise scheme/precision assignment engine
//!   (variance split + sensitivity top-K, Alg. 1).
//! * [`gemm`] — integer GEMM cores: `GemmFixed4`, `GemmFixed8` (i8 MAC)
//!   and `GemmPoT4` (shift-add), plus the row-partitioned mixed GEMM:
//!   class-sorted weight layout ([`gemm::SortedWeights`]), multi-row
//!   SIMD micro-kernels with runtime AVX2/SSE/scalar dispatch
//!   ([`gemm::Isa`]), tile-blocked inner loops, and multi-threaded row
//!   dispatch.
//! * [`model`] — the layer-graph representation loaded from the AOT
//!   manifest, im2col, the plan compiler ([`model::Plan`]), the reusable
//!   [`model::Workspace`], the integer executor that walks compiled
//!   plans, and the `.rmsa` packed-artifact reader/writer
//!   ([`model::artifact`] — see the artifact format section below).
//! * [`fpga`] — the FPGA resource/cycle simulator that reproduces Table 6
//!   (Zynq XC7Z020 / XC7Z045 presets).
//! * [`runtime`] — the native execution runtime: resolves the
//!   [`gemm::ParallelConfig`] and owns the shared thread pool that every
//!   executor fans GEMM work onto.
//! * [`coordinator`] — the serving layer: zero-dependency HTTP/1.1
//!   front-end, request router, dynamic batcher, worker pool, metrics
//!   (Prometheus text format on `GET /metrics`).
//! * [`util`] — substrates built in-repo because the build is offline:
//!   deterministic PRNG, CLI parsing, JSON, stats, a thread pool,
//!   raw-syscall `mmap` file mapping, error plumbing, and the
//!   bench/property-test harnesses.
//!
//! ## Execution model: compile, then run — integer-resident
//!
//! RMSMP's layer-wise-uniform row mixing makes a model's compute
//! structure fully static, so inference is split into a one-time compile
//! and an allocation-free run:
//!
//! * **Plan** ([`model::Plan`], built by [`model::PlanBuilder`] — the
//!   single entry point, `Plan::builder(..).capacity(..).config(..)
//!   .build()`) — at load time the manifest's op program is **lowered**
//!   to a typed IR (`model::ir`: buffer names resolve to dense slot
//!   ids, per-op geometry is precomputed and shape-checked, each
//!   layer's row partition is chunked into a GEMM task schedule — no
//!   optimization), then rewritten by the **pass pipeline**
//!   ([`model::passes`], see the table below): epilogue fusion, output
//!   **domain** inference (u8 codes or f32 per inter-layer edge),
//!   implicit-GEMM strategy, depthwise scheduling, dead-slot
//!   elimination. Each pass is a pure IR rewrite, individually
//!   toggleable via `PlanBuilder::disable_pass`, and reports what it
//!   did ([`model::PassReport`] — `rmsmp plan` prints the per-pass
//!   rewrite log next to each slot's domain and the footprint). The
//!   high-water memory footprint is computed strictly **after** the
//!   pipeline, from the optimized ops. The plan is immutable and
//!   shared (`Arc<Plan>`).
//! * **Integer-resident dataflow** — the paper's hardware never
//!   dequantizes activations between layers (they are 4-bit Fixed
//!   everywhere), and neither does this executor: where a value's only
//!   consumers are quantized GEMMs agreeing on a clip scale, the
//!   producing GEMM runs a **fused epilogue** (a
//!   [`gemm::QuantEpilogue`] in its [`gemm::MixedGemm::dispatch`]
//!   descriptor) that maps each i32 accumulator straight to the *next*
//!   layer's activation code — one dequantizing multiply, the bias
//!   add, an optional fused residual addend (see epilogue fusion
//!   below), and the consumer's requantization ([`gemm::Requant`]),
//!   with ReLU free because the code clamp's lower bound is zero, and
//!   with the NCHW col2im fold fused into the code scatter. The consumer's im2col then unrolls
//!   the u8 code slot directly (padding is the literal code 0, which is
//!   the code of 0.0 — the quantizer is unsigned and zero-point-free).
//!   The f32 round-trip (dequant → store → im2col → requantize) exists
//!   only on edges that need it: the network input, Add operands, Gap
//!   input, and the logits.
//! * **Bit-exactness contract** — the fused epilogue performs exactly
//!   the f32 operations of the fallback path in the same order (scale
//!   multiply, bias add, `n/alpha` scale, clamp, `round_ties_even`), so
//!   integer-resident activation codes and logits are **bit-identical**
//!   to the f32-resident dataflow and to the reference interpreter, for
//!   every batch, thread count, chunk schedule, and kernel ISA (pinned
//!   by `tests/test_requant.rs`).
//! * **Workspace** ([`model::Workspace`]) — the mutable half: f32 slot
//!   buffers *and* u8 code slots (each allocated only for the domains
//!   its slot actually holds), the explicit-fallback im2col scratch
//!   (grouped convs only — implicit convs stream per-lane panels, so
//!   the former largest buffer shrinks to the fallback high-water
//!   mark), quantized-activation codes, GEMM staging, per-lane block
//!   scratch (f32 + i32 + u8 + panel), and the logits matrix, all
//!   preallocated from the plan's footprint and reused across `infer`
//!   calls. Batches at or below the plan capacity
//!   only `resize` within reserved capacity and overwrite in place (a
//!   larger batch grows the buffers once, then that size is the new
//!   steady state). **Sequential steady-state `infer` performs zero
//!   heap allocation on both dataflows** (pinned by a counting-allocator
//!   test); with a thread pool attached, every buffer is still reused
//!   (pinned by a pointer-stability test) and the only per-call
//!   allocations left are the O(threads) job handles the pool boxes per
//!   GEMM dispatch.
//! * **Worker ownership** — the serving coordinator loads weights and
//!   compiles the plan once, then shares `Arc<ModelWeights>` /
//!   `Arc<Manifest>` / `Arc<Plan>` across workers; each worker privately
//!   owns only an executor with its workspace, so an N-worker server
//!   holds ~1x the model, not Nx. Workers drain a per-stage timing
//!   breakdown (quantize / im2col / gemm / epilogue,
//!   [`model::StageTimes`]) into the shared metrics after every batch.
//! * **Reference interpreter** — the original name-resolving,
//!   per-call-allocating interpreter survives as
//!   `Executor::reference_infer`, the bit-exact oracle for the
//!   differential property tests (plan output must equal it exactly,
//!   including grouped conv and residual topologies). Every older
//!   dataflow is still compilable by switching off the pass that
//!   introduced it (`Plan::builder(..).disable_pass(..)`) — the
//!   ablated twins are the baselines `bench_runtime` reports the
//!   `requant_speedup` / `implicit_speedup` / `fusion_speedup` /
//!   `depthwise_speedup` numbers against, and every pass subset is
//!   differential-tested bit-exact in `tests/test_passes.rs`.
//!
//! ## Plan optimizer: rewrite passes over a typed IR
//!
//! Plan compilation is `Ir::lower` (resolve + shape-check only)
//! followed by a fixed pipeline of graph-rewrite passes, each a pure
//! `fn(&mut Ir) -> Result<PassReport>`:
//!
//! | pass | introduced | rewrite | bit-exactness obligation |
//! |------|-----------|---------|--------------------------|
//! | `epilogue_fusion` | PR 6 | folds `Add(+ReLU)` after a conv into the conv's GEMM epilogue (the addend joins the bias add; the orphaned Add and its slot disappear) | IEEE f32 `+` is commutative in `(acc+bias)+addend`; the requant clamp-at-0 subsumes ReLU |
//! | `integer_resident` | PR 4 | marks edges whose consumers are all quantized GEMMs sharing a clip scale as u8-code-resident; bakes the consumer's [`gemm::Requant`] into the producer's epilogue | the fused epilogue performs the fallback's f32 ops in the same order |
//! | `implicit` | PR 5 | switches non-grouped convs to streamed column-tile panels (no im2col matrix); retargets 1×1-only code slots to NHWC so unit convs alias them | the panel packer shares its gather/quantizer expressions with explicit im2col |
//! | `depthwise` | PR 6 | gives grouped convs a per-group streamed panel GEMM schedule (replacing the row-by-row fallback) | per-group GEMMs reuse the same cores/chunks; groups write disjoint rows |
//! | `dead_slot_elim` | PR 6 | drops domains from slots with no remaining readers or writers (fusion orphans) | dead slots are never read |
//!
//! Pass order is fixed: fusion first (so domain inference sees the
//! fused graph), elimination last. A `finalize` step (not a pass, not
//! skippable) then assigns f32 domains to every non-quantized write,
//! and the footprint is recomputed from the rewritten ops — so a slot
//! that became codes-only or dead after fusion budgets no f32 bytes,
//! and streamed convs budget panels instead of patch matrices.
//!
//! ## Parallel execution model
//!
//! The hot path is the row-partitioned mixed GEMM, and its unit of work
//! is one weight row: every output cell `(batch, row)` is produced by
//! exactly one row's dot products, so rows parallelize with no shared
//! accumulation.
//!
//! * **Task granularity** — each scheme class's contiguous sorted-row
//!   range is split into chunks of `ParallelConfig::min_rows_per_task`
//!   rows (precompiled into the plan as [`gemm::TaskChunk`] schedules).
//!   Chunks are interleaved round-robin across the four class ranges so
//!   cheap PoT shift-add chunks and expensive Fixed-8 MAC chunks
//!   alternate in the task list instead of convoying per class.
//! * **Scheduling** — tasks drain through
//!   [`util::pool::ThreadPool::scoped_for_indexed`]: workers (plus the
//!   calling thread) pull the next task index from a shared atomic
//!   cursor, which self-balances heterogeneous task costs; each drain
//!   loop's lane index selects a preallocated scratch lane, keeping the
//!   parallel dispatch free of per-task buffers. The call joins before
//!   returning, so borrowed operands stay valid and all writes are
//!   published to the caller.
//! * **Cache blocking** — inner loops are tiled at
//!   `ParallelConfig::tile_cols` codes so one weight-row tile stays in L1
//!   while it sweeps the batch; per-cell accumulation is a single i32
//!   that survives across tiles, and the dequantizing multiply happens
//!   once per output cell.
//! * **Determinism** — per-row arithmetic is identical in the sequential
//!   and parallel paths, tasks write disjoint output cells, and i32
//!   accumulation is associative, so parallel output is bit-exact vs
//!   sequential for every thread count, task size, and (for the three
//!   RMSMP classes) tile size. The f32-accumulating APoT baseline core is
//!   bit-exact for a fixed `tile_cols`, which the config pins.
//! * **Batch vs row parallelism** — a coordinator worker keeps the GEMM
//!   sequential only when its sibling workers already saturate the pool
//!   and its batch is wide; otherwise the threads go inside the GEMM
//!   (row-level); see `coordinator::batcher::row_parallel_for_batch`.
//!
//! ## Serving: the HTTP request path
//!
//! [`coordinator::HttpServer`] puts the compiled plan behind a real
//! socket with no external dependencies — `std::net` only. One request
//! travels: **socket** (accept loop hands the connection to one of a
//! pool of keep-alive handler threads) → **lazy parse**
//! ([`util::json::lazy_f32_array`] scans the body bytes for exactly
//! `model` / `input` / `deadline_ms` and parses the input floats
//! straight into a buffer — no JSON tree is ever built on the hot
//! path) → **batcher** (admission control: queue-depth backpressure
//! maps [`coordinator::SubmitError`] to HTTP 429 with `Retry-After`,
//! shutdown to 503, validation to 400, unknown model to 404; the
//! batcher coalesces concurrent requests under the max-batch/max-wait
//! policy and sheds deadline-expired requests *before* the GEMM,
//! answering 504) → **plan** (the worker packs the batch into one
//! reused tensor — `coordinator::server::pack_batch`, held to the same
//! zero-allocation contract as the executor — and runs the compiled
//! plan) → **response** (logits rendered with f32 `Display`, the
//! shortest round-trip representation, so a client parsing the JSON
//! recovers bit-identical values). Handlers block on the response
//! channel while the batcher fills, so throughput under concurrency
//! comes from continuous batching — `bench_serve` records the
//! p50/p99/throughput curve over real loopback sockets, and
//! `tests/test_server.rs` drives every rejection path through a real
//! connection. `GET /metrics` renders the counters, latency quantiles,
//! and the per-stage executor timers in Prometheus text format;
//! `rmsmp serve --http ADDR` serves from the CLI.
//!
//! ## Artifact format: pack once, `mmap` forever
//!
//! The legacy `weights.bin` (`RMSW`) container stores *float* weights,
//! so every process start re-runs the whole offline pipeline online:
//! parse, quantize every element, class-sort every layer. The `.rmsa`
//! artifact ([`model::artifact`]) stores that pipeline's **results** —
//! the exact byte planes `PackedWeights`/`SortedWeights` hold in memory
//! — so loading is a header validation plus an `mmap(2)` alias
//! ([`util::mmap`], raw syscall, no new dependencies):
//!
//! ```text
//! +----------------------------------------------------------+
//! | 64 B header: magic "RMSA" | version | file len | FNV-64  |
//! |   checksum | layer count | flags | table/manifest offsets|
//! +----------------------------------------------------------+
//! | n x 160 B layer records: name/kind/geometry/a_alpha +    |
//! |   offsets of the 7 per-layer sections                    |
//! +----------------------------------------------------------+
//! | 64-byte-aligned sections per layer: scheme codes, alphas,|
//! |   biases, class-sort permutation, quantized code plane,  |
//! |   pre-decoded PoT multiplier plane, sorted operand plane |
//! +----------------------------------------------------------+
//! | manifest JSON, embedded verbatim (self-contained file)   |
//! +----------------------------------------------------------+
//! ```
//!
//! * **Alignment** — every section offset is a multiple of 64 (one
//!   cache line, a divisor of the page size), so mapped planes keep the
//!   alignment the SIMD kernels see on the owned path; the loader
//!   rejects misaligned offsets.
//! * **Versioning** — the version field is a hard gate and the `flags`
//!   word must be zero in v1; growth lives in the reserved header and
//!   record bytes. Integrity is checked *before* any section is
//!   touched: magic, version, exact file length, and an FNV-1a-64
//!   checksum over the entire payload — any single bit flip, any
//!   truncation, and any trailing garbage fail loading with a typed
//!   error, never UB (pinned by property tests in
//!   `tests/test_artifact.rs`).
//! * **Zero-copy residency** — the O(rows·cols) planes are
//!   [`util::mmap::Plane`]s aliasing the mapping; only O(rows) metadata
//!   is copied. Logits are **bit-identical** to the parse path across
//!   batch, thread count, and ISA tier, and the mapped executor holds
//!   the same zero-allocation steady state (`tests/test_alloc.rs`).
//!   Deployment note: the page cache backs every process serving the
//!   same artifact with one physical copy, so N replicas (or N models
//!   A/B-paired on one host) cost ~1x the packed bytes, and a warm
//!   restart touches no disk.
//! * **Producers** — `rmsmp pack` (from legacy artifacts) and the
//!   Python exporter (`python/compile/export.py::write_rmsa`) emit the
//!   same bytes; [`model::ModelWeights::load`] sniffs the magic and
//!   dispatches, so every existing entry point accepts either format.
//! * **Multi-model quickstart** —
//!   `rmsmp serve --http 127.0.0.1:8080 --models a.rmsa,b.rmsa` boots
//!   one HTTP front-end over a [`coordinator::Router`] with N resident
//!   models: requests route on their `model` field (404 for unrouted
//!   names), `/metrics` reports per-model counters, and all variants
//!   share one GEMM pool (see `examples/serve_quantized.rs`).
//!
//! ## Kernel architecture
//!
//! Every mixed GEMM — packed activations or streamed conv panels, f32
//! or quantizing output — goes through **one public entry point**:
//! [`gemm::MixedGemm::dispatch`], taking a [`gemm::GemmCall`]
//! descriptor (activation source [`gemm::GemmActs`], sorted weights,
//! chunk schedule, output sink [`gemm::GemmOut`] with an optional
//! [`gemm::QuantEpilogue`]). The kernel layer under it is built from
//! five pieces:
//!
//! * **Implicit-GEMM panel packing** ([`gemm::ColTileSource`],
//!   `gemm/panels.rs`) — convolutions never materialize the
//!   `(N·OH·OW, C·k·k)` im2col matrix. The `GemmActs::Tiles` dispatch
//!   walks the output positions in column
//!   tiles; each tile is packed into a per-lane, cache-sized u8 panel —
//!   gathered straight from the producer's NCHW code slot, quantized on
//!   the fly from an f32 slot (the `n/alpha` reciprocal and clamp
//!   bounds hoisted out of the gather), or, for 1×1 stride-1 pad-0
//!   convs over a plan-retargeted **NHWC** code slot, aliased with no
//!   gather and no copy. Every row class and micro-kernel block of the
//!   layer sweeps the panel while it is L1/L2-hot, then the next tile
//!   is packed — consumer-driven tiling instead of producer-driven
//!   staging, the software analogue of streaming patches into the MAC
//!   array. Parallelism rides the tile axis (tiles own disjoint output
//!   positions). In-place convs keep the explicit staged path, so the
//!   workspace patch buffer shrinks to that fallback's high-water mark
//!   (zero when every conv is streamed).
//! * **Depthwise per-group streaming** (`gemm/depthwise.rs`) — grouped
//!   convs get the same panel treatment instead of the old row-by-row
//!   scalar fallback: the `depthwise` pass precompiles one chunk
//!   schedule per group over the layer's class-sorted layout (group
//!   rows stay contiguous inside each class block), and the kernel
//!   runs one panel-streamed GEMM per group with `fill: false`, each
//!   group writing its disjoint output-channel rows through the same
//!   micro-kernels and (when the edge is integer-resident) the same
//!   quantizing epilogue.
//! * **Class-sorted layout** ([`gemm::SortedWeights`]) — at load time
//!   each layer's rows are permuted so every scheme class occupies one
//!   contiguous block (the scheme-code order PoT-4, Fixed-4, Fixed-8,
//!   APoT-4), exactly how the FPGA streams one class's filters into its
//!   PE array back-to-back. PoT rows are pre-decoded to their
//!   `±2^(6-shift)` i8 multipliers so all three RMSMP classes share one
//!   u8 x i8 inner loop. A [`gemm::RowPartition`] is then just four
//!   ranges; the permutation and its inverse are stored so outputs
//!   scatter back to model row order (a bijection, so parallel tasks
//!   still write disjoint cells).
//! * **Micro-kernel blocking** — dispatch hands each task chunk to
//!   `GemmCore::run_block_tiled` in blocks of
//!   `ParallelConfig::micro_rows` rows (default [`gemm::MICRO_ROWS`],
//!   4; the SIMD tiers carry fused kernels for the whole
//!   [`gemm::MICRO_ROWS_CANDIDATES`] ladder of 4/6/8) over an
//!   [`gemm::ActsView`] (the full matrix or one packed panel — the
//!   kernels cannot tell): one activation tile load feeds the whole
//!   row block, cutting activation bandwidth 4-8x vs the
//!   row-at-a-time kernel, with the column loop still tiled at
//!   `ParallelConfig::tile_cols`. The block height is a tuned
//!   parameter, not a constant — see load-time autotuning below.
//! * **Runtime SIMD dispatch** ([`gemm::Isa`]) — the inner block dot
//!   ([`gemm::dot_block`]) is selected once per engine from a five-tier
//!   ladder, best supported tier first:
//!
//!   | tier | arch | inner step | u8 code range |
//!   |---|---|---|---|
//!   | `avx512vnni` | x86-64 | `vpdpbusd` (u8 x i8 -> i32, 64 lanes) | 0..=255 in-vector |
//!   | `avx2` | x86-64 | `vpmaddubsw`/`vpmaddwd` (32 lanes) | 0..=127; wider falls to scalar |
//!   | `sse41` | x86-64 | `pmaddubsw`/`pmaddwd` (16 lanes) | 0..=127; wider falls to scalar |
//!   | `neon` | aarch64 | `sdot` (i8 x i8 -> i32, 16 lanes) | 0..=127; wider falls to scalar |
//!   | `scalar` | any | portable i32 loop | 0..=255 |
//!
//!   The "wider falls to scalar" rule is the saturation clamp: the
//!   maddubs tiers saturate an i16 intermediate at codes above 127 and
//!   NEON `sdot` would misread them as negative, so activation widths
//!   above 7 bits reroute those tiers to scalar per block
//!   ([`gemm::Isa::wide_code_tier`]) — VNNI has no i16 intermediate and
//!   keeps its vector path at full 8-bit range. Hardware support is
//!   validated **once**, at engine construction, into a
//!   [`gemm::KernelIsa`] token the kernels trust without per-call
//!   re-checks. `RMSMP_ISA=scalar|sse41|avx2|avx512vnni|neon` forces a
//!   tier (clamped to what the host supports, with a one-shot warning),
//!   `RMSMP_NO_SIMD=1` is the deprecated scalar alias; the CI matrix
//!   runs the full test suite once per forced tier. No compile-time
//!   features, zero new dependencies.
//! * **Per-layer load-time autotuning** ([`gemm::autotune`]) —
//!   [`model::Plan`] compilation microbenchmarks the blocking knobs
//!   (`micro_rows` over the 4/6/8 candidate ladder, `tile_cols`,
//!   `min_rows_per_task`, implicit-GEMM panel bytes) once per distinct
//!   layer signature — (rows, cols, batch, scheme-class mix) — on a
//!   synthetic workload with that layer's own class mix, and bakes the
//!   per-layer winners into the compiled plan: each GEMM op carries its
//!   layer's `micro_rows`/`tile_cols`, chunk schedules and panel
//!   budgets are sized per layer, and the executor installs the baked
//!   knobs op by op (restoring the engine baseline afterwards).
//!   Executors built from the plan adopt the largest layer's winners
//!   for any knob the caller left at its default; explicit config
//!   values always win. A candidate must beat the incumbent by >2% to
//!   win, APoT layers keep their tile pinned, and `RMSMP_NO_TUNE=1`
//!   (or `PlanBuilder::no_tune`) compiles with the fixed defaults.
//! * **Persisted tune cache** — results are answered from a per-process
//!   cache, then an on-disk cache (`RMSMP_TUNE_CACHE=path`, or
//!   `rmsmp plan --tune-cache PATH` / `PlanBuilder::tune_cache`), then
//!   a live microbench, in that order. The cache key versions the
//!   tuning schema and spans the ISA tier, thread count, layer
//!   signature, and baseline knobs, so a file is safely shareable
//!   across models and invalidates itself across toolchain or hardware
//!   changes; writes go through a temp file + atomic rename, and a
//!   corrupt or stale file silently degrades to live tuning. A warm
//!   cache answers every layer without a single microbench dispatch
//!   (`Plan::tune_stats` reports the hit/miss provenance, `rmsmp plan`
//!   prints it per layer). Fleet deployment: run one plan compile per
//!   machine type at image-build time with `RMSMP_TUNE_CACHE` pointed
//!   into the image, and every production load boots with tuned
//!   blocking at zero microbench cost.
//!
//! **Bit-exactness guarantee:** the three RMSMP cores accumulate dot
//! products exactly in i32 and apply one dequantizing multiply per
//! output cell with the same expression in every kernel shape, and the
//! implicit panel packer shares its gather loop (and its quantizer
//! expression) with the explicit im2col fronts — so every ISA tier
//! (scalar, SSE4.1, AVX2, AVX-512 VNNI, NEON), row vs block, implicit
//! vs explicit, any tile size, any panel width, any chunk schedule, any
//! thread count, any micro-kernel block height, and tuned vs default
//! (vs warm-cache) blocking all produce bit-identical outputs (pinned
//! by `tests/test_simd.rs`, `tests/test_implicit.rs`,
//! `tests/test_autotune.rs`, and `tests/test_tunecache.rs`). The
//! f32-accumulating APoT baseline core stays on the scalar row loop and
//! is bit-exact for a fixed `tile_cols`, which the config pins and the
//! autotuner never moves.

pub mod assign;
pub mod coordinator;
pub mod fpga;
pub mod gemm;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;

pub use gemm::ParallelConfig;
pub use quant::scheme::Scheme;
pub use util::error::{Error, Result};
