//! im2col: lower NCHW conv to the row-wise mixed GEMM.
//!
//! The FPGA (and this executor) runs convolutions as GEMMs over unrolled
//! patches: output position (y, x) of image n becomes one GEMM row whose
//! columns are the receptive-field values; the weight matrix rows are the
//! filters. Grouped conv (MobileNet depthwise) unrolls per group.

use crate::gemm::panels::{pack_patch_rows, PatchGeometry};
use crate::quant::tensor::Tensor4;
use crate::quant::Mat;

/// Output spatial size for SAME-style padding (the panel packer's
/// formula — one definition shared with the implicit-GEMM path).
pub fn out_dim(in_dim: usize, k: usize, stride: usize, pad: usize) -> usize {
    crate::gemm::panels::out_dim(in_dim, k, stride, pad)
}

/// Unroll `x` into patch rows for a (k x k, stride, pad) conv.
///
/// Returns (patches, out_h, out_w): patches is (n*out_h*out_w, in_ch*k*k)
/// with the same column order as the OIHW weight reshape (ch-major, then
/// ky, kx) — matching `w.reshape(out_ch, -1)` on the Python side.
pub fn im2col(x: &Tensor4, k: usize, stride: usize, pad: usize) -> (Mat, usize, usize) {
    let mut m = Mat::zeros(0, 0);
    let (oh, ow) = im2col_into(x, k, stride, pad, &mut m);
    (m, oh, ow)
}

/// Allocation-free [`im2col`]: unrolls into `out` (resized in place, so a
/// preallocated matrix is reused across calls). Returns (out_h, out_w).
pub fn im2col_into(
    x: &Tensor4,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Mat,
) -> (usize, usize) {
    im2col_slice_into(&x.data, x.n, x.c, x.h, x.w, k, stride, pad, out)
}

/// [`im2col_into`] over a raw NCHW slice — the workspace slots store
/// feature maps as flat `Vec<f32>` buffers. Every element of `out` is
/// written (padding positions are written as literal zeros), so the
/// target never needs pre-zeroing.
pub fn im2col_slice_into(
    data: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Mat,
) -> (usize, usize) {
    im2col_range_into(data, n, c, h, w, 0, c, k, stride, pad, out)
}

/// im2col restricted to one channel group (depthwise: group g = channel g).
pub fn im2col_group(
    x: &Tensor4,
    group: usize,
    ch_per_group: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> (Mat, usize, usize) {
    let mut m = Mat::zeros(0, 0);
    let (oh, ow) = im2col_group_into(x, group, ch_per_group, k, stride, pad, &mut m);
    (m, oh, ow)
}

/// Allocation-free [`im2col_group`]; see [`im2col_into`].
pub fn im2col_group_into(
    x: &Tensor4,
    group: usize,
    ch_per_group: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Mat,
) -> (usize, usize) {
    im2col_range_into(
        &x.data,
        x.n,
        x.c,
        x.h,
        x.w,
        group * ch_per_group,
        ch_per_group,
        k,
        stride,
        pad,
        out,
    )
}

/// Shared kernel: unroll channels `c0..c0+nc` of an NCHW slice into patch
/// rows of `(n*oh*ow, nc*k*k)`.
pub fn im2col_range_into(
    data: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    c0: usize,
    nc: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Mat,
) -> (usize, usize) {
    let g = PatchGeometry::new(n, c, h, w, c0, nc, k, stride, pad);
    out.resize(g.batch(), g.cols());
    pack_patch_rows(data, 0.0f32, &g, 0, g.batch(), &mut out.data);
    (g.oh, g.ow)
}

/// [`im2col_range_into`] over **activation codes**: unrolls a u8 NCHW
/// code slot into GEMM-ready patch rows, written into `out` (resized in
/// place, reused across calls). This is the explicit fallback of the
/// integer-resident datapath's im2col — the codes flow through
/// untouched, and padding positions get the literal code `0`, which
/// *is* the code of the value 0.0 (the activation quantizer is unsigned
/// with its zero point at code 0), so no zero-point arithmetic is
/// needed. Returns (out_h, out_w).
///
/// Both fronts delegate to the per-tile panel packer
/// ([`pack_patch_rows`]) over the full row range — the same gather loop
/// the implicit-GEMM dispatch runs per column tile — so the explicit
/// and implicit paths move the same element to the same cell by
/// construction.
pub fn im2col_codes_range_into(
    data: &[u8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    c0: usize,
    nc: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<u8>,
) -> (usize, usize) {
    let g = PatchGeometry::new(n, c, h, w, c0, nc, k, stride, pad);
    out.resize(g.batch() * g.cols(), 0);
    pack_patch_rows(data, 0u8, &g, 0, g.batch(), out);
    (g.oh, g.ow)
}

/// Fold GEMM output (n*oh*ow, out_ch) back into NCHW.
pub fn col2im(y: &Mat, n: usize, out_ch: usize, oh: usize, ow: usize) -> Tensor4 {
    let mut t = Tensor4::zeros(n, out_ch, oh, ow);
    col2im_slice_into(y, n, out_ch, oh, ow, &mut t.data);
    t
}

/// Allocation-free [`col2im`]: folds into a flat NCHW slice (a workspace
/// slot). Every element of `dst` is written.
pub fn col2im_slice_into(
    y: &Mat,
    n: usize,
    out_ch: usize,
    oh: usize,
    ow: usize,
    dst: &mut [f32],
) {
    assert_eq!(y.rows, n * oh * ow);
    assert_eq!(y.cols, out_ch);
    assert_eq!(dst.len(), n * out_ch * oh * ow);
    for img in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (img * oh + oy) * ow + ox;
                for c in 0..out_ch {
                    dst[((img * out_ch + c) * oh + oy) * ow + ox] = y.at(row, c);
                }
            }
        }
    }
}

/// Reference float conv (oracle for the GEMM path).
pub fn conv_ref(
    x: &Tensor4,
    w: &[f32],
    out_ch: usize,
    in_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> Tensor4 {
    assert_eq!(w.len(), out_ch * in_ch * k * k);
    let oh = out_dim(x.h, k, stride, pad);
    let ow = out_dim(x.w, k, stride, pad);
    let mut out = Tensor4::zeros(x.n, out_ch, oh, ow);
    for n in 0..x.n {
        for oc in 0..out_ch {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ic in 0..in_ch {
                        for ky in 0..k {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy as usize >= x.h {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix as usize >= x.w {
                                    continue;
                                }
                                acc += x.at(n, ic, iy as usize, ix as usize)
                                    * w[((oc * in_ch + ic) * k + ky) * k + kx];
                            }
                        }
                    }
                    out.set(n, oc, oy, ox, acc);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t4(n: usize, c: usize, h: usize, w: usize, seed: u64) -> Tensor4 {
        let mut rng = Rng::new(seed);
        let mut t = Tensor4::zeros(n, c, h, w);
        for v in t.data.iter_mut() {
            *v = rng.normal();
        }
        t
    }

    #[test]
    fn out_dim_same_padding() {
        assert_eq!(out_dim(32, 3, 1, 1), 32);
        assert_eq!(out_dim(32, 3, 2, 1), 16);
        assert_eq!(out_dim(8, 1, 1, 0), 8);
    }

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        let x = rand_t4(2, 3, 8, 8, 1);
        let (out_ch, in_ch, k) = (4, 3, 3);
        let mut rng = Rng::new(2);
        let w: Vec<f32> = (0..out_ch * in_ch * k * k).map(|_| rng.normal()).collect();

        let want = conv_ref(&x, &w, out_ch, in_ch, k, 1, 1);

        let (patches, oh, ow) = im2col(&x, k, 1, 1);
        let wmat = Mat::from_vec(out_ch, in_ch * k * k, w);
        let y = patches.matmul_nt(&wmat);
        let got = col2im(&y, 2, out_ch, oh, ow);

        let err = got
            .data
            .iter()
            .zip(&want.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn strided_conv_matches() {
        let x = rand_t4(1, 2, 9, 9, 3);
        let (out_ch, in_ch, k) = (3, 2, 3);
        let mut rng = Rng::new(4);
        let w: Vec<f32> = (0..out_ch * in_ch * k * k).map(|_| rng.normal()).collect();
        let want = conv_ref(&x, &w, out_ch, in_ch, k, 2, 1);
        let (patches, oh, ow) = im2col(&x, k, 2, 1);
        let y = patches.matmul_nt(&Mat::from_vec(out_ch, in_ch * k * k, w));
        let got = col2im(&y, 1, out_ch, oh, ow);
        assert_eq!((got.h, got.w), (want.h, want.w));
        let err = got
            .data
            .iter()
            .zip(&want.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(err < 1e-4);
    }

    #[test]
    fn code_im2col_matches_float_im2col_cell_for_cell() {
        // quantize-then-im2col must equal im2col-then-quantize: the code
        // kernel moves codes exactly where the float kernel moves values,
        // and padding's code 0 is the code of 0.0 (zero-point-free).
        let mut rng = Rng::new(9);
        let (n, c, h, w) = (2usize, 3usize, 5usize, 6usize);
        let vals: Vec<f32> = (0..n * c * h * w).map(|_| rng.uniform(0.0, 1.3)).collect();
        let inv = 15.0f32 / 0.9;
        let codes: Vec<u8> = vals
            .iter()
            .map(|&v| (v * inv).clamp(0.0, 15.0).round_ties_even() as u8)
            .collect();
        let cases = [(3, 1, 1, 0, 3), (3, 2, 0, 0, 3), (1, 1, 0, 1, 1), (3, 1, 1, 2, 1)];
        for (k, s, p, c0, nc) in cases {
            let mut fpatch = Mat::zeros(0, 0);
            let (oh, ow) =
                im2col_range_into(&vals, n, c, h, w, c0, nc, k, s, p, &mut fpatch);
            let mut cpatch = Vec::new();
            let (oh2, ow2) =
                im2col_codes_range_into(&codes, n, c, h, w, c0, nc, k, s, p, &mut cpatch);
            assert_eq!((oh, ow), (oh2, ow2));
            assert_eq!(cpatch.len(), fpatch.data.len());
            for (got, &v) in cpatch.iter().zip(&fpatch.data) {
                let want = (v * inv).clamp(0.0, 15.0).round_ties_even() as u8;
                assert_eq!(*got, want, "k={k} s={s} p={p} c0={c0}");
            }
        }
    }

    #[test]
    fn group_unroll_shape() {
        let x = rand_t4(1, 4, 6, 6, 5);
        let (m, oh, ow) = im2col_group(&x, 2, 1, 3, 1, 1);
        assert_eq!(m.rows, oh * ow);
        assert_eq!(m.cols, 9);
        assert_eq!((oh, ow), (6, 6));
    }
}
