//! The plan compiler: `manifest.program` → a slot-indexed [`Plan`].
//!
//! RMSMP's layer-wise-uniform row mixing makes the compute structure of a
//! model fully static: every buffer shape, im2col geometry, group slice,
//! and GEMM partition is derivable from the manifest + weights at load
//! time. This module does that derivation **once** — resolving buffer
//! names to dense slot ids, precomputing per-op geometry, shape-checking
//! the whole program, chunking each layer's row partition into a GEMM
//! task schedule, and sizing a high-water memory footprint — so that the
//! executor's steady-state `infer` is a plain walk over precompiled ops
//! against preallocated [`super::workspace::Workspace`] buffers, with no
//! name resolution, no shape discovery, and no buffer allocation (see
//! the crate docs for the exact per-mode zero-allocation guarantee).
//!
//! A `Plan` is immutable and shareable (`Arc<Plan>`): the serving
//! coordinator compiles one per model and hands every worker the same
//! plan next to its private workspace.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::ensure;
use crate::err;
use crate::gemm::{chunk_tasks, ParallelConfig, Requant, RowPartition, TaskChunk, MICRO_ROWS};
use crate::util::error::Result;

use super::im2col::out_dim;
use super::manifest::{Manifest, OpMeta};
use super::weights::ModelWeights;

/// Dense index of a program buffer ("in0", "b3", "logits", ...).
pub type SlotId = usize;

/// Shape of a slot's contents, per batch image (T4) or batch row (M).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotKind {
    /// Feature map: (channels, height, width) per image.
    T4 { c: usize, h: usize, w: usize },
    /// Matrix: `cols` values per batch row.
    M { cols: usize },
}

impl SlotKind {
    /// Elements per batch image.
    pub fn per_image(&self) -> usize {
        match *self {
            SlotKind::T4 { c, h, w } => c * h * w,
            SlotKind::M { cols } => cols,
        }
    }
}

/// One resolved program buffer.
#[derive(Clone, Debug)]
pub struct SlotSpec {
    pub name: String,
    /// Shape of the last write (programs may reuse a name; the per-op
    /// geometry below is what the runner actually consumes).
    pub kind: SlotKind,
    /// High-water elements per batch image across every write.
    pub per_image: usize,
    /// Some write leaves this slot in the f32 domain (the workspace
    /// allocates its f32 buffer). Set by the output-domain inference.
    pub holds_f32: bool,
    /// Some write leaves this slot integer-resident — u8 activation
    /// codes of the consuming layer's quantizer (the workspace allocates
    /// its u8 code buffer).
    pub holds_codes: bool,
    /// The code buffer is stored NHWC (row-major positions × channels)
    /// instead of NCHW: the layout-retarget pass proved every code
    /// writer is a non-grouped implicit conv and every code reader a
    /// 1×1 stride-1 pad-0 conv, so the readers alias the slot directly
    /// as their GEMM activation panel — no gather, no copy.
    pub code_nhwc: bool,
}

/// One compiled op: slot ids + all geometry the runner needs, resolved
/// and shape-checked at load time.
#[derive(Clone, Debug)]
pub enum PlanOp {
    Conv {
        /// Index into `ModelWeights::layers` (== `Plan::layer_parts`).
        layer: usize,
        input: SlotId,
        out: SlotId,
        relu: bool,
        /// Input feature-map dims per image.
        in_c: usize,
        in_h: usize,
        in_w: usize,
        /// Output spatial dims.
        oh: usize,
        ow: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        ch_per_group: usize,
        filt_per_group: usize,
        /// Precompiled GEMM task schedule (empty for grouped conv, which
        /// dispatches row-by-row per group).
        chunks: Vec<TaskChunk>,
        /// The input slot is integer-resident: the GEMM reads u8 codes
        /// directly, skipping the f32 unroll + requantize.
        in_codes: bool,
        /// Integer-resident output: the GEMM epilogue maps accumulators
        /// straight to the consumer layer's activation codes (fused
        /// dequant → bias → ReLU → requantize → NCHW scatter). `None` =
        /// f32 fallback (consumer is Add/Gap/logits or consumers
        /// disagree on scale).
        out_quant: Option<Requant>,
        /// Run as an implicit GEMM: the executor streams the input
        /// through column-tile panels
        /// ([`crate::gemm::MixedGemm::run_implicit_into`]) instead of
        /// materializing the im2col matrix. Compiled for non-grouped,
        /// non-aliased (input != out) convs of an implicit-enabled plan.
        implicit: bool,
        /// Packed panel width (output positions per column tile), sized
        /// so one panel (`panel_positions * cols` u8 codes) stays
        /// cache-resident. 0 on the explicit path.
        panel_positions: usize,
        /// The input code slot is stored NHWC (see
        /// [`SlotSpec::code_nhwc`]): alias it as the activation panel.
        in_nhwc: bool,
        /// Emit output codes NHWC (RowMajor scatter) instead of NCHW —
        /// every consumer is a unit conv that will alias them.
        out_nhwc: bool,
    },
    Linear {
        layer: usize,
        input: SlotId,
        out: SlotId,
        in_cols: usize,
        out_cols: usize,
        chunks: Vec<TaskChunk>,
        /// See [`PlanOp::Conv::in_codes`].
        in_codes: bool,
        /// See [`PlanOp::Conv::out_quant`].
        out_quant: Option<Requant>,
    },
    Add {
        a: SlotId,
        b: SlotId,
        out: SlotId,
        relu: bool,
        /// Elements per image of each operand (shapes checked equal).
        per_image: usize,
    },
    Gap {
        input: SlotId,
        out: SlotId,
        c: usize,
        h: usize,
        w: usize,
    },
}

/// Preallocation sizes for one workspace instance, all at `capacity`
/// batch images. Single source of truth for [`super::Workspace`] and the
/// `rmsmp plan` footprint report.
#[derive(Clone, Debug)]
pub struct Footprint {
    pub capacity: usize,
    pub lanes: usize,
    /// Per-slot f32 elements (0 for slots that are only ever
    /// integer-resident).
    pub slot_elems: Vec<usize>,
    /// Per-slot u8 activation-code elements (0 for f32-only slots).
    pub code_slot_elems: Vec<usize>,
    /// im2col patch-matrix f32 elements — only the ops still on the
    /// explicit path (grouped convs, or every conv when the plan was
    /// compiled without implicit GEMM) stage through it, so for an
    /// implicit plan this is the grouped-conv fallback high-water mark
    /// (0 when every conv runs implicitly).
    pub patch_elems: usize,
    /// Quantized activation codes (u8) — explicit-path convs and the
    /// linear ops; implicit convs stream through per-lane panels
    /// instead.
    pub acts_elems: usize,
    /// GEMM/Gap staging matrix f32 elements.
    pub gemm_out_elems: usize,
    /// Per-lane scratch length: one [`MICRO_ROWS`]-row micro-kernel
    /// block (an f32 output block + an i32 accumulator block of this
    /// many elements each).
    pub lane_elems: usize,
    /// Per-lane implicit-GEMM panel bytes (u8 activation codes for one
    /// `panel_positions`-wide column tile of the widest implicit conv).
    pub panel_elems: usize,
    /// Logits output matrix f32 elements.
    pub logits_elems: usize,
}

impl Footprint {
    /// Bytes of one slot: its f32 buffer plus its u8 code buffer.
    pub fn slot_bytes(&self, slot: SlotId) -> usize {
        4 * self.slot_elems[slot] + self.code_slot_elems[slot]
    }

    pub fn total_slot_bytes(&self) -> usize {
        4 * self.slot_elems.iter().sum::<usize>() + self.code_slot_elems.iter().sum::<usize>()
    }

    /// Bytes of the shared scratch (patches + acts + staging + lanes +
    /// logits). Each GEMM lane holds an f32 block, an i32 block, a u8
    /// code block for the fused requantization epilogue, and a u8
    /// implicit-GEMM panel.
    pub fn scratch_bytes(&self) -> usize {
        4 * self.patch_elems
            + self.acts_elems
            + 4 * self.gemm_out_elems
            + self.lanes * (self.lane_elems * (4 + 4 + 1) + self.panel_elems)
            + 4 * self.logits_elems
    }

    /// Total workspace bytes.
    pub fn total_bytes(&self) -> usize {
        self.total_slot_bytes() + self.scratch_bytes()
    }
}

/// A compiled, immutable execution plan (see module docs).
#[derive(Clone, Debug)]
pub struct Plan {
    pub model: String,
    /// Batch images the workspace preallocates for; larger batches still
    /// run correctly, growing the buffers once (a warm-up event).
    pub capacity: usize,
    /// GEMM rows per task chunk the schedules were compiled with.
    pub chunk_rows: usize,
    /// Whether output-domain inference ran: integer-resident edges carry
    /// u8 activation codes between GEMMs (`false` = every edge f32, the
    /// pre-fusion baseline kept for benchmarking).
    pub integer_resident: bool,
    /// Whether non-grouped convs were compiled for the implicit-GEMM
    /// path (`false` = the explicit-im2col baseline kept for
    /// benchmarking).
    pub implicit: bool,
    pub act_bits: u32,
    pub input_slot: SlotId,
    /// Expected (c, h, w) of the inference input.
    pub input_chw: (usize, usize, usize),
    pub logits_slot: SlotId,
    pub logits_cols: usize,
    pub slots: Vec<SlotSpec>,
    pub ops: Vec<PlanOp>,
    /// Row partition of every weights layer, in `ModelWeights::layers`
    /// order.
    pub layer_parts: Vec<RowPartition>,
    /// High-water per-image scratch geometry (see [`Footprint`]).
    pub max_patch_per_image: usize,
    pub max_acts_per_image: usize,
    pub max_gemm_rows_per_image: usize,
    pub max_gemm_out_per_image: usize,
    /// Widest implicit-GEMM panel (u8 elements, absolute — a panel's
    /// size is batch-independent) and its position count.
    pub max_panel_elems: usize,
    pub max_panel_positions: usize,
}

/// Compile-time dataflow toggles (both default on — the production
/// path). The off positions keep the older dataflows compilable as
/// benchmark baselines and differential-test twins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanOptions {
    /// Run output-domain inference (u8 codes between GEMMs).
    pub integer_resident: bool,
    /// Compile non-grouped convs for the implicit-GEMM panel path
    /// (`false` = explicit im2col through the workspace patch buffer).
    pub implicit: bool,
}

impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions { integer_resident: true, implicit: true }
    }
}

/// Target size of one implicit-GEMM activation panel: positions are
/// chosen so `panel_positions * patch_cols` u8 codes land around half an
/// L1d next to the weight tiles, clamped to keep at least a micro-
/// kernel block's worth of positions and at most a reasonable tile.
const PANEL_BYTES: usize = 32 * 1024;

impl Plan {
    /// Compile `manifest.program` against `weights`. `capacity` sizes the
    /// workspace high-water marks (batch images); `cfg` fixes the GEMM
    /// task granularity so plan schedules match the engine's chunking.
    pub fn compile(
        manifest: &Manifest,
        weights: &ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
    ) -> Result<Plan> {
        Plan::compile_opts(manifest, weights, capacity, cfg, PlanOptions::default())
    }

    /// [`Plan::compile`] with the integer-resident dataflow toggleable
    /// (the implicit-GEMM path stays on): `integer_resident = false`
    /// skips output-domain inference, keeping every inter-layer edge in
    /// f32 — the f32 side of the differential tests and the
    /// requantization-fusion bench baseline.
    pub fn compile_with(
        manifest: &Manifest,
        weights: &ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
        integer_resident: bool,
    ) -> Result<Plan> {
        Plan::compile_opts(
            manifest,
            weights,
            capacity,
            cfg,
            PlanOptions { integer_resident, ..PlanOptions::default() },
        )
    }

    /// [`Plan::compile`] with every dataflow toggle explicit (see
    /// [`PlanOptions`]); `implicit = false` compiles the
    /// explicit-im2col conv path — the baseline `bench_runtime` reports
    /// the implicit-GEMM speedup against.
    pub fn compile_opts(
        manifest: &Manifest,
        weights: &ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
        opts: PlanOptions,
    ) -> Result<Plan> {
        let integer_resident = opts.integer_resident;
        ensure!(
            manifest.input_shape.len() == 4,
            "manifest input_shape must be NCHW, got {:?}",
            manifest.input_shape
        );
        let capacity = capacity.max(1);
        let chunk_rows = cfg.min_rows_per_task.max(1);
        let input_chw = (
            manifest.input_shape[1],
            manifest.input_shape[2],
            manifest.input_shape[3],
        );

        let layer_parts: Vec<RowPartition> = weights
            .layers
            .iter()
            .map(|l| RowPartition::from_schemes(&l.scheme))
            .collect();

        let mut slots: Vec<SlotSpec> = Vec::new();
        let mut index: HashMap<String, SlotId> = HashMap::new();

        // The program input is pre-seeded under the fixed name "in0",
        // mirroring the interpreter's calling convention.
        let input_kind = SlotKind::T4 { c: input_chw.0, h: input_chw.1, w: input_chw.2 };
        let input_slot = 0;
        slots.push(SlotSpec {
            name: "in0".to_string(),
            kind: input_kind,
            per_image: input_kind.per_image(),
            // `infer` seeds the input as floats — the first conv always
            // quantizes (the f32 entry edge of the pipeline)
            holds_f32: true,
            holds_codes: false,
            code_nhwc: false,
        });
        index.insert("in0".to_string(), input_slot);

        // Every id in `index` has been written (define records the shape
        // of the latest write in slots[id].kind), so lookup is the only
        // failure mode.
        let read = |slots: &[SlotSpec],
                    index: &HashMap<String, SlotId>,
                    name: &str|
         -> Result<(SlotId, SlotKind)> {
            let id = *index
                .get(name)
                .ok_or_else(|| err!("missing buffer {name}"))?;
            Ok((id, slots[id].kind))
        };

        let mut ops = Vec::with_capacity(manifest.program.len());
        let mut max_patch = 0usize;
        let mut max_acts = 0usize;
        let mut max_gemm_rows = 0usize;
        let mut max_gemm_out = 0usize;
        let mut max_panel_elems = 0usize;
        let mut max_panel_positions = 0usize;

        for op in &manifest.program {
            match op {
                OpMeta::Conv { layer, input, out, relu } => {
                    manifest.layer(layer)?;
                    let li = weights.layer_index(layer)?;
                    let lw = &weights.layers[li];
                    let (in_id, kind) = read(&slots, &index, input)?;
                    let SlotKind::T4 { c, h, w } = kind else {
                        return Err(err!("conv {layer}: input {input} is not a 4-D buffer"));
                    };
                    let k = lw.kh;
                    let stride = lw.stride;
                    let pad = lw.pad;
                    let groups = lw.groups.max(1);
                    ensure!(stride >= 1, "conv {layer}: stride must be >= 1");
                    ensure!(
                        h + 2 * pad >= k && w + 2 * pad >= k,
                        "conv {layer}: {k}x{k} kernel exceeds padded {h}x{w} input"
                    );
                    ensure!(
                        c % groups == 0,
                        "conv {layer}: {c} input channels not divisible by {groups} groups"
                    );
                    ensure!(
                        lw.out_ch % groups == 0,
                        "conv {layer}: {} filters not divisible by {groups} groups",
                        lw.out_ch
                    );
                    ensure!(
                        lw.rows == lw.out_ch,
                        "conv {layer}: weight rows {} != out channels {}",
                        lw.rows,
                        lw.out_ch
                    );
                    let ch_per_group = c / groups;
                    ensure!(
                        ch_per_group * k * k == lw.cols,
                        "conv {layer}: im2col cols {} != weight cols {}",
                        ch_per_group * k * k,
                        lw.cols
                    );
                    let oh = out_dim(h, k, stride, pad);
                    let ow = out_dim(w, k, stride, pad);
                    let out_kind = SlotKind::T4 { c: lw.out_ch, h: oh, w: ow };
                    let out_id = define(&mut slots, &mut index, out, out_kind);
                    // an in-place conv (input slot == output slot) cannot
                    // stream: the implicit GEMM reads the input while
                    // writing the output, so it keeps the staged path
                    let implicit = opts.implicit && groups == 1 && in_id != out_id;
                    let panel_positions = if implicit {
                        // cache-sized, but never wider than the op's
                        // whole batch at plan capacity — a panel bigger
                        // than the operand is pure waste
                        (PANEL_BYTES / lw.cols.max(1))
                            .clamp(8, 256)
                            .min((oh * ow * capacity).max(1))
                    } else {
                        0
                    };
                    if implicit {
                        // implicit convs never touch the patch/acts
                        // staging — they stream per-lane panels
                        max_panel_elems = max_panel_elems.max(panel_positions * lw.cols);
                        max_panel_positions = max_panel_positions.max(panel_positions);
                    } else {
                        max_patch = max_patch.max(oh * ow * lw.cols);
                        max_acts = max_acts.max(oh * ow * lw.cols);
                        max_gemm_rows = max_gemm_rows.max(oh * ow);
                    }
                    max_gemm_out = max_gemm_out.max(oh * ow * lw.out_ch);
                    let chunks = if groups == 1 {
                        chunk_tasks(&layer_parts[li], chunk_rows)
                    } else {
                        Vec::new()
                    };
                    ops.push(PlanOp::Conv {
                        layer: li,
                        input: in_id,
                        out: out_id,
                        relu: *relu,
                        in_c: c,
                        in_h: h,
                        in_w: w,
                        oh,
                        ow,
                        k,
                        stride,
                        pad,
                        groups,
                        ch_per_group,
                        filt_per_group: lw.out_ch / groups,
                        chunks,
                        in_codes: false,
                        out_quant: None,
                        implicit,
                        panel_positions,
                        in_nhwc: false,
                        out_nhwc: false,
                    });
                }
                OpMeta::Linear { layer, input, out } => {
                    manifest.layer(layer)?;
                    let li = weights.layer_index(layer)?;
                    let lw = &weights.layers[li];
                    let (in_id, kind) = read(&slots, &index, input)?;
                    let SlotKind::M { cols } = kind else {
                        return Err(err!("linear {layer}: input {input} is not a 2-D buffer"));
                    };
                    ensure!(
                        cols == lw.cols,
                        "linear {layer}: input cols {cols} != weight cols {}",
                        lw.cols
                    );
                    let out_id =
                        define(&mut slots, &mut index, out, SlotKind::M {
                            cols: lw.rows,
                        });
                    max_acts = max_acts.max(lw.cols);
                    max_gemm_rows = max_gemm_rows.max(1);
                    max_gemm_out = max_gemm_out.max(lw.rows);
                    ops.push(PlanOp::Linear {
                        layer: li,
                        input: in_id,
                        out: out_id,
                        in_cols: lw.cols,
                        out_cols: lw.rows,
                        chunks: chunk_tasks(&layer_parts[li], chunk_rows),
                        in_codes: false,
                        out_quant: None,
                    });
                }
                OpMeta::Add { a, b, out, relu } => {
                    let (a_id, ka) = read(&slots, &index, a)?;
                    let (b_id, kb) = read(&slots, &index, b)?;
                    let (SlotKind::T4 { .. }, SlotKind::T4 { .. }) = (ka, kb) else {
                        return Err(err!("add {a}+{b}: operands must be 4-D buffers"));
                    };
                    ensure!(
                        ka.per_image() == kb.per_image(),
                        "add shape mismatch {a} {b}"
                    );
                    let out_id = define(&mut slots, &mut index, out, ka);
                    ops.push(PlanOp::Add {
                        a: a_id,
                        b: b_id,
                        out: out_id,
                        relu: *relu,
                        per_image: ka.per_image(),
                    });
                }
                OpMeta::Gap { input, out } => {
                    let (in_id, kind) = read(&slots, &index, input)?;
                    let SlotKind::T4 { c, h, w } = kind else {
                        return Err(err!("gap: input {input} is not a 4-D buffer"));
                    };
                    let out_id =
                        define(&mut slots, &mut index, out, SlotKind::M { cols: c });
                    // gap stages its output through the GEMM staging
                    // matrix (aliasing-safe), so it contributes to it
                    max_gemm_out = max_gemm_out.max(c);
                    ops.push(PlanOp::Gap { input: in_id, out: out_id, c, h, w });
                }
            }
        }

        let logits_slot = *index
            .get("logits")
            .ok_or_else(|| err!("program produced no 'logits' matrix"))?;
        let SlotKind::M { cols: logits_cols } = slots[logits_slot].kind else {
            return Err(err!("program produced no 'logits' matrix"));
        };

        if integer_resident {
            infer_domains(&mut ops, &mut slots, weights, manifest.act_bits, logits_slot);
            if opts.implicit {
                infer_code_layouts(&mut ops, &mut slots);
            }
        } else {
            for op in &ops {
                slots[op_write(op).0].holds_f32 = true;
            }
        }

        Ok(Plan {
            model: manifest.model.clone(),
            capacity,
            chunk_rows,
            integer_resident,
            implicit: opts.implicit,
            act_bits: manifest.act_bits,
            input_slot,
            input_chw,
            logits_slot,
            logits_cols,
            slots,
            ops,
            layer_parts,
            max_patch_per_image: max_patch,
            max_acts_per_image: max_acts,
            max_gemm_rows_per_image: max_gemm_rows,
            max_gemm_out_per_image: max_gemm_out,
            max_panel_elems,
            max_panel_positions,
        })
    }

    /// Check that the plan's baked integer-resident epilogue scales
    /// still match `weights`: a plan compiled against a different
    /// weights table could otherwise requantize inter-layer activations
    /// with a stale consumer clip scale (the f32 fallback reads the
    /// scale from the weights at run time and cannot go stale).
    /// `Executor::from_shared` runs this next to its partition checks.
    pub fn validate_domains(&self, weights: &ModelWeights) -> Result<()> {
        for i in 0..self.ops.len() {
            let rq = match &self.ops[i] {
                PlanOp::Conv { out_quant, .. } | PlanOp::Linear { out_quant, .. } => *out_quant,
                _ => None,
            };
            let Some(rq) = rq else { continue };
            let (s, _) = op_write(&self.ops[i]);
            // the exact reader set the scale was baked for, re-derived
            // with the same live-range scan the inference used
            let (reads, _) = live_range_reads(&self.ops, i, weights);
            for (_, q) in reads {
                let alpha = q
                    .ok_or_else(|| err!("integer-resident slot {s} read by a non-GEMM op"))?;
                ensure!(
                    rq == Requant::new(alpha, self.act_bits),
                    "plan/weights mismatch: integer-resident epilogue scale of slot \
                     {s} differs from the consumer's clip scale"
                );
            }
        }
        Ok(())
    }

    /// Preallocation sizes for a workspace with `lanes` GEMM scratch
    /// lanes (see [`crate::gemm::MixedGemm::lanes`]).
    pub fn footprint(&self, lanes: usize) -> Footprint {
        let n = self.capacity;
        Footprint {
            capacity: n,
            lanes: lanes.max(1),
            slot_elems: self
                .slots
                .iter()
                .map(|s| if s.holds_f32 { s.per_image * n } else { 0 })
                .collect(),
            code_slot_elems: self
                .slots
                .iter()
                .map(|s| if s.holds_codes { s.per_image * n } else { 0 })
                .collect(),
            patch_elems: self.max_patch_per_image * n,
            acts_elems: self.max_acts_per_image * n,
            gemm_out_elems: self.max_gemm_out_per_image * n,
            // lanes serve both the explicit blocks (MICRO_ROWS x full
            // batch) and the implicit blocks (MICRO_ROWS x panel
            // positions) — size for whichever is wider
            lane_elems: MICRO_ROWS
                * (self.max_gemm_rows_per_image * n).max(self.max_panel_positions),
            panel_elems: self.max_panel_elems,
            logits_elems: self.logits_cols * n,
        }
    }

    /// Human-readable plan dump for `rmsmp plan`: ops, slot assignments,
    /// per-slot bytes, and the total workspace footprint — the numbers
    /// an FPGA BRAM budget would be sized from.
    pub fn describe(&self, weights: &ModelWeights, lanes: usize) -> String {
        let fp = self.footprint(lanes);
        let mut s = String::new();
        let _ = writeln!(
            s,
            "plan {}: {} ops, {} slots, capacity batch {}, chunk rows {}, act bits {}, \
             dataflow {}, conv {}",
            self.model,
            self.ops.len(),
            self.slots.len(),
            self.capacity,
            self.chunk_rows,
            self.act_bits,
            if self.integer_resident { "integer-resident" } else { "f32-resident" },
            if self.implicit { "implicit-gemm" } else { "explicit-im2col" }
        );
        let _ = writeln!(s, "slots:");
        for (i, spec) in self.slots.iter().enumerate() {
            let kind = match spec.kind {
                SlotKind::T4 { c, h, w } => format!("T4 {c}x{h}x{w}"),
                SlotKind::M { cols } => format!("M  {cols}"),
            };
            let domain = match (spec.holds_f32, spec.holds_codes, spec.code_nhwc) {
                (true, true, false) => "f32+u8",
                (true, true, true) => "f32+u8~",
                (false, true, false) => "u8",
                // '~' marks an NHWC-retargeted code buffer (unit-conv
                // alias fast path)
                (false, true, true) => "u8~",
                _ => "f32",
            };
            let _ = writeln!(
                s,
                "  s{i:<3} {:<12} {kind:<16} {domain:<7} {:>9} elems/img {:>12} B",
                spec.name,
                spec.per_image,
                fp.slot_bytes(i)
            );
        }
        let _ = writeln!(s, "ops:");
        for (i, op) in self.ops.iter().enumerate() {
            let line = match op {
                PlanOp::Conv {
                    layer,
                    input,
                    out,
                    relu,
                    oh,
                    ow,
                    k,
                    stride,
                    pad,
                    groups,
                    chunks,
                    in_codes,
                    out_quant,
                    implicit,
                    panel_positions,
                    in_nhwc,
                    out_nhwc,
                    ..
                } => {
                    let lw = &weights.layers[*layer];
                    let path = match (implicit, in_nhwc) {
                        (true, true) => format!(" alias panel={panel_positions}"),
                        (true, false) => format!(" implicit panel={panel_positions}"),
                        (false, _) => String::new(),
                    };
                    format!(
                        "conv   {:<12} s{input}{} -> s{out}{}  {}x{} k{k} s{stride} p{pad} \
                         g{groups} oh={oh} ow={ow} chunks={}{}{path}",
                        lw.name,
                        if *in_codes { "[u8]" } else { "" },
                        match (out_quant.is_some(), *out_nhwc) {
                            (true, true) => "[u8~]",
                            (true, false) => "[u8]",
                            _ => "",
                        },
                        lw.rows,
                        lw.cols,
                        chunks.len(),
                        if *relu { " relu" } else { "" }
                    )
                }
                PlanOp::Linear {
                    layer, input, out, in_cols, out_cols, chunks, in_codes, out_quant,
                } => {
                    let lw = &weights.layers[*layer];
                    format!(
                        "linear {:<12} s{input}{} -> s{out}{}  {out_cols}x{in_cols} chunks={}",
                        lw.name,
                        if *in_codes { "[u8]" } else { "" },
                        if out_quant.is_some() { "[u8]" } else { "" },
                        chunks.len()
                    )
                }
                PlanOp::Add { a, b, out, relu, per_image } => format!(
                    "add    {:<12} s{a} + s{b} -> s{out}  {per_image} elems/img{}",
                    "",
                    if *relu { " relu" } else { "" }
                ),
                PlanOp::Gap { input, out, c, h, w } => {
                    format!("gap    {:<12} s{input} -> s{out}  {c}x{h}x{w} -> {c}", "")
                }
            };
            let _ = writeln!(s, "  {i:<3} {line}");
        }
        let _ = writeln!(
            s,
            "workspace (lanes={}): slots {} B + patches {} B + acts {} B + staging {} B + \
             lane scratch {} B + panels {} B + logits {} B = {} B total",
            fp.lanes,
            fp.total_slot_bytes(),
            4 * fp.patch_elems,
            fp.acts_elems,
            4 * fp.gemm_out_elems,
            fp.lanes * fp.lane_elems * 9,
            fp.lanes * fp.panel_elems,
            4 * fp.logits_elems,
            fp.total_bytes()
        );
        s
    }
}

/// Record a write of `kind` to slot `name`, creating the slot on first
/// use and widening its high-water footprint.
fn define(
    slots: &mut Vec<SlotSpec>,
    index: &mut HashMap<String, SlotId>,
    name: &str,
    kind: SlotKind,
) -> SlotId {
    match index.get(name) {
        Some(&id) => {
            slots[id].kind = kind;
            slots[id].per_image = slots[id].per_image.max(kind.per_image());
            id
        }
        None => {
            let id = slots.len();
            slots.push(SlotSpec {
                name: name.to_string(),
                kind,
                per_image: kind.per_image(),
                // domains and code layouts are assigned by the inference
                // passes once every write and read is known
                holds_f32: false,
                holds_codes: false,
                code_nhwc: false,
            });
            index.insert(name.to_string(), id);
            id
        }
    }
}

/// The slot an op writes, and whether that op's GEMM epilogue can emit
/// activation codes (only the GEMM ops can; Add and Gap stay f32).
fn op_write(op: &PlanOp) -> (SlotId, bool) {
    match op {
        PlanOp::Conv { out, .. } | PlanOp::Linear { out, .. } => (*out, true),
        PlanOp::Add { out, .. } | PlanOp::Gap { out, .. } => (*out, false),
    }
}

/// The slots an op reads: `Some(a_alpha)` for the quantized GEMM input
/// of a conv/linear (a read that can consume codes quantized with that
/// clip scale), `None` for an f32-only read (Add operands, Gap input).
fn op_reads(op: &PlanOp, weights: &ModelWeights) -> Vec<(SlotId, Option<f32>)> {
    match op {
        PlanOp::Conv { layer, input, .. } | PlanOp::Linear { layer, input, .. } => {
            vec![(*input, Some(weights.layers[*layer].a_alpha))]
        }
        PlanOp::Add { a, b, .. } => vec![(*a, None), (*b, None)],
        PlanOp::Gap { input, .. } => vec![(*input, None)],
    }
}

/// The readers of the write `ops[i]` makes: every read of its output
/// slot by later ops, up to and including the next op that overwrites
/// the slot (an op's reads happen before its own write, so the
/// overwriting op's reads still belong to this range). Returns
/// `(reader op index, read kind)` pairs plus whether a later op
/// overwrites the slot. Shared by the domain inference and by
/// [`Plan::validate_domains`], so the baked epilogue scales and the
/// staleness check always agree on the reader set.
fn live_range_reads(
    ops: &[PlanOp],
    i: usize,
    weights: &ModelWeights,
) -> (Vec<(usize, Option<f32>)>, bool) {
    let s = op_write(&ops[i]).0;
    let mut reads = Vec::new();
    let mut overwritten = false;
    for j in i + 1..ops.len() {
        for (rs, q) in op_reads(&ops[j], weights) {
            if rs == s {
                reads.push((j, q));
            }
        }
        if op_write(&ops[j]).0 == s {
            overwritten = true;
            break;
        }
    }
    (reads, overwritten)
}

/// Output-domain inference: decide, per op write, whether the value can
/// stay integer-resident (u8 activation codes) between layers.
///
/// A write's readers are its [`live_range_reads`]; the final write to
/// the logits slot additionally has the implicit f32 read of the
/// logits copy-out. The write is integer-resident iff the producing op
/// is a GEMM, the range has at least one reader, every reader is a
/// quantized GEMM input, and all readers agree on the clip scale — the
/// epilogue then requantizes with exactly the scale those consumers
/// would have used on an f32 buffer, which is what keeps the codes
/// bit-exact vs the dequant-store-requantize dataflow. Anything else
/// (Add operand, Gap input, logits, scale disagreement) falls back to
/// f32 for that edge only.
fn infer_domains(
    ops: &mut [PlanOp],
    slots: &mut [SlotSpec],
    weights: &ModelWeights,
    act_bits: u32,
    logits_slot: SlotId,
) {
    for i in 0..ops.len() {
        let (s, mut can_quant) = op_write(&ops[i]);
        // a grouped conv re-reads its input slot per group *after*
        // emitting earlier groups' outputs, so an in == out alias would
        // corrupt later groups on the integer path (the f32 path stages
        // through the GEMM matrix and only writes the slot at the end);
        // keep such writes f32
        if let PlanOp::Conv { groups, input, out, .. } = &ops[i] {
            if *groups > 1 && input == out {
                can_quant = false;
            }
        }
        let (reads, overwritten) = live_range_reads(ops, i, weights);
        let mut read_kinds: Vec<Option<f32>> = reads.iter().map(|&(_, q)| q).collect();
        if !overwritten && s == logits_slot {
            read_kinds.push(None);
        }
        let integer = can_quant
            && !read_kinds.is_empty()
            && read_kinds.iter().all(|k| k.is_some() && *k == read_kinds[0]);
        if integer {
            let rq = Requant::new(read_kinds[0].expect("all readers quantized"), act_bits);
            match &mut ops[i] {
                PlanOp::Conv { out_quant, .. } | PlanOp::Linear { out_quant, .. } => {
                    *out_quant = Some(rq)
                }
                _ => unreachable!("only GEMM ops can emit codes"),
            }
            for &(j, _) in &reads {
                match &mut ops[j] {
                    PlanOp::Conv { in_codes, .. } | PlanOp::Linear { in_codes, .. } => {
                        *in_codes = true
                    }
                    _ => unreachable!("integer readers are GEMM ops"),
                }
            }
            slots[s].holds_codes = true;
        } else {
            slots[s].holds_f32 = true;
        }
    }
}

/// Code-layout retargeting: after domain inference, decide per code slot
/// whether the u8 buffer can be stored **NHWC** (row-major positions ×
/// channels) instead of NCHW. NHWC is the 1×1 stride-1 pad-0 fast path:
/// a unit conv's im2col matrix *is* the NHWC buffer, so an NHWC code
/// slot is aliased directly as the consumer's GEMM activation panel —
/// no gather, no copy, and the producer pays nothing (its fused
/// epilogue scatters RowMajor instead of NCHW, the same number of
/// writes).
///
/// A slot is retargeted iff every op that writes codes into it is a
/// non-grouped implicit conv (its block epilogue can scatter either
/// layout) and every op that reads codes from it is a non-grouped
/// implicit unit conv. Any other participant — grouped conv (writes
/// row-by-row NCHW planes / gathers per channel group), k > 1 reader,
/// strided or padded reader — pins the slot to NCHW and the implicit
/// gather path.
fn infer_code_layouts(ops: &mut [PlanOp], slots: &mut [SlotSpec]) {
    let mut nhwc: Vec<bool> = slots.iter().map(|s| s.holds_codes).collect();
    for op in ops.iter() {
        match op {
            PlanOp::Conv {
                input,
                out,
                out_quant,
                in_codes,
                implicit,
                groups,
                k,
                stride,
                pad,
                ..
            } => {
                if out_quant.is_some() && !(*implicit && *groups == 1) {
                    nhwc[*out] = false;
                }
                let unit_reader =
                    *implicit && *groups == 1 && *k == 1 && *stride == 1 && *pad == 0;
                if *in_codes && !unit_reader {
                    nhwc[*input] = false;
                }
            }
            PlanOp::Linear { input, out, out_quant, in_codes, .. } => {
                // linear code buffers are already row-major and consumed
                // by the linear copy path; leave their layout alone
                if out_quant.is_some() {
                    nhwc[*out] = false;
                }
                if *in_codes {
                    nhwc[*input] = false;
                }
            }
            PlanOp::Add { .. } | PlanOp::Gap { .. } => {}
        }
    }
    for (spec, flag) in slots.iter_mut().zip(&nhwc) {
        spec.code_nhwc = *flag;
    }
    for op in ops.iter_mut() {
        if let PlanOp::Conv { input, out, out_quant, in_codes, in_nhwc, out_nhwc, .. } = op {
            if out_quant.is_some() {
                *out_nhwc = nhwc[*out];
            }
            if *in_codes {
                *in_nhwc = nhwc[*input];
            }
        }
    }
}
