//! The plan compiler: `manifest.program` → a slot-indexed [`Plan`].
//!
//! RMSMP's layer-wise-uniform row mixing makes the compute structure of a
//! model fully static: every buffer shape, im2col geometry, group slice,
//! and GEMM partition is derivable from the manifest + weights at load
//! time. Compilation is a two-stage pipeline done **once**:
//!
//! 1. [`super::ir::Ir::lower`] resolves buffer names to dense slot ids,
//!    precomputes per-op geometry, shape-checks the whole program, and
//!    chunks each layer's row partition into a GEMM task schedule — the
//!    conservative baseline plan (every edge f32, every conv explicit).
//! 2. [`super::passes`] runs the optimizer: epilogue fusion, output-
//!    domain inference, implicit-GEMM strategy selection, depthwise
//!    specialization, dead-slot elimination — each an independently
//!    toggleable rewrite with a [`PassReport`].
//!
//! [`PlanBuilder`] (the only public entry point) drives both stages and
//! seals the result, recomputing the high-water memory [`Footprint`]
//! from the *rewritten* ops, so that the executor's steady-state `infer`
//! is a plain walk over precompiled ops against preallocated
//! [`super::workspace::Workspace`] buffers, with no name resolution, no
//! shape discovery, and no buffer allocation (see the crate docs for the
//! exact per-mode zero-allocation guarantee).
//!
//! A `Plan` is immutable and shareable (`Arc<Plan>`): the serving
//! coordinator compiles one per model and hands every worker the same
//! plan next to its private workspace.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::PathBuf;

use crate::ensure;
use crate::err;
use crate::gemm::{
    autotune, Isa, LayerSig, ParallelConfig, Requant, RowPartition, TaskChunk, TuneStats,
    TunedParams, MAX_MICRO_ROWS,
};
use crate::quant::Scheme;
use crate::util::error::Result;

use super::ir::{Ir, LayerKnobs};
use super::manifest::Manifest;
use super::passes::{self, PassReport};
use super::weights::ModelWeights;

/// Dense index of a program buffer ("in0", "b3", "logits", ...).
pub type SlotId = usize;

/// Shape of a slot's contents, per batch image (T4) or batch row (M).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotKind {
    /// Feature map: (channels, height, width) per image.
    T4 { c: usize, h: usize, w: usize },
    /// Matrix: `cols` values per batch row.
    M { cols: usize },
}

impl SlotKind {
    /// Elements per batch image.
    pub fn per_image(&self) -> usize {
        match *self {
            SlotKind::T4 { c, h, w } => c * h * w,
            SlotKind::M { cols } => cols,
        }
    }
}

/// One resolved program buffer.
#[derive(Clone, Debug)]
pub struct SlotSpec {
    pub name: String,
    /// Shape of the last write (programs may reuse a name; the per-op
    /// geometry below is what the runner actually consumes).
    pub kind: SlotKind,
    /// High-water elements per batch image across every write.
    pub per_image: usize,
    /// Some write leaves this slot in the f32 domain (the workspace
    /// allocates its f32 buffer). Set by the pass pipeline's finalize
    /// step for every non-quantized write.
    pub holds_f32: bool,
    /// Some write leaves this slot integer-resident — u8 activation
    /// codes of the consuming layer's quantizer (the workspace allocates
    /// its u8 code buffer). Set by the `integer_resident` pass.
    pub holds_codes: bool,
    /// The code buffer is stored NHWC (row-major positions × channels)
    /// instead of NCHW: the layout-retarget step of the `implicit` pass
    /// proved every code writer is a non-grouped implicit conv and every
    /// code reader a 1×1 stride-1 pad-0 conv, so the readers alias the
    /// slot directly as their GEMM activation panel — no gather, no
    /// copy. A slot with no domain flags at all is **dead** (orphaned by
    /// epilogue fusion): the workspace allocates nothing for it.
    pub code_nhwc: bool,
}

/// An elementwise `Add(+ReLU)` folded into a conv's GEMM epilogue by the
/// `epilogue_fusion` pass: the epilogue computes
/// `(acc * scale + bias) + addend` per output cell (then ReLU /
/// requantize), instead of staging the conv output and running a
/// separate Add op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FusedAdd {
    /// The f32 slot added cell-wise (NCHW, same shape as the conv
    /// output). Guaranteed f32-resident: its producer sees an f32 read.
    pub addend: SlotId,
    /// Apply ReLU after the add (the fused Add op's relu flag; the conv
    /// itself never has one — fusion requires `relu: false` on the
    /// conv).
    pub relu: bool,
}

/// One compiled op: slot ids + all geometry the runner needs, resolved
/// and shape-checked at load time.
#[derive(Clone, Debug)]
pub enum PlanOp {
    Conv {
        /// Index into `ModelWeights::layers` (== `Plan::layer_parts`).
        layer: usize,
        input: SlotId,
        out: SlotId,
        relu: bool,
        /// Input feature-map dims per image.
        in_c: usize,
        in_h: usize,
        in_w: usize,
        /// Output spatial dims.
        oh: usize,
        ow: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        ch_per_group: usize,
        filt_per_group: usize,
        /// Precompiled GEMM task schedule (empty for grouped conv, which
        /// runs the per-group schedules in `group_chunks` or the
        /// row-by-row fallback).
        chunks: Vec<TaskChunk>,
        /// The input slot is integer-resident: the GEMM reads u8 codes
        /// directly, skipping the f32 unroll + requantize.
        in_codes: bool,
        /// Integer-resident output: the GEMM epilogue maps accumulators
        /// straight to the consumer layer's activation codes (fused
        /// dequant → bias → add → ReLU → requantize → scatter). `None` =
        /// f32 fallback (consumer is Add/Gap/logits or consumers
        /// disagree on scale).
        out_quant: Option<Requant>,
        /// Run as an implicit GEMM: the executor streams the input
        /// through column-tile panels instead of materializing the
        /// im2col matrix. Set by the `implicit` pass for non-grouped,
        /// non-aliased (input != out) convs.
        implicit: bool,
        /// Packed panel width (output positions per column tile), sized
        /// so one panel (`panel_positions * cols` u8 codes) stays
        /// cache-resident. 0 on the staged explicit path.
        panel_positions: usize,
        /// The input code slot is stored NHWC (see
        /// [`SlotSpec::code_nhwc`]): alias it as the activation panel.
        in_nhwc: bool,
        /// Emit output codes NHWC (RowMajor scatter) instead of NCHW —
        /// every consumer is a unit conv that will alias them.
        out_nhwc: bool,
        /// Elementwise add folded into the epilogue (see [`FusedAdd`]).
        fused_add: Option<FusedAdd>,
        /// Depthwise/grouped specialization: one GEMM task schedule per
        /// channel group over the class-sorted row layout. Non-empty iff
        /// the `depthwise` pass specialized this grouped conv; empty
        /// grouped convs take the row-by-row explicit fallback.
        group_chunks: Vec<Vec<TaskChunk>>,
        /// Per-layer tuned micro-kernel row-block height: the executor
        /// installs it on the engine before this op's dispatch
        /// ([`crate::gemm::MixedGemm::set_block_knobs`]). Never changes
        /// output bits — only the blocking schedule.
        micro_rows: usize,
        /// Per-layer tuned column-tile width (same installation path).
        tile_cols: usize,
    },
    Linear {
        layer: usize,
        input: SlotId,
        out: SlotId,
        in_cols: usize,
        out_cols: usize,
        chunks: Vec<TaskChunk>,
        /// See [`PlanOp::Conv::in_codes`].
        in_codes: bool,
        /// See [`PlanOp::Conv::out_quant`].
        out_quant: Option<Requant>,
        /// See [`PlanOp::Conv::micro_rows`].
        micro_rows: usize,
        /// See [`PlanOp::Conv::tile_cols`].
        tile_cols: usize,
    },
    Add {
        a: SlotId,
        b: SlotId,
        out: SlotId,
        relu: bool,
        /// Elements per image of each operand (shapes checked equal).
        per_image: usize,
    },
    Gap {
        input: SlotId,
        out: SlotId,
        c: usize,
        h: usize,
        w: usize,
    },
}

/// Preallocation sizes for one workspace instance, all at `capacity`
/// batch images. Single source of truth for [`super::Workspace`] and the
/// `rmsmp plan` footprint report. Computed strictly **after** the pass
/// pipeline, so slots that became codes-only or dead and staging an op
/// no longer touches contribute nothing.
#[derive(Clone, Debug)]
pub struct Footprint {
    pub capacity: usize,
    pub lanes: usize,
    /// Per-slot f32 elements (0 for slots that are only ever
    /// integer-resident, and for dead slots).
    pub slot_elems: Vec<usize>,
    /// Per-slot u8 activation-code elements (0 for f32-only slots).
    pub code_slot_elems: Vec<usize>,
    /// im2col patch-matrix f32 elements — only the ops still on the
    /// staged explicit path with an f32 input (grouped-conv fallback, or
    /// every conv when the `implicit` pass is disabled) stage through
    /// it (0 when every conv streams panels).
    pub patch_elems: usize,
    /// Quantized activation codes (u8) — staged explicit-path convs and
    /// the linear ops; streamed convs (implicit / depthwise) go through
    /// per-lane panels instead.
    pub acts_elems: usize,
    /// GEMM/Gap staging matrix f32 elements.
    pub gemm_out_elems: usize,
    /// Per-lane scratch length: one [`MAX_MICRO_ROWS`]-row micro-kernel
    /// block (an f32 output block + an i32 accumulator block of this
    /// many elements each) — sized at the widest block height any tuned
    /// layer could use, so per-layer retuning never regrows a lane.
    pub lane_elems: usize,
    /// Per-lane streamed-panel bytes (u8 activation codes for one
    /// `panel_positions`-wide column tile of the widest implicit or
    /// depthwise conv).
    pub panel_elems: usize,
    /// Logits output matrix f32 elements.
    pub logits_elems: usize,
}

impl Footprint {
    /// Bytes of one slot: its f32 buffer plus its u8 code buffer.
    pub fn slot_bytes(&self, slot: SlotId) -> usize {
        4 * self.slot_elems[slot] + self.code_slot_elems[slot]
    }

    pub fn total_slot_bytes(&self) -> usize {
        4 * self.slot_elems.iter().sum::<usize>() + self.code_slot_elems.iter().sum::<usize>()
    }

    /// Bytes of the shared scratch (patches + acts + staging + lanes +
    /// logits). Each GEMM lane holds an f32 block, an i32 block, a u8
    /// code block for the fused requantization epilogue, and a u8
    /// streamed activation panel.
    pub fn scratch_bytes(&self) -> usize {
        4 * self.patch_elems
            + self.acts_elems
            + 4 * self.gemm_out_elems
            + self.lanes * (self.lane_elems * (4 + 4 + 1) + self.panel_elems)
            + 4 * self.logits_elems
    }

    /// Total workspace bytes.
    pub fn total_bytes(&self) -> usize {
        self.total_slot_bytes() + self.scratch_bytes()
    }
}

/// A compiled, immutable execution plan (see module docs). Built by
/// [`Plan::builder`].
#[derive(Clone, Debug)]
pub struct Plan {
    pub model: String,
    /// Batch images the workspace preallocates for; larger batches still
    /// run correctly, growing the buffers once (a warm-up event).
    pub capacity: usize,
    /// GEMM rows per task chunk the schedules were compiled with.
    pub chunk_rows: usize,
    /// Effective GEMM config the plan was compiled with: the builder's
    /// config with the autotuned knobs merged in (explicit values win —
    /// see [`TunedParams::apply_to`]). Engines built from this plan
    /// adopt these knobs so execution matches the compiled schedules.
    pub cfg: ParallelConfig,
    /// The blocking parameters the load-time autotuner chose for this
    /// machine's largest layer — or the fixed defaults
    /// (`RMSMP_NO_TUNE=1`, or [`PlanBuilder::no_tune`]). The engine
    /// baseline; per-layer winners in [`Plan::layer_tuned`] override it
    /// op by op.
    pub tuned: TunedParams,
    /// Effective per-layer blocking (one entry per weights layer,
    /// `ModelWeights::layers` order): the per-layer autotuner winners
    /// merged with the builder config under the explicit-wins contract.
    /// `micro_rows`/`tile_cols` are also baked into each layer's
    /// [`PlanOp`]; `source` records tuned / disk-cache / defaults
    /// provenance per layer.
    pub layer_tuned: Vec<TunedParams>,
    /// Tuning provenance of this compile: how many layer signatures
    /// were answered from a cache vs live microbenches
    /// (`cache_misses == 0` on a warm disk cache).
    pub tune_stats: TuneStats,
    /// Whether the `integer_resident` pass ran: integer-resident edges
    /// carry u8 activation codes between GEMMs (`false` = every edge
    /// f32, the pre-fusion baseline kept for benchmarking).
    pub integer_resident: bool,
    /// Whether the `implicit` pass ran: non-grouped convs stream
    /// column-tile panels (`false` = the explicit-im2col baseline kept
    /// for benchmarking).
    pub implicit: bool,
    pub act_bits: u32,
    pub input_slot: SlotId,
    /// Expected (c, h, w) of the inference input.
    pub input_chw: (usize, usize, usize),
    pub logits_slot: SlotId,
    pub logits_cols: usize,
    pub slots: Vec<SlotSpec>,
    pub ops: Vec<PlanOp>,
    /// Row partition of every weights layer, in `ModelWeights::layers`
    /// order.
    pub layer_parts: Vec<RowPartition>,
    /// High-water per-image scratch geometry (see [`Footprint`]).
    pub max_patch_per_image: usize,
    pub max_acts_per_image: usize,
    pub max_gemm_rows_per_image: usize,
    pub max_gemm_out_per_image: usize,
    /// Widest streamed panel (u8 elements, absolute — a panel's size is
    /// batch-independent) and its position count.
    pub max_panel_elems: usize,
    pub max_panel_positions: usize,
    /// What each optimizer pass did (pipeline order, disabled passes
    /// included) — printed by `rmsmp plan`.
    pub pass_reports: Vec<PassReport>,
}

/// Compile-time dataflow toggles for the deprecated `compile_*` shims.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[deprecated(
    since = "0.6.0",
    note = "use Plan::builder(..).disable_pass(\"integer_resident\") / \
            .disable_pass(\"implicit\") instead"
)]
pub struct PlanOptions {
    /// Run output-domain inference (u8 codes between GEMMs).
    pub integer_resident: bool,
    /// Compile non-grouped convs for the implicit-GEMM panel path
    /// (`false` = explicit im2col through the workspace patch buffer).
    pub implicit: bool,
}

#[allow(deprecated)]
impl Default for PlanOptions {
    fn default() -> PlanOptions {
        PlanOptions { integer_resident: true, implicit: true }
    }
}

/// The one way to compile a [`Plan`]: lower the manifest, run the
/// optimizer pass pipeline (each pass individually toggleable), seal
/// the result.
///
/// ```ignore
/// let plan = Plan::builder(&manifest, &weights)
///     .capacity(8)
///     .config(&cfg)
///     .disable_pass("epilogue_fusion") // bench baseline
///     .build()?;
/// ```
pub struct PlanBuilder<'a> {
    manifest: &'a Manifest,
    weights: &'a ModelWeights,
    capacity: usize,
    cfg: ParallelConfig,
    disabled: Vec<String>,
    tune: bool,
    tune_cache: Option<PathBuf>,
    pin_micro_rows: Option<usize>,
}

impl<'a> PlanBuilder<'a> {
    /// Workspace batch capacity the plan's footprint is sized for
    /// (default 1).
    pub fn capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self
    }

    /// GEMM parallel config: fixes the task-chunk granularity so plan
    /// schedules match the engine's chunking (default
    /// [`ParallelConfig::sequential`]).
    pub fn config(mut self, cfg: &ParallelConfig) -> Self {
        self.cfg = *cfg;
        self
    }

    /// Skip one optimizer pass (see
    /// [`PASS_NAMES`](super::passes::PASS_NAMES)); may be called once
    /// per pass. Unknown names fail at [`PlanBuilder::build`]. The off
    /// positions keep the older dataflows compilable as benchmark
    /// baselines and differential-test twins.
    pub fn disable_pass(mut self, name: &str) -> Self {
        self.disabled.push(name.to_string());
        self
    }

    /// Skip the load-time autotuner and compile with the fixed default
    /// blocking parameters — the deterministic twin of the
    /// `RMSMP_NO_TUNE=1` environment escape hatch (reproducible tests,
    /// tuned-vs-default ablations).
    pub fn no_tune(mut self) -> Self {
        self.tune = false;
        self
    }

    /// Persist (and reuse) tuning results at `path` — the explicit twin
    /// of the `RMSMP_TUNE_CACHE=path` environment default the builder
    /// starts from. A warm cache answers every layer signature without
    /// a microbench; a corrupt or stale file silently falls back to
    /// live tuning (see [`crate::gemm::autotune`]).
    pub fn tune_cache<P: Into<PathBuf>>(mut self, path: P) -> Self {
        self.tune_cache = Some(path.into());
        self
    }

    /// Force every layer's micro-kernel row-block height to `mr`
    /// instead of sweeping the candidates — the ablation twin the
    /// runtime bench uses to isolate the 6/8-row kernels
    /// (`micro_rows_speedup` = pinned-4 time / tuned time). The other
    /// knobs still tune normally. Output bits are unchanged for any
    /// height.
    pub fn pin_micro_rows(mut self, mr: usize) -> Self {
        self.pin_micro_rows = Some(mr.clamp(1, MAX_MICRO_ROWS));
        self
    }

    /// Lower, optimize, seal (see module docs).
    pub fn build(self) -> Result<Plan> {
        for name in &self.disabled {
            ensure!(
                passes::is_pass(name),
                "unknown pass {name:?} (expected one of {:?})",
                passes::PASS_NAMES
            );
        }
        // Resolve the blocking knobs before lowering: the chunk
        // schedules, panel widths, and per-op block knobs bake them in.
        // Tuning runs per distinct layer signature, answered from the
        // process cache / on-disk cache / live microbench in that order.
        let mut tune_stats = TuneStats::default();
        let layer_raw: Vec<TunedParams> = if !self.tune || autotune::no_tune_requested() {
            let mut d = TunedParams::defaults(&self.cfg);
            if let Some(mr) = self.pin_micro_rows {
                d.micro_rows = mr;
            }
            vec![d; self.weights.layers.len()]
        } else {
            let disk = self.tune_cache.as_deref();
            self.weights
                .layers
                .iter()
                .map(|l| {
                    // the f32-accumulating APoT baseline core is only
                    // deterministic for a fixed tile, so any APoT rows
                    // pin this layer's tile_cols at the configured value
                    let pin_tile = l.scheme.iter().any(|&s| s == Scheme::ApotW4A4);
                    let part = RowPartition::from_schemes(&l.scheme);
                    let sig = LayerSig {
                        rows: l.rows,
                        cols: l.cols,
                        // batch proxy: a handful of GEMM rows per
                        // capacity image (panel positions and batch
                        // rows land in the same clamp)
                        batch: self.capacity * 16,
                        counts: [
                            part.len_of(Scheme::PotW4A4),
                            part.len_of(Scheme::FixedW4A4),
                            part.len_of(Scheme::FixedW8A4),
                            part.len_of(Scheme::ApotW4A4),
                        ],
                    };
                    autotune::tune_layer(
                        sig,
                        &self.cfg,
                        pin_tile,
                        self.pin_micro_rows,
                        disk,
                        &mut tune_stats,
                    )
                })
                .collect()
        };
        // the plan-global baseline: the largest layer's winner (what the
        // single-shape tuner used to produce); per-layer knobs override
        // it op by op at execution time
        let tuned = self
            .weights
            .layers
            .iter()
            .zip(&layer_raw)
            .max_by_key(|(l, _)| l.rows * l.cols)
            .map(|(_, p)| *p)
            .unwrap_or_else(|| TunedParams::defaults(&self.cfg));
        let cfg = tuned.apply_to(self.cfg);
        // per-layer effective knobs: each winner merged with the
        // *builder's* config (explicit caller knobs win layer-wide)
        let layer_tuned: Vec<TunedParams> = layer_raw
            .iter()
            .map(|p| {
                let e = p.apply_to(self.cfg);
                TunedParams {
                    micro_rows: e.micro_rows,
                    tile_cols: e.tile_cols,
                    min_rows_per_task: e.min_rows_per_task,
                    panel_bytes: p.panel_bytes,
                    source: p.source,
                }
            })
            .collect();
        let knobs: Vec<LayerKnobs> = layer_tuned
            .iter()
            .map(|p| LayerKnobs {
                micro_rows: p.micro_rows.clamp(1, MAX_MICRO_ROWS),
                tile_cols: p.tile_cols,
                chunk_rows: p.min_rows_per_task.max(1),
                panel_bytes: p.panel_bytes.max(1),
            })
            .collect();
        let mut ir = Ir::lower(
            self.manifest,
            self.weights,
            self.capacity,
            &cfg,
            tuned.panel_bytes,
            knobs,
        )?;
        let pass_reports = passes::run_pipeline(&mut ir, &self.disabled)?;
        let hwm = passes::high_water(&ir);
        let off = |name: &str| self.disabled.iter().any(|d| d == name);
        Ok(Plan {
            model: ir.model,
            capacity: ir.capacity,
            chunk_rows: ir.chunk_rows,
            cfg,
            tuned,
            layer_tuned,
            tune_stats,
            integer_resident: !off("integer_resident"),
            implicit: !off("implicit"),
            act_bits: ir.act_bits,
            input_slot: ir.input_slot,
            input_chw: ir.input_chw,
            logits_slot: ir.logits_slot,
            logits_cols: ir.logits_cols,
            slots: ir.slots,
            ops: ir.ops,
            layer_parts: ir.layer_parts,
            max_patch_per_image: hwm.patch,
            max_acts_per_image: hwm.acts,
            max_gemm_rows_per_image: hwm.gemm_rows,
            max_gemm_out_per_image: hwm.gemm_out,
            max_panel_elems: hwm.panel_elems,
            max_panel_positions: hwm.panel_positions,
            pass_reports,
        })
    }
}

impl Plan {
    /// Start building a plan for `manifest.program` against `weights`
    /// (see [`PlanBuilder`]).
    pub fn builder<'a>(manifest: &'a Manifest, weights: &'a ModelWeights) -> PlanBuilder<'a> {
        PlanBuilder {
            manifest,
            weights,
            capacity: 1,
            cfg: ParallelConfig::sequential(),
            disabled: Vec::new(),
            tune: true,
            tune_cache: autotune::env_cache_path(),
            pin_micro_rows: None,
        }
    }

    /// Compile with every optimizer pass enabled.
    #[deprecated(since = "0.6.0", note = "use Plan::builder(..).capacity(..).config(..).build()")]
    pub fn compile(
        manifest: &Manifest,
        weights: &ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
    ) -> Result<Plan> {
        Plan::builder(manifest, weights).capacity(capacity).config(cfg).build()
    }

    /// Compile with the integer-resident dataflow toggleable.
    #[deprecated(
        since = "0.6.0",
        note = "use Plan::builder(..).disable_pass(\"integer_resident\")"
    )]
    pub fn compile_with(
        manifest: &Manifest,
        weights: &ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
        integer_resident: bool,
    ) -> Result<Plan> {
        let mut b = Plan::builder(manifest, weights).capacity(capacity).config(cfg);
        if !integer_resident {
            b = b.disable_pass("integer_resident");
        }
        b.build()
    }

    /// Compile with the legacy boolean toggles.
    #[deprecated(
        since = "0.6.0",
        note = "use Plan::builder(..).disable_pass(..) with named passes"
    )]
    #[allow(deprecated)]
    pub fn compile_opts(
        manifest: &Manifest,
        weights: &ModelWeights,
        capacity: usize,
        cfg: &ParallelConfig,
        opts: PlanOptions,
    ) -> Result<Plan> {
        let mut b = Plan::builder(manifest, weights).capacity(capacity).config(cfg);
        if !opts.integer_resident {
            b = b.disable_pass("integer_resident");
        }
        if !opts.implicit {
            b = b.disable_pass("implicit");
        }
        b.build()
    }

    /// Check that the plan's baked integer-resident epilogue scales
    /// still match `weights`: a plan compiled against a different
    /// weights table could otherwise requantize inter-layer activations
    /// with a stale consumer clip scale (the f32 fallback reads the
    /// scale from the weights at run time and cannot go stale).
    /// `Executor::from_shared` runs this next to its partition checks.
    pub fn validate_domains(&self, weights: &ModelWeights) -> Result<()> {
        for i in 0..self.ops.len() {
            let rq = match &self.ops[i] {
                PlanOp::Conv { out_quant, .. } | PlanOp::Linear { out_quant, .. } => *out_quant,
                _ => None,
            };
            let Some(rq) = rq else { continue };
            let (s, _) = op_write(&self.ops[i]);
            // the exact reader set the scale was baked for, re-derived
            // with the same live-range scan the inference used
            let (reads, _) = live_range_reads(&self.ops, i, weights);
            for (_, q) in reads {
                let alpha = q
                    .ok_or_else(|| err!("integer-resident slot {s} read by a non-GEMM op"))?;
                ensure!(
                    rq == Requant::new(alpha, self.act_bits),
                    "plan/weights mismatch: integer-resident epilogue scale of slot \
                     {s} differs from the consumer's clip scale"
                );
            }
        }
        Ok(())
    }

    /// Preallocation sizes for a workspace with `lanes` GEMM scratch
    /// lanes (see [`crate::gemm::MixedGemm::lanes`]).
    pub fn footprint(&self, lanes: usize) -> Footprint {
        let n = self.capacity;
        Footprint {
            capacity: n,
            lanes: lanes.max(1),
            slot_elems: self
                .slots
                .iter()
                .map(|s| if s.holds_f32 { s.per_image * n } else { 0 })
                .collect(),
            code_slot_elems: self
                .slots
                .iter()
                .map(|s| if s.holds_codes { s.per_image * n } else { 0 })
                .collect(),
            patch_elems: self.max_patch_per_image * n,
            acts_elems: self.max_acts_per_image * n,
            gemm_out_elems: self.max_gemm_out_per_image * n,
            // lanes serve both the explicit blocks (micro_rows x full
            // batch) and the streamed blocks (micro_rows x panel
            // positions) — size for whichever is wider, at the widest
            // block height the engine can ever run (the dispatch scratch
            // always resizes to MAX_MICRO_ROWS x batch, whatever the
            // tuned per-layer height, so this is the zero-alloc bound)
            lane_elems: MAX_MICRO_ROWS
                * (self.max_gemm_rows_per_image * n).max(self.max_panel_positions),
            panel_elems: self.max_panel_elems,
            logits_elems: self.logits_cols * n,
        }
    }

    /// Human-readable plan dump for `rmsmp plan`: the per-pass optimizer
    /// report, ops, slot assignments, per-slot bytes, and the total
    /// workspace footprint — the numbers an FPGA BRAM budget would be
    /// sized from.
    pub fn describe(&self, weights: &ModelWeights, lanes: usize) -> String {
        let fp = self.footprint(lanes);
        let mut s = String::new();
        let _ = writeln!(
            s,
            "plan {}: {} ops, {} slots, capacity batch {}, chunk rows {}, act bits {}, \
             dataflow {}, conv {}",
            self.model,
            self.ops.len(),
            self.slots.len(),
            self.capacity,
            self.chunk_rows,
            self.act_bits,
            if self.integer_resident { "integer-resident" } else { "f32-resident" },
            if self.implicit { "implicit-gemm" } else { "explicit-im2col" }
        );
        let _ = writeln!(
            s,
            "kernels: isa {}, tile cols {}, min rows/task {}, panel budget {} B ({})",
            Isa::detect().name(),
            self.cfg.tile_cols,
            self.cfg.min_rows_per_task,
            self.tuned.panel_bytes,
            self.tuned.source.name()
        );
        let _ = writeln!(
            s,
            "layer knobs ({} cache hit{}, {} microbenched):",
            self.tune_stats.cache_hits,
            if self.tune_stats.cache_hits == 1 { "" } else { "s" },
            self.tune_stats.cache_misses
        );
        for (lw, t) in weights.layers.iter().zip(&self.layer_tuned) {
            let _ = writeln!(
                s,
                "  {:<12} mr {} tile {:<4} chunk {:<3} panel {:>6} B ({})",
                lw.name,
                t.micro_rows,
                t.tile_cols,
                t.min_rows_per_task,
                t.panel_bytes,
                t.source.name()
            );
        }
        let _ = writeln!(s, "passes:");
        for r in &self.pass_reports {
            if !r.enabled {
                let _ = writeln!(s, "  {:<17} off", r.pass);
                continue;
            }
            let _ = writeln!(
                s,
                "  {:<17} {} rewrite{}",
                r.pass,
                r.rewrites,
                if r.rewrites == 1 { "" } else { "s" }
            );
            for d in &r.details {
                let _ = writeln!(s, "      {d}");
            }
        }
        let _ = writeln!(s, "slots:");
        for (i, spec) in self.slots.iter().enumerate() {
            let kind = match spec.kind {
                SlotKind::T4 { c, h, w } => format!("T4 {c}x{h}x{w}"),
                SlotKind::M { cols } => format!("M  {cols}"),
            };
            let domain = match (spec.holds_f32, spec.holds_codes, spec.code_nhwc) {
                (true, true, false) => "f32+u8",
                (true, true, true) => "f32+u8~",
                (false, true, false) => "u8",
                // '~' marks an NHWC-retargeted code buffer (unit-conv
                // alias fast path)
                (false, true, true) => "u8~",
                (true, false, _) => "f32",
                // orphaned by epilogue fusion; allocates nothing
                (false, false, _) => "dead",
            };
            let _ = writeln!(
                s,
                "  s{i:<3} {:<12} {kind:<16} {domain:<7} {:>9} elems/img {:>12} B",
                spec.name,
                spec.per_image,
                fp.slot_bytes(i)
            );
        }
        let _ = writeln!(s, "ops:");
        for (i, op) in self.ops.iter().enumerate() {
            let line = match op {
                PlanOp::Conv {
                    layer,
                    input,
                    out,
                    relu,
                    oh,
                    ow,
                    k,
                    stride,
                    pad,
                    groups,
                    chunks,
                    in_codes,
                    out_quant,
                    implicit,
                    panel_positions,
                    in_nhwc,
                    out_nhwc,
                    fused_add,
                    group_chunks,
                    ..
                } => {
                    let lw = &weights.layers[*layer];
                    let path = match (implicit, in_nhwc) {
                        (true, true) => format!(" alias panel={panel_positions}"),
                        (true, false) => format!(" implicit panel={panel_positions}"),
                        (false, _) if !group_chunks.is_empty() => {
                            format!(" depthwise panel={panel_positions}")
                        }
                        (false, _) => String::new(),
                    };
                    let fused = match fused_add {
                        Some(fa) => format!(
                            " fuse(+s{}{})",
                            fa.addend,
                            if fa.relu { " relu" } else { "" }
                        ),
                        None => String::new(),
                    };
                    format!(
                        "conv   {:<12} s{input}{} -> s{out}{}  {}x{} k{k} s{stride} p{pad} \
                         g{groups} oh={oh} ow={ow} chunks={}{}{fused}{path}",
                        lw.name,
                        if *in_codes { "[u8]" } else { "" },
                        match (out_quant.is_some(), *out_nhwc) {
                            (true, true) => "[u8~]",
                            (true, false) => "[u8]",
                            _ => "",
                        },
                        lw.rows,
                        lw.cols,
                        chunks.len(),
                        if *relu { " relu" } else { "" }
                    )
                }
                PlanOp::Linear {
                    layer, input, out, in_cols, out_cols, chunks, in_codes, out_quant, ..
                } => {
                    let lw = &weights.layers[*layer];
                    format!(
                        "linear {:<12} s{input}{} -> s{out}{}  {out_cols}x{in_cols} chunks={}",
                        lw.name,
                        if *in_codes { "[u8]" } else { "" },
                        if out_quant.is_some() { "[u8]" } else { "" },
                        chunks.len()
                    )
                }
                PlanOp::Add { a, b, out, relu, per_image } => format!(
                    "add    {:<12} s{a} + s{b} -> s{out}  {per_image} elems/img{}",
                    "",
                    if *relu { " relu" } else { "" }
                ),
                PlanOp::Gap { input, out, c, h, w } => {
                    format!("gap    {:<12} s{input} -> s{out}  {c}x{h}x{w} -> {c}", "")
                }
            };
            let _ = writeln!(s, "  {i:<3} {line}");
        }
        let _ = writeln!(
            s,
            "workspace (lanes={}): slots {} B + patches {} B + acts {} B + staging {} B + \
             lane scratch {} B + panels {} B + logits {} B = {} B total",
            fp.lanes,
            fp.total_slot_bytes(),
            4 * fp.patch_elems,
            fp.acts_elems,
            4 * fp.gemm_out_elems,
            fp.lanes * fp.lane_elems * 9,
            fp.lanes * fp.panel_elems,
            4 * fp.logits_elems,
            fp.total_bytes()
        );
        s
    }
}

/// Record a write of `kind` to slot `name`, creating the slot on first
/// use and widening its high-water footprint.
pub(crate) fn define(
    slots: &mut Vec<SlotSpec>,
    index: &mut HashMap<String, SlotId>,
    name: &str,
    kind: SlotKind,
) -> SlotId {
    match index.get(name) {
        Some(&id) => {
            slots[id].kind = kind;
            slots[id].per_image = slots[id].per_image.max(kind.per_image());
            id
        }
        None => {
            let id = slots.len();
            slots.push(SlotSpec {
                name: name.to_string(),
                kind,
                per_image: kind.per_image(),
                // domains and code layouts are assigned by the pass
                // pipeline once every write and read is known
                holds_f32: false,
                holds_codes: false,
                code_nhwc: false,
            });
            index.insert(name.to_string(), id);
            id
        }
    }
}

/// The slot an op writes, and whether that op's GEMM epilogue can emit
/// activation codes (only the GEMM ops can; Add and Gap stay f32).
pub(crate) fn op_write(op: &PlanOp) -> (SlotId, bool) {
    match op {
        PlanOp::Conv { out, .. } | PlanOp::Linear { out, .. } => (*out, true),
        PlanOp::Add { out, .. } | PlanOp::Gap { out, .. } => (*out, false),
    }
}

/// The slots an op reads: `Some(a_alpha)` for the quantized GEMM input
/// of a conv/linear (a read that can consume codes quantized with that
/// clip scale), `None` for an f32-only read (Add operands, Gap input,
/// a fused-add addend — the epilogue adds it as floats).
pub(crate) fn op_reads(op: &PlanOp, weights: &ModelWeights) -> Vec<(SlotId, Option<f32>)> {
    match op {
        PlanOp::Conv { layer, input, fused_add, .. } => {
            let mut r = vec![(*input, Some(weights.layers[*layer].a_alpha))];
            if let Some(fa) = fused_add {
                r.push((fa.addend, None));
            }
            r
        }
        PlanOp::Linear { layer, input, .. } => {
            vec![(*input, Some(weights.layers[*layer].a_alpha))]
        }
        PlanOp::Add { a, b, .. } => vec![(*a, None), (*b, None)],
        PlanOp::Gap { input, .. } => vec![(*input, None)],
    }
}

/// The readers of the write `ops[i]` makes: every read of its output
/// slot by later ops, up to and including the next op that overwrites
/// the slot (an op's reads happen before its own write, so the
/// overwriting op's reads still belong to this range). Returns
/// `(reader op index, read kind)` pairs plus whether a later op
/// overwrites the slot. Shared by the pass pipeline and by
/// [`Plan::validate_domains`], so the baked epilogue scales and the
/// staleness check always agree on the reader set.
pub(crate) fn live_range_reads(
    ops: &[PlanOp],
    i: usize,
    weights: &ModelWeights,
) -> (Vec<(usize, Option<f32>)>, bool) {
    let s = op_write(&ops[i]).0;
    let mut reads = Vec::new();
    let mut overwritten = false;
    for j in i + 1..ops.len() {
        for (rs, q) in op_reads(&ops[j], weights) {
            if rs == s {
                reads.push((j, q));
            }
        }
        if op_write(&ops[j]).0 == s {
            overwritten = true;
            break;
        }
    }
    (reads, overwritten)
}
