//! AOT manifest loader (`artifacts/manifest.json`).

use std::path::Path;

use crate::bail;
use crate::err;
use crate::fpga::LayerShape;
use crate::quant::Ratio;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// One layer's static description.
#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    pub kind: String, // "conv" | "linear"
    pub rows: usize,
    pub cols: usize,
    pub stride: usize,
    pub pad: usize,
    pub groups: usize,
    pub a_alpha: f32,
    /// Counts per scheme code [pot4, fixed4, fixed8, apot4].
    pub scheme_counts: [usize; 4],
}

/// One op of the graph program.
#[derive(Clone, Debug)]
pub enum OpMeta {
    Conv { layer: String, input: String, out: String, relu: bool },
    Linear { layer: String, input: String, out: String },
    Add { a: String, b: String, out: String, relu: bool },
    Gap { input: String, out: String },
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub arch: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub ratio: Ratio,
    pub act_bits: u32,
    pub layers: Vec<LayerMeta>,
    pub program: Vec<OpMeta>,
    pub gemm_shape: Option<(usize, usize, usize)>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let j = Json::load(path)?;
        Manifest::from_json(&j).with_context(|| format!("manifest {}", path.display()))
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let ratio_v = j.get("ratio")?.as_usize_vec()?;
        if ratio_v.len() != 3 {
            bail!("ratio must have 3 entries");
        }
        let mut layers = Vec::new();
        for l in j.get("layers")?.as_arr()? {
            let sc = l.get("scheme_counts")?.as_usize_vec()?;
            layers.push(LayerMeta {
                name: l.get("name")?.as_str()?.to_string(),
                kind: l.get("kind")?.as_str()?.to_string(),
                rows: l.get("rows")?.as_usize()?,
                cols: l.get("cols")?.as_usize()?,
                stride: l.get("stride")?.as_usize()?,
                pad: l.get("pad")?.as_usize()?,
                groups: l.get("groups")?.as_usize()?,
                a_alpha: l.get("a_alpha")?.as_f64()? as f32,
                scheme_counts: [
                    sc.first().copied().unwrap_or(0),
                    sc.get(1).copied().unwrap_or(0),
                    sc.get(2).copied().unwrap_or(0),
                    sc.get(3).copied().unwrap_or(0),
                ],
            });
        }
        let mut program = Vec::new();
        for op in j.get("program")?.as_arr()? {
            let kind = op.get("op")?.as_str()?;
            let relu = op
                .opt("relu")
                .map(|v| v.as_bool().unwrap_or(false))
                .unwrap_or(false);
            program.push(match kind {
                "conv" => OpMeta::Conv {
                    layer: op.get("layer")?.as_str()?.to_string(),
                    input: op.get("in")?.as_str()?.to_string(),
                    out: op.get("out")?.as_str()?.to_string(),
                    relu,
                },
                "linear" => OpMeta::Linear {
                    layer: op.get("layer")?.as_str()?.to_string(),
                    input: op.get("in")?.as_str()?.to_string(),
                    out: op.get("out")?.as_str()?.to_string(),
                },
                "add" => OpMeta::Add {
                    a: op.get("a")?.as_str()?.to_string(),
                    b: op.get("b")?.as_str()?.to_string(),
                    out: op.get("out")?.as_str()?.to_string(),
                    relu,
                },
                "gap" => OpMeta::Gap {
                    input: op.get("in")?.as_str()?.to_string(),
                    out: op.get("out")?.as_str()?.to_string(),
                },
                other => bail!("unknown op {other:?}"),
            });
        }
        let gemm_shape = match j.opt("gemm_shape") {
            Some(v) => {
                let g = v.as_usize_vec()?;
                Some((g[0], g[1], g[2]))
            }
            None => None,
        };
        Ok(Manifest {
            model: j.get("model")?.as_str()?.to_string(),
            arch: j.get("arch")?.as_str()?.to_string(),
            num_classes: j.get("num_classes")?.as_usize()?,
            input_shape: j.get("input_shape")?.as_usize_vec()?,
            ratio: Ratio::new(ratio_v[0] as u32, ratio_v[1] as u32, ratio_v[2] as u32),
            act_bits: j.get("act_bits")?.as_usize()? as u32,
            layers,
            program,
            gemm_shape,
        })
    }

    pub fn layer(&self, name: &str) -> Result<&LayerMeta> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| err!("layer {name:?} not in manifest"))
    }

    /// Layer shapes for the FPGA simulator, with output spatial positions
    /// derived by walking the program over the input resolution.
    pub fn layer_shapes(&self) -> Vec<LayerShape> {
        let mut hw = *self.input_shape.get(2).unwrap_or(&32);
        let mut shapes = Vec::new();
        for op in &self.program {
            if let OpMeta::Conv { layer, .. } = op {
                let l = self.layer(layer).expect("program references manifest layer");
                // SAME padding: out = ceil(in / stride). 'down' convs run in
                // parallel to the main path at the same stride, so only the
                // main chain advances the tracked resolution.
                if !layer.ends_with(".down") {
                    hw = hw.div_ceil(l.stride.max(1));
                }
                shapes.push(LayerShape {
                    name: layer.clone(),
                    rows: l.rows,
                    cols: l.cols,
                    positions: hw * hw,
                });
            } else if let OpMeta::Linear { layer, .. } = op {
                let l = self.layer(layer).expect("manifest layer");
                shapes.push(LayerShape {
                    name: layer.clone(),
                    rows: l.rows,
                    cols: l.cols,
                    positions: 1,
                });
            }
        }
        shapes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
          "model": "resnet18", "arch": "resnet", "num_classes": 10,
          "input_shape": [8, 3, 32, 32], "ratio": [65, 30, 5], "act_bits": 4,
          "layers": [
            {"name": "stem", "kind": "conv", "rows": 16, "cols": 27,
             "stride": 1, "pad": 1, "groups": 1, "a_alpha": 1.0,
             "scheme_counts": [10, 5, 1, 0]},
            {"name": "fc", "kind": "linear", "rows": 10, "cols": 64,
             "stride": 0, "pad": 0, "groups": 1, "a_alpha": 2.0,
             "scheme_counts": [7, 3, 0, 0]}
          ],
          "program": [
            {"op": "conv", "layer": "stem", "in": "in0", "out": "b0", "relu": true},
            {"op": "gap", "in": "b0", "out": "b1"},
            {"op": "linear", "layer": "fc", "in": "b1", "out": "logits"}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.model, "resnet18");
        assert_eq!(m.ratio, Ratio::RMSMP2);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layer("stem").unwrap().rows, 16);
        assert!(m.layer("nope").is_err());
        assert_eq!(m.program.len(), 3);
    }

    #[test]
    fn layer_shapes_track_spatial() {
        let m = Manifest::from_json(&sample()).unwrap();
        let shapes = m.layer_shapes();
        assert_eq!(shapes.len(), 2);
        assert_eq!(shapes[0].positions, 32 * 32);
        assert_eq!(shapes[1].positions, 1);
    }
}
