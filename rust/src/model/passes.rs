//! The plan optimizer: graph-rewrite passes over the lowered IR.
//!
//! [`super::plan::PlanBuilder`] lowers the manifest to the conservative
//! baseline IR (every edge f32, every conv staged through explicit
//! im2col) and then runs [`run_pipeline`]: a fixed sequence of pure
//! rewrites, each `fn(&mut Ir) -> Result<PassReport>`. Every pass is
//! individually optional (`PlanBuilder::disable_pass`) and must preserve
//! bit-exactness against `reference_infer` — a pass may change *where*
//! an arithmetic step happens (inside a fused GEMM epilogue, on a
//! streamed panel, per channel group), never *what* is computed. The
//! pipeline order is load-bearing:
//!
//! 1. [`epilogue_fusion`] — folds `Add(+ReLU)` ops into the producing
//!    conv's epilogue, so later passes see the fused graph (the fused
//!    output can then go integer-resident, which is the whole point of
//!    fusing before domain inference).
//! 2. [`integer_resident`] — output-domain inference (PR 4): decides per
//!    GEMM write whether the value stays u8 activation codes.
//! 3. [`implicit`] — conv-strategy selection (PR 5): non-grouped convs
//!    stream column-tile panels instead of materializing im2col, plus
//!    the NHWC code-layout retarget for unit-conv chains.
//! 4. [`depthwise`] — grouped-conv specialization: per-group panel-GEMM
//!    schedules replacing the row-by-row explicit fallback.
//! 5. [`dead_slot_elim`] — slots orphaned by fusion stop being
//!    allocated, so the footprint reports the true post-optimization
//!    memory.
//!
//! After the pipeline, [`finalize`] marks the f32 domain of every
//! non-quantized write (the inverse of what `integer_resident` claimed)
//! and [`high_water`] recomputes the scratch footprint strictly from the
//! rewritten ops — the pre-pass IR never leaks sizing.

use crate::gemm::{Requant, RowPartition, TaskChunk};
use crate::quant::Scheme;
use crate::util::error::Result;

use super::ir::Ir;
use super::plan::{live_range_reads, op_reads, op_write, FusedAdd, PlanOp};

// Panel sizing note: one streamed activation panel (implicit GEMM and
// the depthwise per-group kernel) targets the layer's panel budget
// (`Ir::layer_knobs[layer].panel_bytes`, falling back to the global
// `Ir::panel_bytes`) of u8 codes — positions land around half an L1d
// next to the weight tiles, clamped to keep at least a micro-kernel
// block's worth of positions and at most a reasonable tile. The budget
// defaults to `crate::gemm::autotune::DEFAULT_PANEL_BYTES` and may be
// overridden per machine and per layer by the plan builder's load-time
// autotuner.

/// What one pass did to the IR: how many ops/slots it rewrote, plus a
/// human-readable line per rewrite (printed by `rmsmp plan` and pinned
/// by the pass-report golden test).
#[derive(Clone, Debug, PartialEq)]
pub struct PassReport {
    /// Pass name, one of [`PASS_NAMES`].
    pub pass: &'static str,
    /// `false` when the pass was skipped via `disable_pass`.
    pub enabled: bool,
    /// Number of rewrites applied (0 = the pass matched nothing).
    pub rewrites: usize,
    /// One line per rewrite, in op order.
    pub details: Vec<String>,
}

impl PassReport {
    fn new(pass: &'static str) -> PassReport {
        PassReport { pass, enabled: true, rewrites: 0, details: Vec::new() }
    }
}

type Pass = fn(&mut Ir) -> Result<PassReport>;

/// The fixed pipeline, in execution order (see module docs for why the
/// order matters). `PlanBuilder::disable_pass` names entries of
/// [`PASS_NAMES`].
const PIPELINE: [(&str, Pass); 5] = [
    ("epilogue_fusion", epilogue_fusion),
    ("integer_resident", integer_resident),
    ("implicit", implicit),
    ("depthwise", depthwise),
    ("dead_slot_elim", dead_slot_elim),
];

/// Names accepted by `PlanBuilder::disable_pass`, in pipeline order.
pub const PASS_NAMES: [&str; 5] = [
    "epilogue_fusion",
    "integer_resident",
    "implicit",
    "depthwise",
    "dead_slot_elim",
];

/// True iff `name` is a pass the pipeline knows.
pub(crate) fn is_pass(name: &str) -> bool {
    PASS_NAMES.contains(&name)
}

/// Run every enabled pass in pipeline order, then [`finalize`] the slot
/// domains. Disabled passes still get a (disabled) report entry so the
/// per-pass output always lists the full pipeline.
pub(crate) fn run_pipeline(ir: &mut Ir, disabled: &[String]) -> Result<Vec<PassReport>> {
    let mut reports = Vec::with_capacity(PIPELINE.len());
    for (name, pass) in PIPELINE {
        if disabled.iter().any(|d| d == name) {
            reports.push(PassReport {
                pass: name,
                enabled: false,
                rewrites: 0,
                details: Vec::new(),
            });
        } else {
            reports.push(pass(ir)?);
        }
    }
    finalize(ir);
    Ok(reports)
}

/// Epilogue fusion: fold an elementwise `Add(+ReLU)` into the GEMM
/// epilogue of the conv immediately producing one of its operands.
///
/// `conv(x) -> t; add t + r -> y` becomes `conv(x) [+r] -> y` with
/// [`FusedAdd`] carried on the conv: the epilogue computes
/// `(acc*scale + bias) + r` per cell instead of staging `t`. Guards, in
/// order:
/// * the operand's producer is the conv **directly before** the add
///   (adjacency also guarantees the addend's value cannot change
///   between the conv and the add);
/// * the conv is non-grouped and has no ReLU of its own (a conv-level
///   ReLU would clamp before the add — not the program's semantics);
/// * the add is the **sole** reader of the conv's output (checked with
///   the same [`live_range_reads`] scan domain inference uses), so
///   dropping the intermediate slot is observationally invisible;
/// * no aliasing that would make the fused op read a cell it already
///   wrote: the addend is not the add's output, the conv's input is not
///   the add's output, and the two add operands are distinct.
///
/// f32 addition is commutative bit-for-bit, so the epilogue order
/// `(conv + bias) + addend` matches the interpreter's `addend + conv`
/// exactly; a fused ReLU is `max(0, .)` on the sum either way, and on
/// the integer-resident path the unsigned activation quantizer's clamp
/// at 0 subsumes it.
fn epilogue_fusion(ir: &mut Ir) -> Result<PassReport> {
    let mut rep = PassReport::new("epilogue_fusion");
    let mut i = 1;
    while i < ir.ops.len() {
        let (a, b, add_out, add_relu) = match ir.ops[i] {
            PlanOp::Add { a, b, out, relu, .. } => (a, b, out, relu),
            _ => {
                i += 1;
                continue;
            }
        };
        // try the conv directly before the add as producer of either
        // operand (b first: `x + conv(x)` residuals name the conv second)
        let fused = [(b, a), (a, b)].into_iter().any(|(operand, addend)| {
            try_fuse_add(ir, i, operand, addend, add_out, add_relu)
        });
        if fused {
            let layer = match &ir.ops[i - 1] {
                PlanOp::Conv { layer, .. } => *layer,
                _ => unreachable!("fusion target is a conv"),
            };
            rep.rewrites += 1;
            rep.details.push(format!(
                "fold add{} -> conv {} epilogue (out s{add_out})",
                if add_relu { "+relu" } else { "" },
                ir.weights.layers[layer].name,
            ));
            ir.ops.remove(i);
        } else {
            i += 1;
        }
    }
    Ok(rep)
}

/// Try to fold the add at `ops[add_idx]` into the conv at `add_idx - 1`
/// producing `operand` (see [`epilogue_fusion`] for the guard set).
/// Returns whether the conv was rewritten; the caller removes the add.
fn try_fuse_add(
    ir: &mut Ir,
    add_idx: usize,
    operand: usize,
    addend: usize,
    add_out: usize,
    add_relu: bool,
) -> bool {
    let ci = add_idx - 1;
    match &ir.ops[ci] {
        PlanOp::Conv { out, input, groups, relu, fused_add, .. } => {
            // one fused addend per conv: a second fold would clobber the
            // first (chained adds keep their standalone op)
            if fused_add.is_some() {
                return false;
            }
            if *out != operand || *groups != 1 || *relu {
                return false;
            }
            if addend == operand || addend == add_out || *input == add_out {
                return false;
            }
        }
        _ => return false,
    }
    // sole-reader check: the conv output's live range must contain
    // exactly the add (as an f32 read)
    let (reads, _) = live_range_reads(&ir.ops, ci, ir.weights);
    if !(reads.len() == 1 && reads[0].0 == add_idx && reads[0].1.is_none()) {
        return false;
    }
    match &mut ir.ops[ci] {
        PlanOp::Conv { out, fused_add, .. } => {
            *out = add_out;
            *fused_add = Some(FusedAdd { addend, relu: add_relu });
        }
        _ => unreachable!(),
    }
    true
}

/// Output-domain inference (PR 4's dataflow fusion, as a pass): decide,
/// per op write, whether the value can stay integer-resident (u8
/// activation codes) between layers.
///
/// A write's readers are its [`live_range_reads`]; the final write to
/// the logits slot additionally has the implicit f32 read of the logits
/// copy-out. The write is integer-resident iff the producing op is a
/// GEMM, the range has at least one reader, every reader is a quantized
/// GEMM input, and all readers agree on the clip scale — the epilogue
/// then requantizes with exactly the scale those consumers would have
/// used on an f32 buffer, which is what keeps the codes bit-exact vs
/// the dequant-store-requantize dataflow. Anything else (Add operand,
/// fused-add addend, Gap input, logits, scale disagreement) falls back
/// to f32 for that edge only; [`finalize`] records those f32 domains.
fn integer_resident(ir: &mut Ir) -> Result<PassReport> {
    let mut rep = PassReport::new("integer_resident");
    for i in 0..ir.ops.len() {
        let (s, mut can_quant) = op_write(&ir.ops[i]);
        // a grouped conv re-reads its input slot per group *after*
        // emitting earlier groups' outputs, so an in == out alias would
        // corrupt later groups on the integer path (the f32 path stages
        // through the GEMM matrix and only writes the slot at the end);
        // keep such writes f32
        if let PlanOp::Conv { groups, input, out, .. } = &ir.ops[i] {
            if *groups > 1 && input == out {
                can_quant = false;
            }
        }
        let (reads, overwritten) = live_range_reads(&ir.ops, i, ir.weights);
        let mut read_kinds: Vec<Option<f32>> = reads.iter().map(|&(_, q)| q).collect();
        if !overwritten && s == ir.logits_slot {
            read_kinds.push(None);
        }
        let integer = can_quant
            && !read_kinds.is_empty()
            && read_kinds.iter().all(|k| k.is_some() && *k == read_kinds[0]);
        if integer {
            let rq =
                Requant::new(read_kinds[0].expect("all readers quantized"), ir.act_bits);
            match &mut ir.ops[i] {
                PlanOp::Conv { out_quant, .. } | PlanOp::Linear { out_quant, .. } => {
                    *out_quant = Some(rq)
                }
                _ => unreachable!("only GEMM ops can emit codes"),
            }
            for &(j, _) in &reads {
                match &mut ir.ops[j] {
                    PlanOp::Conv { in_codes, .. } | PlanOp::Linear { in_codes, .. } => {
                        *in_codes = true
                    }
                    _ => unreachable!("integer readers are GEMM ops"),
                }
            }
            ir.slots[s].holds_codes = true;
            rep.rewrites += 1;
            rep.details.push(format!(
                "slot s{s} {} integer-resident ({} reader{})",
                ir.slots[s].name,
                reads.len(),
                if reads.len() == 1 { "" } else { "s" },
            ));
        }
    }
    Ok(rep)
}

/// Conv-strategy selection (PR 5's implicit GEMM, as a pass): every
/// non-grouped conv whose input and output slots differ streams
/// column-tile panels instead of materializing the im2col matrix (an
/// in-place conv cannot stream: the GEMM would read the input while
/// writing the output). Panels are sized to the IR's panel budget
/// (`Ir::panel_bytes` — autotuned or the fixed default).
///
/// The pass then retargets code-slot layouts: a code slot written only
/// by non-grouped implicit convs and read only by implicit **unit**
/// convs (1×1 stride-1 pad-0) is stored NHWC, so readers alias it
/// directly as their GEMM activation panel — no gather, no copy. A conv
/// with a fused addend pins its output NCHW: the addend is an f32
/// feature map indexed in NCHW, and the fused epilogue indexes both
/// through one layout.
fn implicit(ir: &mut Ir) -> Result<PassReport> {
    let mut rep = PassReport::new("implicit");
    for op in ir.ops.iter_mut() {
        if let PlanOp::Conv {
            layer, input, out, groups, implicit, panel_positions, oh, ow, ..
        } = op
        {
            if *groups == 1 && input != out {
                *implicit = true;
                *panel_positions = panel_width(
                    ir.layer_knobs[*layer].panel_bytes,
                    ir.weights.layers[*layer].cols,
                    *oh * *ow,
                    ir.capacity,
                );
                rep.rewrites += 1;
                rep.details.push(format!(
                    "conv {} implicit (panel {} positions)",
                    ir.weights.layers[*layer].name, *panel_positions,
                ));
            }
        }
    }
    retarget_code_layouts(ir, &mut rep);
    Ok(rep)
}

/// Panel width for one streamed conv: cache-sized, but never wider than
/// the op's whole batch at plan capacity — a panel bigger than the
/// operand is pure waste. `panel_bytes` is the machine-tuned (or
/// default) panel budget the IR carries.
fn panel_width(panel_bytes: usize, cols: usize, hw: usize, capacity: usize) -> usize {
    (panel_bytes / cols.max(1))
        .clamp(8, 256)
        .min((hw * capacity).max(1))
}

/// The NHWC retarget half of [`implicit`] (see its docs). Runs on
/// whatever code slots domain inference produced — none when
/// `integer_resident` was disabled, making this a no-op.
fn retarget_code_layouts(ir: &mut Ir, rep: &mut PassReport) {
    let mut nhwc: Vec<bool> = ir.slots.iter().map(|s| s.holds_codes).collect();
    for op in ir.ops.iter() {
        match op {
            PlanOp::Conv {
                input,
                out,
                out_quant,
                in_codes,
                implicit,
                groups,
                k,
                stride,
                pad,
                fused_add,
                ..
            } => {
                if out_quant.is_some() && !(*implicit && *groups == 1 && fused_add.is_none())
                {
                    nhwc[*out] = false;
                }
                let unit_reader =
                    *implicit && *groups == 1 && *k == 1 && *stride == 1 && *pad == 0;
                if *in_codes && !unit_reader {
                    nhwc[*input] = false;
                }
            }
            PlanOp::Linear { input, out, out_quant, in_codes, .. } => {
                // linear code buffers are already row-major and consumed
                // by the linear copy path; leave their layout alone
                if out_quant.is_some() {
                    nhwc[*out] = false;
                }
                if *in_codes {
                    nhwc[*input] = false;
                }
            }
            PlanOp::Add { .. } | PlanOp::Gap { .. } => {}
        }
    }
    for (i, (spec, flag)) in ir.slots.iter_mut().zip(&nhwc).enumerate() {
        spec.code_nhwc = *flag;
        if *flag {
            rep.details.push(format!("slot s{i} {} codes stored nhwc", spec.name));
        }
    }
    for op in ir.ops.iter_mut() {
        if let PlanOp::Conv { input, out, out_quant, in_codes, in_nhwc, out_nhwc, .. } = op {
            if out_quant.is_some() {
                *out_nhwc = nhwc[*out];
            }
            if *in_codes {
                *in_nhwc = nhwc[*input];
            }
        }
    }
}

/// Depthwise/grouped-conv specialization: replace the row-by-row
/// explicit-im2col fallback with per-group streamed panel GEMMs.
///
/// The class-sorted weight layout sorts **stably**, so the rows of one
/// channel group stay contiguous inside each scheme-class block; a
/// group's GEMM schedule is then just one row range per class, chunked
/// and interleaved exactly like [`crate::gemm::chunk_tasks`] does for a
/// whole layer. The executor dispatches the groups sequentially — each
/// against a column-tile panel source restricted to the group's input
/// channels — with the partial-schedule prefill disabled, because the
/// union of the per-group schedules covers every output row exactly
/// once.
fn depthwise(ir: &mut Ir) -> Result<PassReport> {
    let mut rep = PassReport::new("depthwise");
    for op in ir.ops.iter_mut() {
        if let PlanOp::Conv {
            layer, groups, filt_per_group, group_chunks, panel_positions, oh, ow, ..
        } = op
        {
            if *groups > 1 {
                let lw = &ir.weights.layers[*layer];
                *group_chunks = group_task_chunks(
                    &lw.scheme,
                    &ir.layer_parts[*layer],
                    *groups,
                    *filt_per_group,
                    ir.layer_knobs[*layer].chunk_rows,
                );
                *panel_positions = panel_width(
                    ir.layer_knobs[*layer].panel_bytes,
                    lw.cols,
                    *oh * *ow,
                    ir.capacity,
                );
                rep.rewrites += 1;
                rep.details.push(format!(
                    "conv {} depthwise ({} groups, panel {} positions)",
                    lw.name, *groups, *panel_positions,
                ));
            }
        }
    }
    Ok(rep)
}

/// Per-group GEMM task schedules over the class-sorted row layout (see
/// [`depthwise`]): group `g`'s rows of class `c` occupy the sorted range
/// `class_start(c) + |{r < g*fpg : scheme(r) = c}| ..` of length "class-c
/// rows inside the group" — prefix counts over the model-order scheme
/// vector, because the stable sort preserves model order within a class.
fn group_task_chunks(
    scheme: &[Scheme],
    part: &RowPartition,
    groups: usize,
    filt_per_group: usize,
    chunk_rows: usize,
) -> Vec<Vec<TaskChunk>> {
    let chunk = chunk_rows.max(1);
    let mut out = Vec::with_capacity(groups);
    // class-row counts below the current group boundary
    let mut below = [0usize; 4];
    for g in 0..groups {
        let mut upto = below;
        for r in g * filt_per_group..(g + 1) * filt_per_group {
            upto[scheme[r] as usize] += 1;
        }
        // round-robin across the group's per-class sorted ranges in
        // chunk-sized tasks, mirroring `chunk_tasks` for a whole layer
        let mut offset = [0usize; 4];
        let mut end = [0usize; 4];
        for (k, &s) in RowPartition::CLASS_ORDER.iter().enumerate() {
            let base = part.range(s).start;
            offset[k] = base + below[k];
            end[k] = base + upto[k];
        }
        let mut tasks = Vec::new();
        loop {
            let mut pushed = false;
            for (k, &s) in RowPartition::CLASS_ORDER.iter().enumerate() {
                let o = offset[k];
                if o < end[k] {
                    let e = end[k].min(o + chunk);
                    tasks.push(TaskChunk { scheme: s, start: o, end: e });
                    offset[k] = e;
                    pushed = true;
                }
            }
            if !pushed {
                break;
            }
        }
        out.push(tasks);
        below = upto;
    }
    out
}

/// Dead-slot elimination: a slot neither read nor written by any op
/// (epilogue fusion orphans the intermediate between a conv and its
/// folded add) is marked dead — no domain flags, so the footprint
/// allocates zero bytes for it. The program input and logits slots are
/// always live.
fn dead_slot_elim(ir: &mut Ir) -> Result<PassReport> {
    let mut rep = PassReport::new("dead_slot_elim");
    let mut live = vec![false; ir.slots.len()];
    live[ir.input_slot] = true;
    live[ir.logits_slot] = true;
    for op in &ir.ops {
        live[op_write(op).0] = true;
        for (s, _) in op_reads(op, ir.weights) {
            live[s] = true;
        }
    }
    for (s, spec) in ir.slots.iter_mut().enumerate() {
        if !live[s] {
            spec.holds_f32 = false;
            spec.holds_codes = false;
            spec.code_nhwc = false;
            rep.rewrites += 1;
            rep.details.push(format!("slot s{s} {} dead", spec.name));
        }
    }
    Ok(rep)
}

/// Mandatory post-pipeline step (not a pass — correctness, not
/// optimization): every op write whose epilogue does **not** emit codes
/// leaves its slot in the f32 domain, so the workspace allocates the f32
/// buffer. With `integer_resident` disabled this marks every write;
/// with it enabled, exactly the writes inference left unquantized.
pub(crate) fn finalize(ir: &mut Ir) {
    for op in &ir.ops {
        let quant = matches!(
            op,
            PlanOp::Conv { out_quant: Some(_), .. } | PlanOp::Linear { out_quant: Some(_), .. }
        );
        if !quant {
            ir.slots[op_write(op).0].holds_f32 = true;
        }
    }
}

/// Post-pipeline scratch high-water marks, per batch image (see
/// [`super::plan::Footprint`]). Computed strictly from the rewritten
/// ops: an op fused away, streamed, or specialized contributes nothing
/// to the staging buffers it no longer touches.
pub(crate) struct HighWater {
    pub(crate) patch: usize,
    pub(crate) acts: usize,
    pub(crate) gemm_rows: usize,
    pub(crate) gemm_out: usize,
    pub(crate) panel_elems: usize,
    pub(crate) panel_positions: usize,
}

pub(crate) fn high_water(ir: &Ir) -> HighWater {
    let mut hwm = HighWater {
        patch: 0,
        acts: 0,
        gemm_rows: 0,
        gemm_out: 0,
        panel_elems: 0,
        panel_positions: 0,
    };
    for op in &ir.ops {
        match op {
            PlanOp::Conv {
                layer,
                oh,
                ow,
                implicit,
                panel_positions,
                group_chunks,
                in_codes,
                out_quant,
                ..
            } => {
                let lw = &ir.weights.layers[*layer];
                let hw = oh * ow;
                if *implicit || !group_chunks.is_empty() {
                    // streamed paths (implicit / depthwise): per-lane
                    // panels, no patch/acts staging
                    hwm.panel_elems = hwm.panel_elems.max(panel_positions * lw.cols);
                    hwm.panel_positions = hwm.panel_positions.max(*panel_positions);
                } else {
                    // staged paths (explicit im2col, grouped row-by-row
                    // fallback): integer-resident inputs skip the f32
                    // patch matrix and go straight to codes
                    if !*in_codes {
                        hwm.patch = hwm.patch.max(hw * lw.cols);
                    }
                    hwm.acts = hwm.acts.max(hw * lw.cols);
                    hwm.gemm_rows = hwm.gemm_rows.max(hw);
                }
                if out_quant.is_none() {
                    hwm.gemm_out = hwm.gemm_out.max(hw * lw.out_ch);
                }
            }
            PlanOp::Linear { layer, out_quant, .. } => {
                let lw = &ir.weights.layers[*layer];
                hwm.acts = hwm.acts.max(lw.cols);
                hwm.gemm_rows = hwm.gemm_rows.max(1);
                if out_quant.is_none() {
                    hwm.gemm_out = hwm.gemm_out.max(lw.rows);
                }
            }
            PlanOp::Add { .. } => {}
            // gap stages its output through the GEMM staging matrix
            // (aliasing-safe)
            PlanOp::Gap { c, .. } => {
                hwm.gemm_out = hwm.gemm_out.max(*c);
            }
        }
    }
    hwm
}
