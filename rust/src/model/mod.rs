//! Model representation + compiled-plan integer inference.
//!
//! * [`manifest`]  — parses `artifacts/manifest.json` (graph program,
//!   layer table, ratio) via the in-repo JSON parser.
//! * [`weights`]   — loads `artifacts/weights.bin` (folded weights,
//!   schemes, alphas) and packs them into [`crate::gemm::PackedWeights`].
//! * [`im2col`]    — conv -> GEMM lowering for the integer path, with
//!   `_into` variants that reuse workspace buffers.
//! * [`plan`]      — the plan compiler: program names resolved to dense
//!   slot ids, per-op geometry precomputed and shape-checked, GEMM task
//!   schedules chunked, memory footprint sized — all once, at load time.
//! * [`workspace`] — the preallocated mutable buffers one inference
//!   stream reuses across calls (zero steady-state allocation).
//! * [`graph`]     — the executor: walks the compiled plan against the
//!   workspace (`infer`), and keeps the original name-resolving
//!   interpreter as the differential-test oracle (`reference_infer`) —
//!   the deployment path the FPGA simulator models, runnable on CPU.

pub mod graph;
pub mod im2col;
pub mod manifest;
pub mod plan;
pub mod weights;
pub mod workspace;

pub use graph::{Executor, Op, StageTimes};
pub use manifest::Manifest;
pub use plan::{Plan, PlanOp, PlanOptions};
pub use weights::{LayerWeights, ModelWeights};
pub use workspace::Workspace;
