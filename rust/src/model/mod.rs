//! Model representation + compiled-plan integer inference.
//!
//! * [`manifest`]  — parses `artifacts/manifest.json` (graph program,
//!   layer table, ratio) via the in-repo JSON parser.
//! * [`weights`]   — loads `artifacts/weights.bin` (folded weights,
//!   schemes, alphas) and packs them into [`crate::gemm::PackedWeights`].
//! * [`artifact`]  — the `.rmsa` packed artifact: the class-sorted,
//!   PoT-pre-decoded planes baked at export time, checksummed, and
//!   loaded zero-copy by `mmap` (manifest JSON embedded).
//! * [`im2col`]    — conv -> GEMM lowering for the integer path, with
//!   `_into` variants that reuse workspace buffers.
//! * [`ir`]        — the compiler IR: the manifest lowered to
//!   slot-indexed ops, shape-checked, with no optimization applied.
//! * [`passes`]    — the plan optimizer: graph-rewrite passes (epilogue
//!   fusion, domain inference, implicit-GEMM strategy, depthwise
//!   specialization, dead-slot elimination), each reporting what it did.
//! * [`plan`]      — [`plan::PlanBuilder`]: lower + optimize + seal into
//!   an immutable [`Plan`], with the memory footprint computed from the
//!   optimized ops — all once, at load time.
//! * [`workspace`] — the preallocated mutable buffers one inference
//!   stream reuses across calls (zero steady-state allocation).
//! * [`graph`]     — the executor: walks the compiled plan against the
//!   workspace (`infer`), and keeps the original name-resolving
//!   interpreter as the differential-test oracle (`reference_infer`) —
//!   the deployment path the FPGA simulator models, runnable on CPU.

pub mod artifact;
pub mod graph;
pub mod im2col;
pub(crate) mod ir;
pub mod manifest;
pub mod passes;
pub mod plan;
pub mod weights;
pub mod workspace;

pub use graph::{Executor, Op, StageTimes};
pub use manifest::Manifest;
pub use passes::{PassReport, PASS_NAMES};
pub use plan::{FusedAdd, Plan, PlanBuilder, PlanOp};
#[allow(deprecated)]
pub use plan::PlanOptions;
pub use weights::{LayerWeights, ModelWeights};
pub use workspace::Workspace;
