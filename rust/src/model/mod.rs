//! Model representation + integer inference executor.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (graph program, layer
//!   table, ratio) via the in-repo JSON parser.
//! * [`weights`]  — loads `artifacts/weights.bin` (folded weights, schemes,
//!   alphas) and packs them into [`crate::gemm::PackedWeights`].
//! * [`im2col`]   — conv -> GEMM lowering for the integer path.
//! * [`graph`]    — the op-program interpreter: executes conv/linear/add/
//!   gap over the mixed GEMM cores, layer by layer — the deployment path
//!   the FPGA simulator models, runnable on CPU.

pub mod graph;
pub mod im2col;
pub mod manifest;
pub mod weights;

pub use graph::{Executor, Op};
pub use manifest::Manifest;
pub use weights::{LayerWeights, ModelWeights};
