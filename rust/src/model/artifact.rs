//! `.rmsa` packed weight artifacts: the class-sorted, PoT-pre-decoded
//! layout baked at export time and loaded by `mmap` — validate the
//! header, then alias.
//!
//! The legacy `RMSW` container stores float weights, so every load pays
//! the full online pipeline: parse, quantize every element
//! (`PackedWeights::quantize` — a log2 / level search per weight), and
//! permute rows into the class-sorted kernel layout
//! (`SortedWeights::from_packed`). That work is identical across every
//! process and every restart. The artifact stores its *results*: the
//! exact byte planes `PackedWeights` / `SortedWeights` hold in memory,
//! so loading is a header validation plus O(rows) metadata copies — the
//! O(rows·cols) quantized planes are aliased straight out of the mapping
//! ([`crate::util::mmap::Plane`]), and the page cache shares them across
//! every process serving the same artifact.
//!
//! ## Container layout (version 1, all integers little-endian)
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------
//!      0     4  magic "RMSA"
//!      4     4  u32  format version (1)
//!      8     8  u64  file length (must equal the real file size)
//!     16     8  u64  checksum of bytes[24..file_len] (FNV-1a-64
//!                    over LE u64 words, zero-padded tail, length
//!                    mixed into the final state)
//!     24     4  u32  layer count
//!     28     4  u32  flags (0 in v1)
//!     32     8  u64  layer table offset (64)
//!     40     8  u64  manifest JSON offset
//!     48     8  u64  manifest JSON length
//!     56     8  u64  reserved (0)
//!     64          fixed 160-byte layer records, then the sections
//! ```
//!
//! Each 160-byte layer record:
//!
//! ```text
//! offset  size  field
//! ------  ----  -----------------------------------------------
//!      0     8  u64  name offset          (name_len bytes, UTF-8)
//!      8     4  u32  name length
//!     12     1  u8   kind (0 = conv, 1 = linear)
//!     13     1  u8   has_pot (1 iff any row is PoT — the pot_mult
//!                    plane exists exactly then)
//!     14     2  reserved (0)
//!     16    36  nine u32: rows cols out_ch in_ch kh kw stride pad groups
//!     52     4  f32  a_alpha
//!     56     8  u64  scheme offset        (rows bytes, scheme codes)
//!     64     8  u64  alpha offset         (rows f32, model row order)
//!     72     8  u64  bias offset          (rows f32)
//!     80     8  u64  perm offset          (rows u32, sorted → original)
//!     88     8  u64  codes offset         (rows·cols i8, model order)
//!     96     8  u64  pot_mult offset      (rows·cols i8, or 0 if no PoT rows)
//!    104     8  u64  ops offset           (rows·cols i8, sorted order)
//!    112    48  reserved (0)
//! ```
//!
//! **Alignment**: every section offset (names and manifest included) is
//! a multiple of 64 — one cache line, and a divisor of the page size, so
//! a mapped section keeps the alignment the SIMD kernels see on the
//! owned path. The loader rejects misaligned offsets.
//!
//! **Versioning**: the major format version is a hard gate — a reader
//! only accepts versions it was built for. Room to grow lives in the
//! reserved header/record fields and the `flags` word, which v1 readers
//! require to be zero (so a future writer can only set a flag by also
//! bumping the version if old readers must not load the file).
//!
//! **Validation**: magic, version, file length, and checksum are checked
//! before any section is touched; offsets/lengths go through checked
//! arithmetic against the real file size; scheme bytes must decode, the
//! stored permutation must equal the stable class sort recomputed from
//! the scheme plane, and `has_pot` must match the scheme counts. A
//! corrupt artifact produces a typed [`crate::util::error::Error`] —
//! never undefined behavior (pinned by bit-flip/truncation property
//! tests).
//!
//! **Design lineage**: the layout follows tract's NNEF tensor container
//! (the exemplar this repo's roadmap pointed at): one magic + version
//! header, a table of fixed-size item records up front so a reader can
//! plan without scanning, all bulk tensor bytes in aligned sections
//! aliasable directly from the mapping, and the human-readable graph
//! description (here: the manifest JSON) embedded verbatim next to the
//! tensors so one file is the whole model.

use std::path::Path;
use std::sync::Arc;

use crate::gemm::{PackedWeights, RowPartition, SortedWeights};
use crate::model::{LayerWeights, Manifest, ModelWeights};
use crate::quant::Scheme;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::mmap::{MappedFile, Plane, SECTION_ALIGN};
use crate::{bail, ensure, err};

/// Artifact magic (`RMSW` is the legacy float container).
pub const MAGIC: &[u8; 4] = b"RMSA";
/// Format version this build writes and accepts.
pub const VERSION: u32 = 1;
const HEADER_LEN: usize = 64;
const RECORD_LEN: usize = 160;

/// FNV-1a-64 over little-endian u64 words (tail zero-padded), with the
/// payload length mixed into the final state. Every step is a bijection
/// of the running state, so any single flipped bit — and any truncation
/// the length mix sees — changes the digest.
pub fn checksum(payload: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h = 0xcbf29ce484222325u64;
    let mut words = payload.chunks_exact(8);
    for w in words.by_ref() {
        h = (h ^ u64::from_le_bytes(w.try_into().unwrap())).wrapping_mul(PRIME);
    }
    let rem = words.remainder();
    if !rem.is_empty() {
        let mut w = [0u8; 8];
        w[..rem.len()].copy_from_slice(rem);
        h = (h ^ u64::from_le_bytes(w)).wrapping_mul(PRIME);
    }
    (h ^ payload.len() as u64).wrapping_mul(PRIME)
}

// ---- writer -------------------------------------------------------------

fn pad_to_align(v: &mut Vec<u8>) {
    v.resize(v.len().next_multiple_of(SECTION_ALIGN), 0);
}

/// Append one aligned section, returning its offset.
fn push_section(v: &mut Vec<u8>, bytes: &[u8]) -> u64 {
    pad_to_align(v);
    let off = v.len() as u64;
    v.extend_from_slice(bytes);
    off
}

#[inline]
fn i8_bytes(s: &[i8]) -> &[u8] {
    // i8 and u8 have identical layout; reinterpreting a shared slice is safe.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, s.len()) }
}

/// Serialize a model into the `.rmsa` container. The manifest JSON is
/// validated, then embedded verbatim (so the artifact round-trips the
/// exact document the export produced).
pub fn pack(manifest_json: &str, weights: &ModelWeights) -> Result<Vec<u8>> {
    let j = Json::parse(manifest_json).context("manifest JSON for packing")?;
    Manifest::from_json(&j).context("manifest for packing")?;

    let n = weights.layers.len();
    let mut v = vec![0u8; HEADER_LEN + n * RECORD_LEN];
    let mut records = Vec::with_capacity(n);
    for l in &weights.layers {
        ensure!(l.rows < u32::MAX as usize, "layer {:?}: too many rows", l.name);
        let name_off = push_section(&mut v, l.name.as_bytes());
        let scheme_bytes: Vec<u8> = l.scheme.iter().map(|&s| s as u8).collect();
        let scheme_off = push_section(&mut v, &scheme_bytes);
        let alpha_bytes: Vec<u8> = l.alpha.iter().flat_map(|a| a.to_le_bytes()).collect();
        let alpha_off = push_section(&mut v, &alpha_bytes);
        let bias_bytes: Vec<u8> = l.bias.iter().flat_map(|b| b.to_le_bytes()).collect();
        let bias_off = push_section(&mut v, &bias_bytes);
        let perm_bytes: Vec<u8> = l
            .sorted
            .perm
            .iter()
            .flat_map(|&p| (p as u32).to_le_bytes())
            .collect();
        let perm_off = push_section(&mut v, &perm_bytes);
        let codes_off = push_section(&mut v, i8_bytes(&l.packed.codes));
        let has_pot = !l.packed.pot_mult.is_empty();
        let pot_mult_off = if has_pot {
            push_section(&mut v, i8_bytes(&l.packed.pot_mult))
        } else {
            0
        };
        let ops_off = push_section(&mut v, i8_bytes(l.sorted.op_rows(0, l.sorted.rows)));
        records.push((name_off, has_pot, scheme_off, alpha_off, bias_off, perm_off, codes_off, pot_mult_off, ops_off));
    }
    let manifest_off = push_section(&mut v, manifest_json.as_bytes());
    let manifest_len = manifest_json.len() as u64;

    // layer table
    for (i, (l, rec)) in weights.layers.iter().zip(&records).enumerate() {
        let (name_off, has_pot, scheme_off, alpha_off, bias_off, perm_off, codes_off, pot_mult_off, ops_off) = *rec;
        let r = HEADER_LEN + i * RECORD_LEN;
        v[r..r + 8].copy_from_slice(&name_off.to_le_bytes());
        v[r + 8..r + 12].copy_from_slice(&(l.name.len() as u32).to_le_bytes());
        v[r + 12] = if l.kind == "conv" { 0 } else { 1 };
        v[r + 13] = has_pot as u8;
        let geo = [l.rows, l.cols, l.out_ch, l.in_ch, l.kh, l.kw, l.stride, l.pad, l.groups];
        for (k, g) in geo.iter().enumerate() {
            let o = r + 16 + 4 * k;
            v[o..o + 4].copy_from_slice(&(*g as u32).to_le_bytes());
        }
        v[r + 52..r + 56].copy_from_slice(&l.a_alpha.to_le_bytes());
        for (k, off) in [scheme_off, alpha_off, bias_off, perm_off, codes_off, pot_mult_off, ops_off]
            .iter()
            .enumerate()
        {
            let o = r + 56 + 8 * k;
            v[o..o + 8].copy_from_slice(&off.to_le_bytes());
        }
    }

    // header
    v[0..4].copy_from_slice(MAGIC);
    v[4..8].copy_from_slice(&VERSION.to_le_bytes());
    let file_len = v.len() as u64;
    v[8..16].copy_from_slice(&file_len.to_le_bytes());
    v[24..28].copy_from_slice(&(n as u32).to_le_bytes());
    // flags at 28..32 stay 0
    v[32..40].copy_from_slice(&(HEADER_LEN as u64).to_le_bytes());
    v[40..48].copy_from_slice(&manifest_off.to_le_bytes());
    v[48..56].copy_from_slice(&manifest_len.to_le_bytes());
    let sum = checksum(&v[24..]);
    v[16..24].copy_from_slice(&sum.to_le_bytes());
    Ok(v)
}

/// [`pack`] straight to a file.
pub fn pack_to_file(manifest_json: &str, weights: &ModelWeights, out: &Path) -> Result<()> {
    let bytes = pack(manifest_json, weights)?;
    std::fs::write(out, &bytes).with_context(|| format!("writing {}", out.display()))?;
    Ok(())
}

// ---- reader -------------------------------------------------------------

fn section<'a>(b: &'a [u8], off: usize, len: usize, what: &str) -> Result<&'a [u8]> {
    let end = off
        .checked_add(len)
        .ok_or_else(|| err!("{what} section range overflows ({off} + {len})"))?;
    b.get(off..end)
        .ok_or_else(|| err!("{what} section [{off}, {end}) outside the {}-byte artifact", b.len()))
}

fn aligned(off: usize, what: &str) -> Result<usize> {
    ensure!(
        off % SECTION_ALIGN == 0,
        "{what} section at byte {off} breaks the {SECTION_ALIGN}-byte alignment rule"
    );
    Ok(off)
}

fn rd_u32(b: &[u8], off: usize) -> Result<u32> {
    Ok(u32::from_le_bytes(section(b, off, 4, "u32 field")?.try_into().unwrap()))
}

fn rd_u64_usize(b: &[u8], off: usize) -> Result<usize> {
    let x = u64::from_le_bytes(section(b, off, 8, "u64 field")?.try_into().unwrap());
    usize::try_from(x).map_err(|_| err!("offset {x} exceeds the address space"))
}

fn rd_f32_vec(b: &[u8], off: usize, n: usize, what: &str) -> Result<Vec<f32>> {
    let raw = section(b, off, 4 * n, what)?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Load an artifact: map the file, validate header + checksum, parse the
/// embedded manifest, and assemble [`ModelWeights`] whose quantized
/// planes alias the mapping (float weights are `None` on this path).
pub fn load(path: &Path) -> Result<(Manifest, ModelWeights)> {
    let map = Arc::new(MappedFile::open(&path.to_string_lossy())?);
    load_mapped(map).with_context(|| format!("artifact {}", path.display()))
}

fn load_mapped(map: Arc<MappedFile>) -> Result<(Manifest, ModelWeights)> {
    let b = map.bytes();
    ensure!(b.len() >= HEADER_LEN, "truncated: {} bytes is smaller than the header", b.len());
    ensure!(&b[0..4] == MAGIC, "bad magic (want RMSA)");
    let version = rd_u32(b, 4)?;
    ensure!(version == VERSION, "unsupported artifact version {version} (reader speaks {VERSION})");
    let file_len = rd_u64_usize(b, 8)?;
    ensure!(
        file_len == b.len(),
        "file length mismatch: header says {file_len}, file holds {} bytes",
        b.len()
    );
    let stored_sum = u64::from_le_bytes(b[16..24].try_into().unwrap());
    let actual_sum = checksum(&b[24..]);
    ensure!(
        stored_sum == actual_sum,
        "checksum mismatch: stored {stored_sum:#018x}, computed {actual_sum:#018x}"
    );
    let n_layers = rd_u32(b, 24)? as usize;
    let flags = rd_u32(b, 28)?;
    ensure!(flags == 0, "unknown flags {flags:#x} (v1 defines none)");
    let table_off = aligned(rd_u64_usize(b, 32)?, "layer table")?;
    let manifest_off = aligned(rd_u64_usize(b, 40)?, "manifest")?;
    let manifest_len = rd_u64_usize(b, 48)?;

    let mjson = std::str::from_utf8(section(b, manifest_off, manifest_len, "manifest")?)
        .map_err(|e| err!("manifest is not UTF-8: {e}"))?;
    let manifest = Manifest::from_json(&Json::parse(mjson)?).context("embedded manifest")?;

    let table_len = n_layers
        .checked_mul(RECORD_LEN)
        .ok_or_else(|| err!("layer count {n_layers} overflows"))?;
    let table = section(b, table_off, table_len, "layer table")?;

    let mut layers = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        let r = &table[i * RECORD_LEN..(i + 1) * RECORD_LEN];
        let name_off = aligned(rd_u64_usize(r, 0)?, "name")?;
        let name_len = rd_u32(r, 8)? as usize;
        let name = std::str::from_utf8(section(b, name_off, name_len, "name")?)
            .map_err(|e| err!("layer {i} name is not UTF-8: {e}"))?
            .to_string();
        let kind = match r[12] {
            0 => "conv",
            1 => "linear",
            k => bail!("layer {name:?}: unknown kind code {k}"),
        }
        .to_string();
        let has_pot = match r[13] {
            0 => false,
            1 => true,
            k => bail!("layer {name:?}: bad has_pot byte {k}"),
        };
        let mut geo = [0usize; 9];
        for (k, g) in geo.iter_mut().enumerate() {
            *g = rd_u32(r, 16 + 4 * k)? as usize;
        }
        let [rows, cols, out_ch, in_ch, kh, kw, stride, pad, groups] = geo;
        let elems = rows
            .checked_mul(cols)
            .ok_or_else(|| err!("layer {name:?}: shape {rows}x{cols} overflows"))?;
        let a_alpha = f32::from_le_bytes(r[52..56].try_into().unwrap());
        let scheme_off = aligned(rd_u64_usize(r, 56)?, "scheme")?;
        let alpha_off = aligned(rd_u64_usize(r, 64)?, "alpha")?;
        let bias_off = aligned(rd_u64_usize(r, 72)?, "bias")?;
        let perm_off = aligned(rd_u64_usize(r, 80)?, "perm")?;
        let codes_off = aligned(rd_u64_usize(r, 88)?, "codes")?;
        let pot_mult_off = aligned(rd_u64_usize(r, 96)?, "pot_mult")?;
        let ops_off = aligned(rd_u64_usize(r, 104)?, "ops")?;

        let scheme: Vec<Scheme> = section(b, scheme_off, rows, "scheme")?
            .iter()
            .map(|&c| Scheme::from_code(c).ok_or_else(|| err!("layer {name:?}: bad scheme code {c}")))
            .collect::<Result<_>>()?;
        let mut counts = [0usize; 4];
        for s in &scheme {
            counts[*s as usize] += 1;
        }
        ensure!(
            has_pot == (counts[0] > 0),
            "layer {name:?}: has_pot flag disagrees with {} PoT rows",
            counts[0]
        );
        let alpha = rd_f32_vec(b, alpha_off, rows, "alpha")?;
        let bias = rd_f32_vec(b, bias_off, rows, "bias")?;
        // the stored permutation must be exactly the stable class sort of
        // the scheme plane — the layout contract every kernel relies on
        let perm: Vec<usize> = section(b, perm_off, 4 * rows, "perm")?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        let mut want_perm = Vec::with_capacity(rows);
        for class in RowPartition::CLASS_ORDER {
            for (ri, s) in scheme.iter().enumerate() {
                if *s == class {
                    want_perm.push(ri);
                }
            }
        }
        ensure!(
            perm == want_perm,
            "layer {name:?}: stored permutation is not the stable class sort"
        );

        let codes = Plane::mapped(map.clone(), codes_off, elems)?;
        let pot_mult = if has_pot {
            Plane::mapped(map.clone(), pot_mult_off, elems)?
        } else {
            Plane::empty()
        };
        let ops = Plane::mapped(map.clone(), ops_off, elems)?;
        let sorted_alpha: Vec<f32> = perm.iter().map(|&o| alpha[o]).collect();
        let packed =
            PackedWeights::from_parts(rows, cols, codes, pot_mult, scheme.clone(), alpha.clone())
                .with_context(|| format!("layer {name:?} packed planes"))?;
        let sorted = SortedWeights::from_parts(rows, cols, ops, perm, sorted_alpha, counts)
            .with_context(|| format!("layer {name:?} sorted plane"))?;
        layers.push(LayerWeights {
            name,
            kind,
            rows,
            cols,
            out_ch,
            in_ch,
            kh,
            kw,
            stride,
            pad,
            groups,
            a_alpha,
            scheme,
            alpha,
            bias,
            w: None,
            packed,
            sorted,
        });
    }
    Ok((manifest, ModelWeights { layers }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, Mat};
    use crate::util::rng::Rng;

    fn tiny_manifest_json() -> String {
        r#"{
          "model": "tiny", "arch": "mlp", "num_classes": 3,
          "input_shape": [1, 2, 1, 1], "ratio": [65, 30, 5], "act_bits": 4,
          "layers": [
            {"name": "fc", "kind": "linear", "rows": 3, "cols": 2,
             "stride": 0, "pad": 0, "groups": 1, "a_alpha": 1.0,
             "scheme_counts": [1, 1, 1, 0]}
          ],
          "program": [
            {"op": "gap", "in": "in0", "out": "b0"},
            {"op": "linear", "layer": "fc", "in": "b0", "out": "logits"}
          ]
        }"#
        .to_string()
    }

    fn tiny_weights(seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let w = Mat::from_vec(3, 2, rng.normal_vec(6, 0.5));
        let scheme = vec![Scheme::FixedW4A4, Scheme::PotW4A4, Scheme::FixedW8A4];
        let alpha: Vec<f32> = (0..3).map(|r| quant::default_alpha(w.row(r))).collect();
        let packed = PackedWeights::quantize(&w, &scheme, &alpha);
        let sorted = SortedWeights::from_packed(&packed);
        ModelWeights {
            layers: vec![LayerWeights {
                name: "fc".into(),
                kind: "linear".into(),
                rows: 3,
                cols: 2,
                out_ch: 3,
                in_ch: 2,
                kh: 0,
                kw: 0,
                stride: 0,
                pad: 0,
                groups: 1,
                a_alpha: 1.0,
                scheme,
                alpha: alpha.clone(),
                bias: vec![0.1, -0.2, 0.3],
                w: Some(w),
                packed,
                sorted,
            }],
        }
    }

    fn write_tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rmsmp-artifact-{}-{}.rmsa", std::process::id(), name));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn pack_load_roundtrip_matches() {
        let weights = tiny_weights(7);
        let bytes = pack(&tiny_manifest_json(), &weights).unwrap();
        let p = write_tmp("roundtrip", &bytes);
        let (m, loaded) = load(&p).unwrap();
        assert_eq!(m.model, "tiny");
        assert_eq!(loaded.layers.len(), 1);
        let (a, b) = (&weights.layers[0], &loaded.layers[0]);
        assert_eq!(a.name, b.name);
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.scheme, b.scheme);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.bias, b.bias);
        assert_eq!(a.packed.codes, b.packed.codes);
        assert_eq!(a.packed.pot_mult, b.packed.pot_mult);
        assert_eq!(a.sorted.perm, b.sorted.perm);
        assert_eq!(a.sorted.inv, b.sorted.inv);
        assert_eq!(a.sorted.alpha, b.sorted.alpha);
        assert_eq!(
            a.sorted.op_rows(0, a.rows),
            b.sorted.op_rows(0, b.rows)
        );
        assert_eq!(a.sorted.partition(), b.sorted.partition());
        assert!(b.w.is_none(), "artifact path must not fabricate float weights");
        assert!(b.packed.codes.is_mapped());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn model_weights_load_dispatches_on_magic() {
        let weights = tiny_weights(9);
        let bytes = pack(&tiny_manifest_json(), &weights).unwrap();
        let p = write_tmp("dispatch", &bytes);
        let via_load = ModelWeights::load(&p).unwrap();
        assert_eq!(via_load.layers[0].packed.codes, weights.layers[0].packed.codes);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_wrong_magic_version_and_length() {
        let weights = tiny_weights(11);
        let good = pack(&tiny_manifest_json(), &weights).unwrap();

        let mut bad = good.clone();
        bad[0] = b'X';
        let p = write_tmp("magic", &bad);
        assert!(load(&p).unwrap_err().to_string().contains("magic"));

        let mut bad = good.clone();
        bad[4] = 99;
        std::fs::write(&p, &bad).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("version"));

        std::fs::write(&p, &good[..good.len() - 7]).unwrap();
        assert!(load(&p).unwrap_err().to_string().contains("length mismatch"));

        std::fs::write(&p, &good[..32]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn checksum_catches_any_single_bit_flip() {
        let weights = tiny_weights(13);
        let good = pack(&tiny_manifest_json(), &weights).unwrap();
        let p = write_tmp("flip", &good);
        assert!(load(&p).is_ok());
        let mut rng = Rng::new(0xF11B);
        for _ in 0..40 {
            let byte = 24 + rng.below((good.len() - 24) as u64) as usize;
            let bit = rng.below(8) as u8;
            let mut bad = good.clone();
            bad[byte] ^= 1 << bit;
            std::fs::write(&p, &bad).unwrap();
            let e = load(&p).unwrap_err().to_string();
            assert!(e.contains("checksum"), "flip at byte {byte} bit {bit}: {e}");
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn misaligned_section_is_rejected() {
        let weights = tiny_weights(17);
        let mut bytes = pack(&tiny_manifest_json(), &weights).unwrap();
        // nudge the codes offset (record field at 88) off alignment and
        // re-seal the checksum so only the alignment check can fire
        let r = HEADER_LEN;
        let off = u64::from_le_bytes(bytes[r + 88..r + 96].try_into().unwrap());
        bytes[r + 88..r + 96].copy_from_slice(&(off + 1).to_le_bytes());
        let sum = checksum(&bytes[24..]);
        bytes[16..24].copy_from_slice(&sum.to_le_bytes());
        let p = write_tmp("misaligned", &bytes);
        let e = load(&p).unwrap_err().to_string();
        assert!(e.contains("alignment"), "{e}");
        std::fs::remove_file(&p).unwrap();
    }
}
