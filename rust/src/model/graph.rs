//! The integer inference executor: compiled-plan runner + reference
//! interpreter.
//!
//! Inference is split compile-then-run (see [`super::plan`]): at load
//! time the manifest's op program is compiled into a slot-indexed
//! [`Plan`] and a preallocated [`Workspace`]; at request time
//! [`Executor::infer`] walks the precompiled ops against the workspace
//! buffers — no name resolution, no shape discovery, and no
//! steady-state buffer allocation (batches at or below the plan's
//! capacity reuse every buffer in place; sequential execution performs
//! zero heap allocation outright, parallel dispatch additionally boxes
//! O(threads) pool jobs per GEMM).
//!
//! Every GEMM goes through the engine's single entry point,
//! [`crate::gemm::MixedGemm::dispatch`] over a [`crate::gemm::GemmCall`]
//! descriptor; the plan's pass pipeline (see [`super::passes`]) decided
//! at compile time which kernel each op selects:
//!
//! * **integer-resident** (`in_codes`/`out_quant`): where output-domain
//!   inference proved a value's only consumers are quantized GEMMs, the
//!   GEMM runs with the fused requantization epilogue
//!   ([`crate::gemm::QuantEpilogue`]) and the value flows to the next
//!   layer as u8 activation codes; only the input edge, unfused
//!   Add/Gap operands, and the logits run through f32.
//! * **implicit** (`implicit`/`panel_positions`): non-grouped convs
//!   never materialize an im2col matrix — the executor hands the GEMM a
//!   [`ColTileSource`] over the input slot and the dispatch packs
//!   cache-resident column-tile panels on the fly (gathering u8 codes
//!   from the NCHW slot, quantizing f32 during the gather, or aliasing
//!   NHWC-retargeted slots outright).
//! * **fused residual** (`fused_add`): a following elementwise
//!   Add(+ReLU) folded into the conv's epilogue — the addend slot joins
//!   the fused epilogue on the quant path, or one aliased `add_slots`
//!   pass on the f32 fallback; the standalone Add op is gone.
//! * **depthwise** (`group_chunks`): grouped convs run as per-group
//!   implicit dispatches ([`crate::gemm::MixedGemm::run_depthwise`])
//!   with per-group task schedules — no materialized patch buffer. The
//!   explicit per-row fallback survives only for plans compiled with
//!   the `depthwise` pass disabled.
//!
//! The original name-resolving interpreter survives as
//! [`Executor::reference_infer`]: the bit-exact oracle the differential
//! tests pin the plan path against (and the baseline the runtime bench
//! reports the plan speedup over). Integer-resident codes and logits
//! are pinned bit-exact against it by `tests/test_requant.rs`.
//!
//! The executor owns one [`MixedGemm`]; when built via
//! [`Executor::with_parallel`] the GEMM fans row chunks out over a thread
//! pool (optionally shared with other executors — the coordinator gives
//! every worker the same pool). `set_row_parallel` lets the coordinator
//! toggle row-level parallelism per batch without rebuilding anything.
//! The compiled-plan path dispatches every GEMM over the layer's
//! load-time class-sorted layout (`LayerWeights::sorted`) so the SIMD
//! micro-kernels stream contiguous same-scheme weight blocks; the
//! reference interpreter keeps sorting per call through the
//! compatibility wrappers, staying bit-exact.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::ensure;
use crate::err;
use crate::gemm::depthwise::{DwConv, DwOut, DwSource};
use crate::gemm::{
    requant_row, ColTileSource, GemmActs, GemmCall, GemmOut, Isa, MixedGemm, OutLayout,
    PackedActs, ParallelConfig, PatchGeometry, QuantEpilogue,
};
use crate::quant::tensor::Tensor4;
use crate::quant::Mat;
use crate::util::error::Result;
use crate::util::pool::ThreadPool;

use super::im2col::{
    col2im, col2im_slice_into, im2col, im2col_codes_range_into, im2col_group, im2col_range_into,
};
use super::manifest::{Manifest, OpMeta};
use super::plan::{Plan, PlanOp};
use super::weights::{LayerWeights, ModelWeights};
use super::workspace::Workspace;

/// Re-export for the coordinator's type surface.
pub type Op = OpMeta;

/// A buffer flowing through the reference interpreter: 4-D feature map
/// or 2-D matrix.
#[derive(Clone, Debug)]
pub enum Buf {
    T4(Tensor4),
    M(Mat),
}

impl Buf {
    fn t4(&self) -> Result<&Tensor4> {
        match self {
            Buf::T4(t) => Ok(t),
            Buf::M(_) => Err(err!("expected 4-D buffer")),
        }
    }

    fn mat(&self) -> Result<&Mat> {
        match self {
            Buf::M(m) => Ok(m),
            Buf::T4(_) => Err(err!("expected 2-D buffer")),
        }
    }
}

/// Cumulative wall time of the compiled-plan executor's pipeline
/// stages, in nanoseconds. `infer` accumulates these per call; the
/// serving loop drains them into the shared metrics
/// ([`crate::coordinator::Metrics`]) so the stats line shows where
/// batch time goes. On the integer-resident path the requantization
/// epilogue is fused into the GEMM, so `quantize_ns` and `epilogue_ns`
/// collapse toward zero and their cost appears (much reduced) inside
/// `gemm_ns`; on the implicit-GEMM conv path the im2col gather (and the
/// f32 path's quantize) are fused into the dispatch's panel packing
/// too, so for non-grouped convs `im2col_ns` also collapses into
/// `gemm_ns` and only the grouped fallback still reports it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTimes {
    /// Activation quantization (f32 → u8 codes) ahead of a GEMM, and
    /// the linear path's code copy.
    pub quantize_ns: u64,
    /// im2col patch unrolling (f32 or u8-code).
    pub im2col_ns: u64,
    /// Mixed-GEMM dispatch (includes the fused requantization epilogue
    /// on integer-resident ops).
    pub gemm_ns: u64,
    /// The f32 fallback's separate bias/ReLU pass + col2im fold +
    /// linear copy-out.
    pub epilogue_ns: u64,
}

impl StageTimes {
    /// Accumulate another sample into this one.
    pub fn add(&mut self, o: &StageTimes) {
        self.quantize_ns += o.quantize_ns;
        self.im2col_ns += o.im2col_ns;
        self.gemm_ns += o.gemm_ns;
        self.epilogue_ns += o.epilogue_ns;
    }

    /// Total across all four stages.
    pub fn total_ns(&self) -> u64 {
        self.quantize_ns + self.im2col_ns + self.gemm_ns + self.epilogue_ns
    }
}

/// The integer inference executor (see module docs).
pub struct Executor {
    manifest: Arc<Manifest>,
    weights: Arc<ModelWeights>,
    plan: Arc<Plan>,
    ws: Workspace,
    gemm: MixedGemm,
    row_parallel: bool,
    /// MACs executed since construction (for GOP accounting).
    pub macs: u64,
    /// Per-stage wall time accumulated by `infer` since the last
    /// [`Executor::take_stage_times`].
    stages: StageTimes,
}

impl Executor {
    /// Sequential executor (the seed's behaviour).
    pub fn new(manifest: Manifest, weights: ModelWeights) -> Result<Executor> {
        Executor::with_parallel(manifest, weights, ParallelConfig::sequential(), None)
    }

    /// Executor with a parallel mixed GEMM: compiles the plan (sized for
    /// the manifest's batch dimension) and preallocates the workspace.
    /// Pass a pool to share threads with other executors, or `None` to
    /// let the GEMM own one (when the config resolves to more than one
    /// thread).
    pub fn with_parallel(
        manifest: Manifest,
        weights: ModelWeights,
        cfg: ParallelConfig,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Executor> {
        let capacity = manifest.input_shape.first().copied().unwrap_or(1);
        let plan = Arc::new(
            Plan::builder(&manifest, &weights)
                .capacity(capacity)
                .config(&cfg)
                .build()?,
        );
        Executor::from_shared(Arc::new(manifest), Arc::new(weights), plan, cfg, pool)
    }

    /// Executor over already-shared model state: the serving coordinator
    /// compiles one [`Plan`] and loads one [`ModelWeights`], then gives
    /// every worker its own executor (private [`Workspace`]) over the
    /// same three `Arc`s — an N-worker server holds ~1x the weights, not
    /// Nx.
    pub fn from_shared(
        manifest: Arc<Manifest>,
        weights: Arc<ModelWeights>,
        plan: Arc<Plan>,
        cfg: ParallelConfig,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Executor> {
        // the plan bakes in layer indices and row partitions; reject a
        // weights table it was not compiled against before an op can
        // index out of bounds or run with the wrong geometry
        ensure!(
            plan.layer_parts.len() == weights.layers.len(),
            "plan compiled for {} layers, weights have {}",
            plan.layer_parts.len(),
            weights.layers.len()
        );
        for (part, lw) in plan.layer_parts.iter().zip(&weights.layers) {
            ensure!(
                part.total() == lw.rows,
                "plan/weights mismatch at layer {}: partition covers {} of {} rows",
                lw.name,
                part.total(),
                lw.rows
            );
            // the plan's chunk schedules index the sorted layout; a
            // weights table with a different class mix would make them
            // dispatch rows to the wrong cores
            ensure!(
                part == lw.sorted.partition(),
                "plan/weights mismatch at layer {}: scheme class mix differs",
                lw.name
            );
        }
        // the integer-resident epilogues bake the consumers' clip scales
        // in; reject weights they would requantize with a stale scale
        plan.validate_domains(&weights)?;
        // adopt the plan's autotuned blocking knobs for any knob the
        // caller left at its default, so execution matches the compiled
        // schedules (explicit caller values still win)
        let cfg = plan.tuned.apply_to(cfg);
        let gemm = match pool {
            Some(p) => MixedGemm::with_shared_pool(cfg, p),
            None => MixedGemm::with_config(cfg),
        };
        let row_parallel = gemm.is_parallel();
        let ws = Workspace::new(&plan, gemm.lanes());
        Ok(Executor {
            manifest,
            weights,
            plan,
            ws,
            gemm,
            row_parallel,
            macs: 0,
            stages: StageTimes::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn weights(&self) -> &ModelWeights {
        &self.weights
    }

    /// The compiled execution plan this executor runs.
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The executor's reusable workspace (introspection / footprint).
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Toggle row-level GEMM parallelism for subsequent `infer` calls.
    /// No-op when the executor has no pool. The coordinator turns this
    /// off for batches wide enough to fill the machine by themselves.
    pub fn set_row_parallel(&mut self, on: bool) {
        self.row_parallel = on && self.gemm.is_parallel();
    }

    /// Whether the next `infer` will use row-level parallelism.
    pub fn row_parallel(&self) -> bool {
        self.row_parallel
    }

    /// Force the GEMM kernel ISA (differential tests and benches).
    /// Requests wider than the hardware supports are clamped.
    pub fn set_isa(&mut self, isa: Isa) {
        self.gemm.set_isa(isa);
    }

    /// The SIMD ISA the GEMM micro-kernels run on.
    pub fn isa(&self) -> Isa {
        self.gemm.isa()
    }

    /// Per-stage wall time accumulated by `infer` since construction or
    /// the last [`Executor::take_stage_times`].
    pub fn stage_times(&self) -> StageTimes {
        self.stages
    }

    /// Drain the accumulated per-stage timings (the serving loop calls
    /// this after each batch and feeds the sample to the metrics).
    pub fn take_stage_times(&mut self) -> StageTimes {
        std::mem::take(&mut self.stages)
    }

    /// Run one batch (NCHW input) through the compiled plan; returns the
    /// logits (batch, num_classes), borrowed from the workspace (valid
    /// until the next `infer`). For batches at or below the plan
    /// capacity (after a first warm-up call when the batch exceeds it),
    /// no buffer is allocated: sequential execution touches the heap
    /// zero times; parallel dispatch additionally boxes O(threads) pool
    /// jobs per GEMM.
    pub fn infer(&mut self, x: &Tensor4) -> Result<&Mat> {
        let plan = Arc::clone(&self.plan);
        let weights = Arc::clone(&self.weights);
        let (pc, ph, pw) = plan.input_chw;
        ensure!(
            (x.c, x.h, x.w) == (pc, ph, pw),
            "input shape {}x{}x{} != manifest {pc}x{ph}x{pw}",
            x.c,
            x.h,
            x.w
        );
        let n = x.n;
        let act_bits = plan.act_bits;
        let row_parallel = self.row_parallel;
        let gemm = &mut self.gemm;
        let ws = &mut self.ws;
        // per-layer tuned blocking: each GEMM op installs its baked
        // micro_rows/tile_cols on the engine before dispatch; restore
        // the engine baseline afterwards so the reference interpreter
        // (and any later caller) sees the config it was built with
        let base_cfg = gemm.config();
        let mut macs = 0u64;
        let mut st = StageTimes::default();

        ws.slots[plan.input_slot].resize(x.data.len(), 0.0);
        ws.slots[plan.input_slot].copy_from_slice(&x.data);

        for op in &plan.ops {
            match op {
                PlanOp::Conv {
                    layer,
                    input,
                    out,
                    relu,
                    in_c,
                    in_h,
                    in_w,
                    oh,
                    ow,
                    k,
                    stride,
                    pad,
                    groups,
                    ch_per_group,
                    filt_per_group,
                    chunks,
                    in_codes,
                    out_quant,
                    implicit,
                    panel_positions,
                    in_nhwc,
                    out_nhwc,
                    fused_add,
                    group_chunks,
                    micro_rows,
                    tile_cols,
                } => {
                    let lw = &weights.layers[*layer];
                    gemm.set_block_knobs(*micro_rows, *tile_cols);
                    let inp_len = n * in_c * in_h * in_w;
                    let hw = oh * ow;
                    let batch = n * hw;
                    let out_len = n * lw.out_ch * hw;
                    if *implicit {
                        // implicit GEMM: no materialized im2col, no f32
                        // staging on the integer path — the dispatch
                        // streams the input through per-lane panels
                        // (aliasing the slot outright when it is NHWC)
                        let geo = PatchGeometry::new(
                            n, *in_c, *in_h, *in_w, 0, *in_c, *k, *stride, *pad,
                        );
                        let t = Instant::now();
                        match out_quant {
                            Some(rq) => {
                                let layout = if *out_nhwc {
                                    OutLayout::RowMajor { cols: lw.out_ch }
                                } else {
                                    OutLayout::Nchw { channels: lw.out_ch, hw }
                                };
                                // fused residual: the addend slot joins
                                // the epilogue (it is always f32 — the
                                // conv reads it elementwise, not as a
                                // quantized GEMM input)
                                let addend =
                                    fused_add.as_ref().map(|fa| &ws.slots[fa.addend][..out_len]);
                                if *in_codes {
                                    let (inp, outv) =
                                        slot_pair(&mut ws.code_slots, *input, *out);
                                    outv.resize(out_len, 0);
                                    let src = code_source(
                                        &inp[..inp_len],
                                        geo,
                                        *in_nhwc,
                                        lw.a_alpha,
                                        act_bits,
                                    );
                                    gemm.dispatch(
                                        GemmCall {
                                            acts: GemmActs::Tiles {
                                                src: &src,
                                                positions: *panel_positions,
                                            },
                                            weights: &lw.sorted,
                                            chunks,
                                            parallel: row_parallel,
                                            fill: true,
                                            out: GemmOut::Quant {
                                                out: &mut outv[..out_len],
                                                epi: QuantEpilogue {
                                                    bias: &lw.bias,
                                                    rq: *rq,
                                                    layout,
                                                    addend,
                                                },
                                            },
                                        },
                                        &mut ws.scratch,
                                    );
                                } else {
                                    ws.code_slots[*out].resize(out_len, 0);
                                    let src = ColTileSource::F32 {
                                        data: &ws.slots[*input][..inp_len],
                                        geo,
                                        alpha: lw.a_alpha,
                                        bits: act_bits,
                                    };
                                    gemm.dispatch(
                                        GemmCall {
                                            acts: GemmActs::Tiles {
                                                src: &src,
                                                positions: *panel_positions,
                                            },
                                            weights: &lw.sorted,
                                            chunks,
                                            parallel: row_parallel,
                                            fill: true,
                                            out: GemmOut::Quant {
                                                out: &mut ws.code_slots[*out][..out_len],
                                                epi: QuantEpilogue {
                                                    bias: &lw.bias,
                                                    rq: *rq,
                                                    layout,
                                                    addend,
                                                },
                                            },
                                        },
                                        &mut ws.scratch,
                                    );
                                }
                            }
                            None => {
                                ws.stage.resize(batch, lw.rows);
                                if *in_codes {
                                    let src = code_source(
                                        &ws.code_slots[*input][..inp_len],
                                        geo,
                                        *in_nhwc,
                                        lw.a_alpha,
                                        act_bits,
                                    );
                                    gemm.dispatch(
                                        GemmCall {
                                            acts: GemmActs::Tiles {
                                                src: &src,
                                                positions: *panel_positions,
                                            },
                                            weights: &lw.sorted,
                                            chunks,
                                            parallel: row_parallel,
                                            fill: true,
                                            out: GemmOut::F32(&mut ws.stage),
                                        },
                                        &mut ws.scratch,
                                    );
                                } else {
                                    let src = ColTileSource::F32 {
                                        data: &ws.slots[*input][..inp_len],
                                        geo,
                                        alpha: lw.a_alpha,
                                        bits: act_bits,
                                    };
                                    gemm.dispatch(
                                        GemmCall {
                                            acts: GemmActs::Tiles {
                                                src: &src,
                                                positions: *panel_positions,
                                            },
                                            weights: &lw.sorted,
                                            chunks,
                                            parallel: row_parallel,
                                            fill: true,
                                            out: GemmOut::F32(&mut ws.stage),
                                        },
                                        &mut ws.scratch,
                                    );
                                }
                            }
                        }
                        st.gemm_ns += t.elapsed().as_nanos() as u64;
                        macs += (batch * lw.rows * lw.cols) as u64;
                    } else if !group_chunks.is_empty() {
                        // depthwise/grouped specialization: per-group
                        // implicit dispatches over the compiled per-group
                        // schedules — no materialized patch buffer
                        let t = Instant::now();
                        match out_quant {
                            Some(rq) => {
                                let layout = OutLayout::Nchw { channels: lw.out_ch, hw };
                                if *in_codes {
                                    let (inp, outv) =
                                        slot_pair(&mut ws.code_slots, *input, *out);
                                    outv.resize(out_len, 0);
                                    gemm.run_depthwise(
                                        DwConv {
                                            src: DwSource::Codes(&inp[..inp_len]),
                                            n,
                                            c: *in_c,
                                            h: *in_h,
                                            w: *in_w,
                                            k: *k,
                                            stride: *stride,
                                            pad: *pad,
                                            ch_per_group: *ch_per_group,
                                            alpha: lw.a_alpha,
                                            bits: act_bits,
                                            weights: &lw.sorted,
                                            group_chunks,
                                            panel_positions: *panel_positions,
                                            parallel: row_parallel,
                                        },
                                        &mut ws.scratch,
                                        DwOut::Quant {
                                            out: &mut outv[..out_len],
                                            bias: &lw.bias,
                                            rq: *rq,
                                            layout,
                                        },
                                    );
                                } else {
                                    ws.code_slots[*out].resize(out_len, 0);
                                    let (slots, code_slots) = (&ws.slots, &mut ws.code_slots);
                                    gemm.run_depthwise(
                                        DwConv {
                                            src: DwSource::F32(&slots[*input][..inp_len]),
                                            n,
                                            c: *in_c,
                                            h: *in_h,
                                            w: *in_w,
                                            k: *k,
                                            stride: *stride,
                                            pad: *pad,
                                            ch_per_group: *ch_per_group,
                                            alpha: lw.a_alpha,
                                            bits: act_bits,
                                            weights: &lw.sorted,
                                            group_chunks,
                                            panel_positions: *panel_positions,
                                            parallel: row_parallel,
                                        },
                                        &mut ws.scratch,
                                        DwOut::Quant {
                                            out: &mut code_slots[*out][..out_len],
                                            bias: &lw.bias,
                                            rq: *rq,
                                            layout,
                                        },
                                    );
                                }
                            }
                            None => {
                                ws.stage.resize(batch, lw.rows);
                                let src = if *in_codes {
                                    DwSource::Codes(&ws.code_slots[*input][..inp_len])
                                } else {
                                    DwSource::F32(&ws.slots[*input][..inp_len])
                                };
                                gemm.run_depthwise(
                                    DwConv {
                                        src,
                                        n,
                                        c: *in_c,
                                        h: *in_h,
                                        w: *in_w,
                                        k: *k,
                                        stride: *stride,
                                        pad: *pad,
                                        ch_per_group: *ch_per_group,
                                        alpha: lw.a_alpha,
                                        bits: act_bits,
                                        weights: &lw.sorted,
                                        group_chunks,
                                        panel_positions: *panel_positions,
                                        parallel: row_parallel,
                                    },
                                    &mut ws.scratch,
                                    DwOut::F32(&mut ws.stage),
                                );
                            }
                        }
                        st.gemm_ns += t.elapsed().as_nanos() as u64;
                        macs += (batch * lw.rows * lw.cols) as u64;
                    } else if *groups == 1 {
                        if *in_codes {
                            // integer-resident input: unroll the u8 code
                            // slot straight into the GEMM operand — no
                            // f32 im2col, no requantize pass
                            let t = Instant::now();
                            im2col_codes_range_into(
                                &ws.code_slots[*input][..inp_len],
                                n,
                                *in_c,
                                *in_h,
                                *in_w,
                                0,
                                *in_c,
                                *k,
                                *stride,
                                *pad,
                                &mut ws.acts.codes,
                            );
                            ws.acts.set_meta(batch, lw.cols, lw.a_alpha, act_bits);
                            st.im2col_ns += t.elapsed().as_nanos() as u64;
                        } else {
                            let t = Instant::now();
                            im2col_range_into(
                                &ws.slots[*input][..inp_len],
                                n,
                                *in_c,
                                *in_h,
                                *in_w,
                                0,
                                *in_c,
                                *k,
                                *stride,
                                *pad,
                                &mut ws.patches,
                            );
                            st.im2col_ns += t.elapsed().as_nanos() as u64;
                            let t = Instant::now();
                            PackedActs::quantize_into(
                                &ws.patches,
                                lw.a_alpha,
                                act_bits,
                                &mut ws.acts,
                            );
                            st.quantize_ns += t.elapsed().as_nanos() as u64;
                        }
                        match out_quant {
                            Some(rq) => {
                                // fused epilogue: accumulator → consumer
                                // code, bias + add + ReLU + requantize +
                                // NCHW scatter all inside the dispatch
                                let t = Instant::now();
                                ws.code_slots[*out].resize(out_len, 0);
                                let addend =
                                    fused_add.as_ref().map(|fa| &ws.slots[fa.addend][..out_len]);
                                gemm.dispatch(
                                    GemmCall {
                                        acts: GemmActs::Packed(&ws.acts),
                                        weights: &lw.sorted,
                                        chunks,
                                        parallel: row_parallel,
                                        fill: true,
                                        out: GemmOut::Quant {
                                            out: &mut ws.code_slots[*out][..out_len],
                                            epi: QuantEpilogue {
                                                bias: &lw.bias,
                                                rq: *rq,
                                                layout: OutLayout::Nchw {
                                                    channels: lw.out_ch,
                                                    hw,
                                                },
                                                addend,
                                            },
                                        },
                                    },
                                    &mut ws.scratch,
                                );
                                st.gemm_ns += t.elapsed().as_nanos() as u64;
                            }
                            None => {
                                let t = Instant::now();
                                ws.stage.resize(batch, lw.rows);
                                gemm.dispatch(
                                    GemmCall {
                                        acts: GemmActs::Packed(&ws.acts),
                                        weights: &lw.sorted,
                                        chunks,
                                        parallel: row_parallel,
                                        fill: true,
                                        out: GemmOut::F32(&mut ws.stage),
                                    },
                                    &mut ws.scratch,
                                );
                                st.gemm_ns += t.elapsed().as_nanos() as u64;
                            }
                        }
                        macs += (batch * lw.rows * lw.cols) as u64;
                    } else {
                        // grouped conv: run each group's filters over its
                        // channel slice, row by row.
                        match out_quant {
                            Some(_) => ws.code_slots[*out].resize(n * lw.out_ch * hw, 0),
                            None => ws.stage.resize(batch, lw.rows),
                        }
                        for g in 0..*groups {
                            if *in_codes {
                                let t = Instant::now();
                                im2col_codes_range_into(
                                    &ws.code_slots[*input][..inp_len],
                                    n,
                                    *in_c,
                                    *in_h,
                                    *in_w,
                                    g * ch_per_group,
                                    *ch_per_group,
                                    *k,
                                    *stride,
                                    *pad,
                                    &mut ws.acts.codes,
                                );
                                ws.acts.set_meta(batch, lw.cols, lw.a_alpha, act_bits);
                                st.im2col_ns += t.elapsed().as_nanos() as u64;
                            } else {
                                let t = Instant::now();
                                im2col_range_into(
                                    &ws.slots[*input][..inp_len],
                                    n,
                                    *in_c,
                                    *in_h,
                                    *in_w,
                                    g * ch_per_group,
                                    *ch_per_group,
                                    *k,
                                    *stride,
                                    *pad,
                                    &mut ws.patches,
                                );
                                st.im2col_ns += t.elapsed().as_nanos() as u64;
                                let t = Instant::now();
                                PackedActs::quantize_into(
                                    &ws.patches,
                                    lw.a_alpha,
                                    act_bits,
                                    &mut ws.acts,
                                );
                                st.quantize_ns += t.elapsed().as_nanos() as u64;
                            }
                            let t = Instant::now();
                            let (col, acc) = ws.scratch.lane0(batch);
                            for fi in 0..*filt_per_group {
                                let r = g * filt_per_group + fi;
                                col.fill(0.0);
                                gemm.run_row_into(&ws.acts, &lw.packed, r, acc, col);
                                match out_quant {
                                    Some(rq) => {
                                        // row epilogue: requantize this
                                        // filter's outputs straight into
                                        // its NCHW code plane
                                        for img in 0..n {
                                            let base = ((img * lw.out_ch) + r) * hw;
                                            requant_row(
                                                &col[img * hw..(img + 1) * hw],
                                                lw.bias[r],
                                                *rq,
                                                &mut ws.code_slots[*out][base..base + hw],
                                            );
                                        }
                                    }
                                    None => {
                                        for (b, &v) in col.iter().enumerate() {
                                            ws.stage.set(b, r, v);
                                        }
                                    }
                                }
                            }
                            st.gemm_ns += t.elapsed().as_nanos() as u64;
                            macs += (batch * filt_per_group * lw.cols) as u64;
                        }
                    }
                    if out_quant.is_none() {
                        // f32 fallback epilogue, shared by every path
                        // that staged through the f32 matrix: bias +
                        // relu, fold into the output slot, then replay a
                        // folded residual Add (the integer path fused
                        // all of this into the GEMM dispatch above)
                        let t = Instant::now();
                        conv_bias_relu(&mut ws.stage, &lw.bias, *relu);
                        ws.slots[*out].resize(out_len, 0.0);
                        col2im_slice_into(
                            &ws.stage,
                            n,
                            lw.out_ch,
                            *oh,
                            *ow,
                            &mut ws.slots[*out][..out_len],
                        );
                        if let Some(fa) = fused_add {
                            // out = addend + conv — f32 addition is
                            // commutative, so this is bit-identical to
                            // the standalone Add op it replaced
                            add_slots(&mut ws.slots, fa.addend, *out, *out, out_len, fa.relu);
                        }
                        st.epilogue_ns += t.elapsed().as_nanos() as u64;
                    }
                }
                PlanOp::Linear {
                    layer,
                    input,
                    out,
                    in_cols,
                    out_cols,
                    chunks,
                    in_codes,
                    out_quant,
                    micro_rows,
                    tile_cols,
                } => {
                    let lw = &weights.layers[*layer];
                    gemm.set_block_knobs(*micro_rows, *tile_cols);
                    let in_len = n * in_cols;
                    let t = Instant::now();
                    if *in_codes {
                        // the producer already wrote this layer's codes
                        // row-major — a straight copy replaces quantize
                        PackedActs::copy_codes_into(
                            &ws.code_slots[*input][..in_len],
                            n,
                            *in_cols,
                            lw.a_alpha,
                            act_bits,
                            &mut ws.acts,
                        );
                    } else {
                        PackedActs::quantize_slice_into(
                            &ws.slots[*input][..in_len],
                            n,
                            *in_cols,
                            lw.a_alpha,
                            act_bits,
                            &mut ws.acts,
                        );
                    }
                    st.quantize_ns += t.elapsed().as_nanos() as u64;
                    match out_quant {
                        Some(rq) => {
                            let t = Instant::now();
                            let out_len = n * out_cols;
                            ws.code_slots[*out].resize(out_len, 0);
                            gemm.dispatch(
                                GemmCall {
                                    acts: GemmActs::Packed(&ws.acts),
                                    weights: &lw.sorted,
                                    chunks,
                                    parallel: row_parallel,
                                    fill: true,
                                    out: GemmOut::Quant {
                                        out: &mut ws.code_slots[*out][..out_len],
                                        epi: QuantEpilogue {
                                            bias: &lw.bias,
                                            rq: *rq,
                                            layout: OutLayout::RowMajor { cols: *out_cols },
                                            addend: None,
                                        },
                                    },
                                },
                                &mut ws.scratch,
                            );
                            st.gemm_ns += t.elapsed().as_nanos() as u64;
                        }
                        None => {
                            let t = Instant::now();
                            ws.stage.resize(n, lw.rows);
                            gemm.dispatch(
                                GemmCall {
                                    acts: GemmActs::Packed(&ws.acts),
                                    weights: &lw.sorted,
                                    chunks,
                                    parallel: row_parallel,
                                    fill: true,
                                    out: GemmOut::F32(&mut ws.stage),
                                },
                                &mut ws.scratch,
                            );
                            st.gemm_ns += t.elapsed().as_nanos() as u64;
                            let t = Instant::now();
                            for r in 0..ws.stage.rows {
                                let row = ws.stage.row_mut(r);
                                for (c, v) in row.iter_mut().enumerate() {
                                    *v += lw.bias[c];
                                }
                            }
                            let out_len = n * out_cols;
                            ws.slots[*out].resize(out_len, 0.0);
                            ws.slots[*out][..out_len]
                                .copy_from_slice(&ws.stage.data[..out_len]);
                            st.epilogue_ns += t.elapsed().as_nanos() as u64;
                        }
                    }
                    macs += (n * lw.rows * lw.cols) as u64;
                }
                PlanOp::Add { a, b, out, relu, per_image } => {
                    add_slots(&mut ws.slots, *a, *b, *out, n * per_image, *relu);
                }
                PlanOp::Gap { input, out, c, h, w } => {
                    // stage through the GEMM staging matrix so in-place
                    // (input == out) programs stay correct
                    ws.stage.resize(n, *c);
                    {
                        let inp = &ws.slots[*input];
                        let hw = (h * w) as f32;
                        for img in 0..n {
                            for ch in 0..*c {
                                let base = (img * c + ch) * h * w;
                                let mut s = 0.0;
                                for y in 0..*h {
                                    for xx in 0..*w {
                                        s += inp[base + y * w + xx];
                                    }
                                }
                                ws.stage.set(img, ch, s / hw);
                            }
                        }
                    }
                    let out_len = n * c;
                    ws.slots[*out].resize(out_len, 0.0);
                    ws.slots[*out][..out_len].copy_from_slice(&ws.stage.data[..out_len]);
                }
            }
        }

        gemm.set_block_knobs(base_cfg.micro_rows, base_cfg.tile_cols);

        let out_len = n * plan.logits_cols;
        ws.logits.resize(n, plan.logits_cols);
        ws.logits
            .data
            .copy_from_slice(&ws.slots[plan.logits_slot][..out_len]);
        self.macs += macs;
        self.stages.add(&st);
        Ok(&self.ws.logits)
    }

    /// The original name-resolving interpreter: re-discovers shapes and
    /// allocates per layer on every call. Kept as the bit-exact oracle
    /// for the differential tests (plan output must equal this exactly)
    /// and as the baseline for the plan-vs-interpreter bench.
    pub fn reference_infer(&mut self, x: &Tensor4) -> Result<Mat> {
        let manifest = Arc::clone(&self.manifest);
        let mut bufs: HashMap<&str, Buf> =
            HashMap::with_capacity(manifest.program.len() + 1);
        bufs.insert("in0", Buf::T4(x.clone()));
        for op in &manifest.program {
            match op {
                OpMeta::Conv { layer, input, out, relu } => {
                    let t = bufs
                        .get(input.as_str())
                        .ok_or_else(|| err!("missing buffer {input}"))?
                        .t4()?;
                    let y = self.ref_conv(layer, t, *relu)?;
                    bufs.insert(out.as_str(), Buf::T4(y));
                }
                OpMeta::Linear { layer, input, out } => {
                    let m = bufs
                        .get(input.as_str())
                        .ok_or_else(|| err!("missing buffer {input}"))?
                        .mat()?;
                    let y = self.ref_linear(layer, m)?;
                    bufs.insert(out.as_str(), Buf::M(y));
                }
                OpMeta::Add { a, b, out, relu } => {
                    let ta = bufs.get(a.as_str()).ok_or_else(|| err!("missing {a}"))?.t4()?;
                    let tb = bufs.get(b.as_str()).ok_or_else(|| err!("missing {b}"))?.t4()?;
                    ensure!(ta.data.len() == tb.data.len(), "add shape mismatch {a} {b}");
                    let mut t = ta.clone();
                    for (v, w) in t.data.iter_mut().zip(&tb.data) {
                        *v += w;
                        if *relu && *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    bufs.insert(out.as_str(), Buf::T4(t));
                }
                OpMeta::Gap { input, out } => {
                    let t = bufs
                        .get(input.as_str())
                        .ok_or_else(|| err!("missing {input}"))?
                        .t4()?;
                    let mut m = Mat::zeros(t.n, t.c);
                    let hw = (t.h * t.w) as f32;
                    for n in 0..t.n {
                        for c in 0..t.c {
                            let mut s = 0.0;
                            for y in 0..t.h {
                                for x in 0..t.w {
                                    s += t.at(n, c, y, x);
                                }
                            }
                            m.set(n, c, s / hw);
                        }
                    }
                    bufs.insert(out.as_str(), Buf::M(m));
                }
            }
        }
        match bufs.remove("logits") {
            Some(Buf::M(m)) => Ok(m),
            _ => Err(err!("program produced no 'logits' matrix")),
        }
    }

    fn ref_conv(&mut self, name: &str, x: &Tensor4, relu: bool) -> Result<Tensor4> {
        let li = self.weights.layer_index(name)?;
        let lw: &LayerWeights = &self.weights.layers[li];
        let part = &self.plan.layer_parts[li];
        let k = lw.kh;
        let out_ch = lw.out_ch;
        let groups = lw.groups.max(1);

        let (mut y, oh, ow) = if groups == 1 {
            let (patches, oh, ow) = im2col(x, k, lw.stride, lw.pad);
            let acts = PackedActs::quantize(&patches, lw.a_alpha, self.manifest.act_bits);
            self.macs += (patches.rows * lw.rows * lw.cols) as u64;
            let y = self
                .gemm
                .run_partitioned_with(&acts, &lw.packed, part, self.row_parallel);
            (y, oh, ow)
        } else {
            // grouped conv: run each group's filters over its channel slice.
            let ch_per_group = x.c / groups;
            let filt_per_group = out_ch / groups;
            let mut y: Option<Mat> = None;
            let (mut oh, mut ow) = (0, 0);
            // row-dispatch scratch, hoisted out of the group loop (every
            // group has the same patch-row count, so these allocate once
            // instead of per group)
            let mut col: Vec<f32> = Vec::new();
            let mut acc: Vec<i32> = Vec::new();
            for g in 0..groups {
                let (patches, o_h, o_w) = im2col_group(x, g, ch_per_group, k, lw.stride, lw.pad);
                oh = o_h;
                ow = o_w;
                let acts = PackedActs::quantize(&patches, lw.a_alpha, self.manifest.act_bits);
                let y_all = y.get_or_insert_with(|| Mat::zeros(patches.rows, out_ch));
                // rows of this group's filters in the global weight matrix
                col.resize(acts.rows, 0.0);
                acc.resize(acts.rows, 0);
                for fi in 0..filt_per_group {
                    let r = g * filt_per_group + fi;
                    col.fill(0.0);
                    self.gemm.run_row_into(&acts, &lw.packed, r, &mut acc, &mut col);
                    for bidx in 0..acts.rows {
                        y_all.set(bidx, r, col[bidx]);
                    }
                }
                self.macs += (patches.rows * filt_per_group * lw.cols) as u64;
            }
            (y.unwrap(), oh, ow)
        };

        // bias + relu
        for r in 0..y.rows {
            let row = y.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v += lw.bias[c];
                if relu && *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        Ok(col2im(&y, x.n, out_ch, oh, ow))
    }

    fn ref_linear(&mut self, name: &str, x: &Mat) -> Result<Mat> {
        let li = self.weights.layer_index(name)?;
        let lw = &self.weights.layers[li];
        let part = &self.plan.layer_parts[li];
        let acts = PackedActs::quantize(x, lw.a_alpha, self.manifest.act_bits);
        self.macs += (x.rows * lw.rows * lw.cols) as u64;
        let mut y = self
            .gemm
            .run_partitioned_with(&acts, &lw.packed, part, self.row_parallel);
        for r in 0..y.rows {
            let row = y.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v += lw.bias[c];
            }
        }
        Ok(y)
    }
}

/// The f32 fallback's conv epilogue: add per-channel bias and clamp at
/// zero across the staging matrix — arithmetic identical to the
/// reference interpreter's bias/ReLU pass.
fn conv_bias_relu(stage: &mut Mat, bias: &[f32], relu: bool) {
    for r in 0..stage.rows {
        let row = stage.row_mut(r);
        for (c, v) in row.iter_mut().enumerate() {
            *v += bias[c];
            if relu && *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// Elementwise `out = a + b` (optionally ReLU-clamped) over flat slot
/// buffers, handling every aliasing pattern without copies or
/// allocation. Arithmetic matches the reference interpreter exactly
/// (`a[i] + b[i]`, then clamp).
fn add_slots(slots: &mut [Vec<f32>], a: usize, b: usize, out: usize, len: usize, relu: bool) {
    let fuse = |v: f32| if relu && v < 0.0 { 0.0 } else { v };
    if out == a && out == b {
        let o = &mut slots[out];
        o.resize(len, 0.0);
        for v in o[..len].iter_mut() {
            *v = fuse(*v + *v);
        }
    } else if out == a {
        let (o, rhs) = two_slots(slots, out, b);
        o.resize(len, 0.0);
        for (v, &w) in o[..len].iter_mut().zip(&rhs[..len]) {
            *v = fuse(*v + w);
        }
    } else if out == b {
        let (o, lhs) = two_slots(slots, out, a);
        o.resize(len, 0.0);
        for (v, &w) in o[..len].iter_mut().zip(&lhs[..len]) {
            *v = fuse(w + *v);
        }
    } else if a == b {
        let (o, lhs) = two_slots(slots, out, a);
        o.resize(len, 0.0);
        for (v, &w) in o[..len].iter_mut().zip(&lhs[..len]) {
            *v = fuse(w + w);
        }
    } else {
        // three distinct slots: move the target out (no allocation — the
        // Vec's buffer moves with it) so all three can be viewed at once
        let mut o = std::mem::take(&mut slots[out]);
        o.resize(len, 0.0);
        for ((v, &x), &y) in o[..len]
            .iter_mut()
            .zip(&slots[a][..len])
            .zip(&slots[b][..len])
        {
            *v = fuse(x + y);
        }
        slots[out] = o;
    }
}

/// Disjoint (mutable, shared) borrows of two slots, `w != r`.
fn two_slots<T>(slots: &mut [Vec<T>], w: usize, r: usize) -> (&mut Vec<T>, &Vec<T>) {
    debug_assert_ne!(w, r);
    if w < r {
        let (lo, hi) = slots.split_at_mut(r);
        (&mut lo[w], &hi[0])
    } else {
        let (lo, hi) = slots.split_at_mut(w);
        (&mut hi[0], &lo[r])
    }
}

/// Disjoint (shared input, mutable output) borrows of two code slots —
/// the implicit GEMM reads the producer slot while its epilogue writes
/// the consumer slot (`input != out`, enforced at plan compile: aliased
/// convs fall back to the staged path).
fn slot_pair<T>(slots: &mut [Vec<T>], input: usize, out: usize) -> (&Vec<T>, &mut Vec<T>) {
    let (w, r) = two_slots(slots, out, input);
    (r, w)
}

/// The implicit-GEMM activation source for an integer-resident conv
/// input: the no-copy NHWC alias when the plan retargeted the slot
/// (unit convs), else the NCHW code gather.
fn code_source<'a>(
    codes: &'a [u8],
    geo: PatchGeometry,
    nhwc: bool,
    alpha: f32,
    bits: u32,
) -> ColTileSource<'a> {
    if nhwc {
        // a unit conv's patch matrix IS the NHWC buffer: positions are
        // rows, channels are columns
        ColTileSource::Packed { codes, rows: geo.batch(), cols: geo.cols(), alpha, bits }
    } else {
        ColTileSource::Codes { data: codes, geo, alpha, bits }
    }
}
