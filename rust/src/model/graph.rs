//! Graph-program interpreter: layer-by-layer integer inference.
//!
//! Executes the manifest's op program over the packed weights using the
//! mixed GEMM cores — the software model of the FPGA's layer-by-layer
//! execution. Every conv/linear quantizes its input activations (A4) and
//! dispatches row classes to the scheme cores; adds/GAP/ReLU run in float
//! (they are elementwise / accumulation stages on the hardware too).
//!
//! The executor owns one [`MixedGemm`]; when built via
//! [`Executor::with_parallel`] the GEMM fans row chunks out over a thread
//! pool (optionally shared with other executors — the coordinator gives
//! every worker the same pool). `set_row_parallel` lets the coordinator
//! toggle row-level parallelism per batch without rebuilding anything.

use std::collections::HashMap;
use std::sync::Arc;

use crate::ensure;
use crate::err;
use crate::gemm::{MixedGemm, PackedActs, ParallelConfig, RowPartition};
use crate::quant::tensor::Tensor4;
use crate::quant::Mat;
use crate::util::error::Result;
use crate::util::pool::ThreadPool;

use super::im2col::{col2im, im2col, im2col_group};
use super::manifest::{Manifest, OpMeta};
use super::weights::{LayerWeights, ModelWeights};

/// Re-export for the coordinator's type surface.
pub type Op = OpMeta;

/// A buffer flowing through the program: 4-D feature map or 2-D matrix.
#[derive(Clone, Debug)]
pub enum Buf {
    T4(Tensor4),
    M(Mat),
}

impl Buf {
    fn t4(&self) -> Result<&Tensor4> {
        match self {
            Buf::T4(t) => Ok(t),
            Buf::M(_) => Err(err!("expected 4-D buffer")),
        }
    }

    fn mat(&self) -> Result<&Mat> {
        match self {
            Buf::M(m) => Ok(m),
            Buf::T4(_) => Err(err!("expected 2-D buffer")),
        }
    }
}

/// Per-layer cached execution state.
struct LayerExec {
    part: RowPartition,
}

/// The integer inference executor.
pub struct Executor {
    pub manifest: Manifest,
    pub weights: ModelWeights,
    gemm: MixedGemm,
    cache: HashMap<String, LayerExec>,
    row_parallel: bool,
    /// MACs executed since construction (for GOP accounting).
    pub macs: u64,
}

impl Executor {
    /// Sequential executor (the seed's behaviour).
    pub fn new(manifest: Manifest, weights: ModelWeights) -> Result<Executor> {
        Executor::with_parallel(manifest, weights, ParallelConfig::sequential(), None)
    }

    /// Executor with a parallel mixed GEMM. Pass a pool to share threads
    /// with other executors, or `None` to let the GEMM own one (when the
    /// config resolves to more than one thread).
    pub fn with_parallel(
        manifest: Manifest,
        weights: ModelWeights,
        cfg: ParallelConfig,
        pool: Option<Arc<ThreadPool>>,
    ) -> Result<Executor> {
        // validate: every program layer exists in both tables
        for op in &manifest.program {
            if let OpMeta::Conv { layer, .. } | OpMeta::Linear { layer, .. } = op {
                manifest.layer(layer)?;
                weights.layer(layer)?;
            }
        }
        let cache = weights
            .layers
            .iter()
            .map(|l| {
                (
                    l.name.clone(),
                    LayerExec { part: RowPartition::from_schemes(&l.scheme) },
                )
            })
            .collect();
        let gemm = match pool {
            Some(p) => MixedGemm::with_shared_pool(cfg, p),
            None => MixedGemm::with_config(cfg),
        };
        let row_parallel = gemm.is_parallel();
        Ok(Executor { manifest, weights, gemm, cache, row_parallel, macs: 0 })
    }

    /// Toggle row-level GEMM parallelism for subsequent `infer` calls.
    /// No-op when the executor has no pool. The coordinator turns this
    /// off for batches wide enough to fill the machine by themselves.
    pub fn set_row_parallel(&mut self, on: bool) {
        self.row_parallel = on && self.gemm.is_parallel();
    }

    /// Whether the next `infer` will use row-level parallelism.
    pub fn row_parallel(&self) -> bool {
        self.row_parallel
    }

    /// Run one batch (NCHW input) through the program; returns logits
    /// (batch, num_classes).
    pub fn infer(&mut self, x: Tensor4) -> Result<Mat> {
        let mut bufs: HashMap<String, Buf> = HashMap::new();
        bufs.insert("in0".to_string(), Buf::T4(x));
        let program = self.manifest.program.clone();
        for op in &program {
            match op {
                OpMeta::Conv { layer, input, out, relu } => {
                    let t = bufs
                        .get(input)
                        .ok_or_else(|| err!("missing buffer {input}"))?
                        .t4()?;
                    let y = self.conv(layer, t, *relu)?;
                    bufs.insert(out.clone(), Buf::T4(y));
                }
                OpMeta::Linear { layer, input, out } => {
                    let m = bufs
                        .get(input)
                        .ok_or_else(|| err!("missing buffer {input}"))?
                        .mat()?;
                    let y = self.linear(layer, m)?;
                    bufs.insert(out.clone(), Buf::M(y));
                }
                OpMeta::Add { a, b, out, relu } => {
                    let ta = bufs.get(a).ok_or_else(|| err!("missing {a}"))?.t4()?;
                    let tb = bufs.get(b).ok_or_else(|| err!("missing {b}"))?.t4()?;
                    ensure!(ta.data.len() == tb.data.len(), "add shape mismatch {a} {b}");
                    let mut t = ta.clone();
                    for (v, w) in t.data.iter_mut().zip(&tb.data) {
                        *v += w;
                        if *relu && *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    bufs.insert(out.clone(), Buf::T4(t));
                }
                OpMeta::Gap { input, out } => {
                    let t = bufs.get(input).ok_or_else(|| err!("missing {input}"))?.t4()?;
                    let mut m = Mat::zeros(t.n, t.c);
                    let hw = (t.h * t.w) as f32;
                    for n in 0..t.n {
                        for c in 0..t.c {
                            let mut s = 0.0;
                            for y in 0..t.h {
                                for x in 0..t.w {
                                    s += t.at(n, c, y, x);
                                }
                            }
                            m.set(n, c, s / hw);
                        }
                    }
                    bufs.insert(out.clone(), Buf::M(m));
                }
            }
        }
        match bufs.remove("logits") {
            Some(Buf::M(m)) => Ok(m),
            _ => Err(err!("program produced no 'logits' matrix")),
        }
    }

    fn run_gemm(&self, acts: &PackedActs, lw: &LayerWeights, part: &RowPartition) -> Mat {
        self.gemm.run_partitioned_with(acts, &lw.packed, part, self.row_parallel)
    }

    fn conv(&mut self, name: &str, x: &Tensor4, relu: bool) -> Result<Tensor4> {
        let lw: &LayerWeights = self.weights.layer(name)?;
        let part = &self.cache[name].part;
        let k = lw.kh;
        let out_ch = lw.out_ch;
        let groups = lw.groups.max(1);

        let (mut y, oh, ow) = if groups == 1 {
            let (patches, oh, ow) = im2col(x, k, lw.stride, lw.pad);
            let acts = PackedActs::quantize(&patches, lw.a_alpha, self.manifest.act_bits);
            self.macs += (patches.rows * lw.rows * lw.cols) as u64;
            (self.run_gemm(&acts, lw, part), oh, ow)
        } else {
            // grouped conv: run each group's filters over its channel slice.
            let ch_per_group = x.c / groups;
            let filt_per_group = out_ch / groups;
            let mut y: Option<Mat> = None;
            let (mut oh, mut ow) = (0, 0);
            for g in 0..groups {
                let (patches, o_h, o_w) = im2col_group(x, g, ch_per_group, k, lw.stride, lw.pad);
                oh = o_h;
                ow = o_w;
                let acts = PackedActs::quantize(&patches, lw.a_alpha, self.manifest.act_bits);
                let y_all = y.get_or_insert_with(|| Mat::zeros(patches.rows, out_ch));
                // rows of this group's filters in the global weight matrix
                let mut col = vec![0.0f32; acts.rows];
                let mut acc = vec![0i32; acts.rows];
                for fi in 0..filt_per_group {
                    let r = g * filt_per_group + fi;
                    col.fill(0.0);
                    self.gemm.run_row_into(&acts, &lw.packed, r, &mut acc, &mut col);
                    for bidx in 0..acts.rows {
                        y_all.set(bidx, r, col[bidx]);
                    }
                }
                self.macs += (patches.rows * filt_per_group * lw.cols) as u64;
            }
            (y.unwrap(), oh, ow)
        };

        // bias + relu
        for r in 0..y.rows {
            let row = y.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v += lw.bias[c];
                if relu && *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        Ok(col2im(&y, x.n, out_ch, oh, ow))
    }

    fn linear(&mut self, name: &str, x: &Mat) -> Result<Mat> {
        let lw = self.weights.layer(name)?;
        let part = &self.cache[name].part;
        let acts = PackedActs::quantize(x, lw.a_alpha, self.manifest.act_bits);
        self.macs += (x.rows * lw.rows * lw.cols) as u64;
        let mut y = self.run_gemm(&acts, lw, part);
        for r in 0..y.rows {
            let row = y.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v += lw.bias[c];
            }
        }
        Ok(y)
    }
}
